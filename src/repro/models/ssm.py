"""State-space blocks: Mamba2 (SSD, chunked) and RWKV6 (Finch).

Both are linear-time in sequence length (the long_500k shapes route here)
and expose one-step ``*_decode`` updates with O(1) state caches.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import shard
from .layers import _init, act_fn, rms_norm


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

def mamba_dims(cfg):
    d_in = cfg.mamba_expand * cfg.d_model
    nh = d_in // cfg.mamba_head_dim
    return d_in, nh


def init_mamba(key, cfg) -> dict:
    d, ds = cfg.d_model, cfg.ssm_state
    d_in, nh = mamba_dims(cfg)
    conv_ch = d_in + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "w_in": _init(ks[0], (d, 2 * d_in + 2 * ds + nh)),   # z, xBC, dt
        "conv_w": _init(ks[1], (cfg.conv_width, conv_ch), scale=0.5),
        "conv_b": jnp.zeros((conv_ch,)),
        "dt_bias": jnp.zeros((nh,)),
        "A_log": jnp.zeros((nh,)),
        "D": jnp.ones((nh,)),
        "norm": jnp.zeros((d_in,)),
        "w_out": _init(ks[2], (d_in, d)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, C), w (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    return out + b[None, None]


def _ssd_chunked(xh, dt, a_log, Bc, Cc, chunk: int):
    """Chunked SSD scan (Mamba2).  xh: (B,S,nh,hd), dt: (B,S,nh),
    a_log: per-step log-decay (B,S,nh), Bc/Cc: (B,S,ds)."""
    B, S, nh, hd = xh.shape
    ds = Bc.shape[-1]
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xh = xh.reshape(B, nc, L, nh, hd)
    dtx = (dt.reshape(B, nc, L, nh)[..., None] * xh).astype(f32)
    al = a_log.reshape(B, nc, L, nh).astype(f32)
    Bc = Bc.reshape(B, nc, L, ds).astype(f32)
    Cc = Cc.reshape(B, nc, L, ds).astype(f32)

    cum = jnp.cumsum(al, axis=2)                            # (B,nc,L,nh)
    # intra-chunk: scores[t,s] = (C_t·B_s) exp(cum_t - cum_s) [s<=t]
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)              # (B,nc,L,L)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,nh)
    tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
    m = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = cb[..., None] * m                              # (B,nc,L,L,nh)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", scores, dtx)

    # chunk-final states: sum_s exp(cum_L - cum_s) dtx_s ⊗ B_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,nc,L,nh)
    st = jnp.einsum("bclh,bclhd,bcln->bchdn", tail, dtx, Bc)  # (B,nc,nh,hd,ds)

    # inter-chunk: scan over chunk axis
    def step(S_prev, inp):
        st_c, decay_c = inp                                  # (B,nh,hd,ds),(B,nh)
        S_new = S_prev * decay_c[..., None, None] + st_c
        return S_new, S_prev

    decay_chunk = jnp.exp(cum[:, :, -1, :])                  # (B,nc,nh)
    S0 = jnp.zeros((B, nh, hd, ds), f32)
    _, S_prevs = jax.lax.scan(step, S0, (jnp.moveaxis(st, 1, 0),
                                         jnp.moveaxis(decay_chunk, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                    # (B,nc,nh,hd,ds)
    y_inter = jnp.einsum("bctn,bcth,bchdn->bcthd",
                         Cc, jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(B, nc * L, nh, hd)
    return y[:, :S]


def mamba_block(p, x, cfg, chunk: int = 128, cache=None):
    """Returns (out, new_cache).  cache = {"conv": (B,K-1,C), "ssm": (B,nh,hd,ds)}."""
    B, S, d = x.shape
    ds = cfg.ssm_state
    d_in, nh = mamba_dims(cfg)
    hd = cfg.mamba_head_dim
    proj = jnp.einsum("bsd,dk->bsk", x, p["w_in"].astype(x.dtype))
    z, xBC, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * ds], axis=-1)

    if cache is None:
        xBC = _causal_conv(xBC, p["conv_w"].astype(x.dtype),
                           p["conv_b"].astype(x.dtype))
        new_conv = None
    else:
        ctx = jnp.concatenate([cache["conv"].astype(x.dtype), xBC], axis=1)
        K = p["conv_w"].shape[0]
        xBC = sum(ctx[:, i:i + S] * p["conv_w"][i][None, None].astype(x.dtype)
                  for i in range(K)) + p["conv_b"][None, None].astype(x.dtype)
        new_conv = ctx[:, -(K - 1):]
    xBC = act_fn("silu")(xBC)
    xs, Bc, Cc = jnp.split(xBC, [d_in, d_in + ds], axis=-1)
    xh = xs.reshape(B, S, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    a_log = -jnp.exp(p["A_log"].astype(jnp.float32))[None, None] * dt

    new_cache = None
    if cache is None:
        y = _ssd_chunked(xh, dt, a_log, Bc, Cc, chunk)
    else:  # single/few-step decode: recurrent update
        def step(Sst, inp):
            xh_t, dt_t, al_t, B_t, C_t = inp
            Sst = Sst * jnp.exp(al_t)[..., None, None] + \
                jnp.einsum("bh,bhd,bn->bhdn", dt_t, xh_t, B_t)
            y_t = jnp.einsum("bn,bhdn->bhd", C_t, Sst)
            return Sst, y_t

        seq = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
               jnp.moveaxis(dt, 1, 0), jnp.moveaxis(a_log, 1, 0),
               jnp.moveaxis(Bc.astype(jnp.float32), 1, 0),
               jnp.moveaxis(Cc.astype(jnp.float32), 1, 0))
        S_fin, ys = jax.lax.scan(step, cache["ssm"].astype(jnp.float32), seq)
        y = jnp.moveaxis(ys, 0, 1)
        new_cache = {"conv": new_conv, "ssm": S_fin}

    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype) * act_fn("silu")(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(x.dtype))
    return shard(out, "batch", "seq", None), new_cache


def mamba_cache(cfg, B, dtype=jnp.float32):
    d_in, nh = mamba_dims(cfg)
    conv_ch = d_in + 2 * cfg.ssm_state
    return {"conv": jnp.zeros((B, cfg.conv_width - 1, conv_ch), dtype),
            "ssm": jnp.zeros((B, nh, cfg.mamba_head_dim, cfg.ssm_state),
                             jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV6 (Finch): data-dependent decay time mix + channel mix
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    lora = 64
    ks = jax.random.split(key, 12)
    nh = d // cfg.rwkv_head_dim
    return {
        "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
        "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
        "mu_v": jnp.full((d,), 0.5), "mu_g": jnp.full((d,), 0.5),
        "mu_w": jnp.full((d,), 0.5),
        "w_r": _init(ks[0], (d, d)), "w_k": _init(ks[1], (d, d)),
        "w_v": _init(ks[2], (d, d)), "w_g": _init(ks[3], (d, d)),
        "w_o": _init(ks[4], (d, d)),
        "w0": jnp.full((d,), -4.0),
        "w_lora_a": _init(ks[5], (d, lora)),
        "w_lora_b": _init(ks[6], (lora, d), scale=0.01),
        "u": jnp.zeros((nh, cfg.rwkv_head_dim)),
        "ln_x": jnp.zeros((d,)),
        "mu_cr": jnp.full((d,), 0.5), "mu_ck": jnp.full((d,), 0.5),
        "w_ck": _init(ks[7], (d, ff)), "w_cv": _init(ks[8], (ff, d)),
        "w_cr": _init(ks[9], (d, d)),
    }


def _token_shift(x, prev):
    """prev: (B, d) last token of previous call (zeros at start)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, state):
    """WKV6 recurrence.  r,k: (B,S,nh,hk), v: (B,S,nh,hv), w: (B,S,nh,hk)
    decays in (0,1); u: (nh,hk) bonus.  state: (B,nh,hk,hv)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = S * w_t[..., None] + kv
        return S, y

    seq = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                for t in (r, k, v, w))
    S_fin, ys = jax.lax.scan(step, state.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), S_fin


def rwkv_block(p, x_in, cfg, cache=None):
    """Full residual RWKV6 block: x + time-mix + channel-mix.
    Returns (out, new_cache); cache = {"shift_a","shift_c": (B,d),
    "wkv": (B,nh,hk,hv)}."""
    B, S, d = x_in.shape
    hk = cfg.rwkv_head_dim
    nh = d // hk
    x = rms_norm(x_in, p["ln1"], cfg.norm_eps)
    prev_a = cache["shift_a"].astype(x.dtype) if cache is not None else \
        jnp.zeros((B, d), x.dtype)
    xs = _token_shift(x, prev_a)

    def lerp(mu):
        return x + (xs - x) * mu.astype(x.dtype)[None, None]

    r = jnp.einsum("bsd,dk->bsk", lerp(p["mu_r"]), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", lerp(p["mu_k"]), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", lerp(p["mu_v"]), p["w_v"].astype(x.dtype))
    g = jnp.einsum("bsd,dk->bsk", lerp(p["mu_g"]), p["w_g"].astype(x.dtype))
    # data-dependent decay (the Finch contribution)
    wl = jnp.einsum("bsd,dl->bsl", lerp(p["mu_w"]), p["w_lora_a"].astype(x.dtype))
    wl = jnp.einsum("bsl,ld->bsd", jnp.tanh(wl), p["w_lora_b"].astype(x.dtype))
    w = jnp.exp(-jnp.exp((p["w0"][None, None] + wl).astype(jnp.float32)))

    rh = r.reshape(B, S, nh, hk)
    kh = k.reshape(B, S, nh, hk)
    vh = v.reshape(B, S, nh, hk)
    wh = w.reshape(B, S, nh, hk)
    state = cache["wkv"] if cache is not None else \
        jnp.zeros((B, nh, hk, hk), jnp.float32)
    y, S_fin = _wkv_scan(rh, kh, vh, wh, p["u"], state)
    y = y.reshape(B, S, d).astype(x.dtype)
    # per-head group norm
    yh = y.reshape(B, S, nh, hk).astype(jnp.float32)
    mu = jnp.mean(yh, -1, keepdims=True)
    var = jnp.var(yh, -1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(B, S, d) * (1.0 + p["ln_x"][None, None])).astype(x.dtype)
    y = y * act_fn("silu")(g)
    att = jnp.einsum("bsd,dk->bsk", y, p["w_o"].astype(x.dtype))

    # channel mix on the post-attention residual stream
    res = x_in + att
    x2 = rms_norm(res, p["ln2"], cfg.norm_eps)
    prev_c = cache["shift_c"].astype(x.dtype) if cache is not None else \
        jnp.zeros((B, d), x.dtype)
    xs2 = _token_shift(x2, prev_c)

    def lerp2(mu):
        return x2 + (xs2 - x2) * mu.astype(x.dtype)[None, None]

    ck = jnp.einsum("bsd,df->bsf", lerp2(p["mu_ck"]), p["w_ck"].astype(x.dtype))
    cv = jnp.einsum("bsf,fd->bsd", jnp.square(jax.nn.relu(ck)),
                    p["w_cv"].astype(x.dtype))
    cr = jax.nn.sigmoid(
        jnp.einsum("bsd,dk->bsk", lerp2(p["mu_cr"]), p["w_cr"].astype(x.dtype)))
    ffn = cr * cv

    new_cache = None
    if cache is not None:
        new_cache = {"shift_a": x[:, -1], "shift_c": x2[:, -1], "wkv": S_fin}
    return res + ffn, new_cache


def rwkv_cache(cfg, B, dtype=jnp.float32):
    d = cfg.d_model
    nh = d // cfg.rwkv_head_dim
    return {"shift_a": jnp.zeros((B, d), dtype),
            "shift_c": jnp.zeros((B, d), dtype),
            "wkv": jnp.zeros((B, nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32)}
