"""Shared transformer building blocks (pure functions over param pytrees).

Conventions:
- params are nested dicts of jnp arrays; layer-stacked params carry a leading
  (n_layers,) axis and are consumed via lax.scan.
- activations are bf16 by default with f32 softmax/norm accumulations.
- ``shard(x, *logical_axes)`` annotates activations for GSPMD.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import shard


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps=1e-5):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd), positions: (B, S) -> rotated (half-split layout)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl §3): positions (3, B, S) for (t, h, w);
    frequency bands are partitioned across the three position streams."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # (hd/2,)
    sec = np.cumsum((0,) + tuple(sections))
    assert sec[-1] == hd // 2, (sections, hd)
    parts = []
    for i in range(3):
        ang = positions[i][..., None].astype(jnp.float32) * freqs[sec[i]:sec[i + 1]]
        parts.append(ang)
    ang = jnp.concatenate(parts, axis=-1)                    # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA with optional bias / qk-norm / cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": _init(ks[0], (d, cfg.n_heads * hd)),
        "wk": _init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": _init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": _init(ks[3], (cfg.n_heads * hd, d)),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,))
        p["k_norm"] = jnp.zeros((hd,))
    return p


def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_type == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _sdpa(q, k, v, causal: bool, q_offset=None):
    """q: (B, Sq, Hq, hd), k/v: (B, Skv, Hkv, hd) — grouped-query attention."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    q = q.reshape(B, Sq, Hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if causal:
        qpos = jnp.arange(Sq)[:, None] if q_offset is None else \
            (q_offset + jnp.arange(Sq))[:, None]
        mask = qpos >= jnp.arange(Skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq * hd)


def attention(p, x, cfg, positions, causal=True, cache=None):
    """Returns (out, new_cache).  cache = dict(k, v, index) for decode."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)
    new_cache = None
    if cache is not None:
        idx = cache["index"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "index": idx + S}
        Skv = ck.shape[1]
        mask_pos = jnp.arange(Skv) < (idx + S)
        logits_mask = mask_pos
        out = _sdpa_decode(q, ck, cv, logits_mask)
    else:
        out = _sdpa(q, k, v, causal)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", None), new_cache


def _sdpa_decode(q, k, v, valid_mask):
    """Decode attention against a full cache with a validity mask."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    q = q.reshape(B, Sq, Hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k.astype(q.dtype))
    logits = logits.astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.where(valid_mask[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(q.dtype))
    return out.reshape(B, Sq, Hq * hd)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (deepseek-v2)
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> dict:
    d = cfg.d_model
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": _init(ks[0], (d, r)),            # latent compression
        "w_krope": _init(ks[1], (d, dr)),          # shared rope key
        "kv_norm": jnp.zeros((r,)),
        "w_uk": _init(ks[2], (r, H * dn)),         # latent -> keys
        "w_uv": _init(ks[3], (r, H * dv)),         # latent -> values
        "wo": _init(ks[4], (H * dv, d)),
    }
    if qr:
        p["w_dq"] = _init(ks[5], (d, qr))
        p["q_norm"] = jnp.zeros((qr,))
        p["w_uq"] = _init(ks[6], (qr, H * (dn + dr)))
    else:
        p["wq"] = _init(ks[7], (d, H * (dn + dr)))
    return p


def mla_attention(p, x, cfg, positions, causal=True, cache=None):
    """MLA: queries/keys split into nope + shared-rope parts; the KV cache
    stores only the rank-r latent + rope key (the paper's memory saving)."""
    B, S, d = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cfg.q_lora_rank:
        q = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", q, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if cache is not None:
        idx = cache["index"]
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, idx, 0))
        new_cache = {"c_kv": cc, "k_rope": cr, "index": idx + S}
        c_kv, k_rope = cc.astype(x.dtype), cr.astype(x.dtype)
        valid = jnp.arange(c_kv.shape[1]) < (idx + S)
    else:
        valid = None

    k_nope = jnp.einsum("btr,rh->bth", c_kv, p["w_uk"].astype(x.dtype))
    k_nope = k_nope.reshape(B, -1, H, dn)
    v = jnp.einsum("btr,rh->bth", c_kv, p["w_uv"].astype(x.dtype))
    v = v.reshape(B, -1, H, dv)
    k_nope = shard(k_nope, "batch", "kv_seq", "heads", None)
    v = shard(v, "batch", "kv_seq", "heads", None)

    scale = 1.0 / math.sqrt(dn + dr)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope) +
              jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
              ).astype(jnp.float32) * scale
    Skv = logits.shape[-1]
    if valid is not None:
        logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    elif causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(Skv)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, H * dv)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLP + MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, act: str) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": _init(ks[0], (d, ff)), "w_down": _init(ks[1], (ff, d))}
    if act in ("silu", "gelu"):
        p["w_gate"] = _init(ks[2], (d, ff))
    return p


def mlp(p, x, act: str):
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        up = act_fn(act)(gate) * up
    else:
        up = act_fn(act)(up)
    up = shard(up, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", up, p["w_down"].astype(x.dtype))
    return shard(out, "batch", "seq", None)


def init_moe(key, cfg) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, E)),
        "w_gate": _init(ks[1], (E, d, ff)),
        "w_up": _init(ks[2], (E, d, ff)),
        "w_down": _init(ks[3], (E, ff, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, cfg.n_shared_experts * ff, cfg.act)
    return p


def moe(p, x, cfg):
    """Top-k routed experts with GROUPED capacity-based one-hot dispatch
    (GShard-style).  Tokens are split into groups of ``cfg.moe_group_size``
    and each group gets its own capacity C_g = cf*Tg*k/E, so the dispatch
    tensors are (G, Tg, E, C_g) — linear in tokens, not quadratic (an
    ungrouped dispatch has C ~ T and costs ~50x the expert GEMMs at 1M
    tokens; see EXPERIMENTS.md §Perf).  The dispatch/combine einsums lower
    to all-to-alls when experts are sharded over the 'expert'/'model' mesh
    axis.  Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # group size: tokens per dispatch group.  Small token counts (decode
    # steps, smoke tests) get one dropless group so that prefill ==
    # incremental decode exactly.
    if T <= 4 * E or cfg.capacity_factor <= 0:
        G, Tg, C = 1, T, T
    else:
        Tg = min(cfg.moe_group_size or T, T)
        while T % Tg:                       # largest divisor <= requested
            Tg -= 1
        G = T // Tg
        C = max(1, int(cfg.capacity_factor * Tg * k / E))
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)    # (T, k, E)
    oh = onehot.reshape(G, Tg, k, E)
    gates = gate_vals.reshape(G, Tg, k)
    # capacity slots are assigned over the flattened (token, slot) axis of
    # each group so different top-k columns of one expert never collide.
    # Slot positions are computed in f32 (bf16 cannot represent integers
    # > 256 exactly); only the big 0/1 dispatch tensors are bf16 (exact).
    ohf = oh.reshape(G, Tg * k, E)
    posf = jnp.cumsum(ohf, axis=1) - ohf
    pos = jnp.einsum("gse,gse->gs", posf, ohf).reshape(G, Tg, k)
    ddt = x.dtype   # bf16 in production (0/1 exact); f32 models stay exact
    keep = (pos < C).astype(ddt)
    poh = jax.nn.one_hot(pos, C, dtype=ddt)                    # (G,Tg,k,C)
    oh16 = oh.astype(ddt)
    disp = jnp.einsum("gtke,gtk,gtkc->gtec", oh16, keep, poh)  # (G,Tg,E,C)
    comb = jnp.einsum("gtec,gtke,gtk->gtec", disp, oh16,
                      gates.astype(ddt))
    xg = xt.reshape(G, Tg, d)
    xe = jnp.einsum("gtec,gtd->egcd", disp.astype(x.dtype), xg)
    # expert slots: experts over 'model' (the EP all-to-all), slot groups
    # KEEP their 'data' sharding — replicating slots would force the SPMD
    # partitioner to all-gather every expert activation in the backward
    # pass (§Perf cell A it2: 16.8 GiB/dev of gathers in a 2-layer probe)
    xe = shard(xe, "expert", "moe_slots", None, None)
    xe = xe.reshape(E, G * C, d)                               # expert slots
    xe = shard(xe, "expert", "moe_slots", None)
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
    h = act_fn(cfg.act)(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    ye = shard(ye, "expert", "moe_slots", None)
    ye = ye.reshape(E, G, C, d)
    out = jnp.einsum("gtec,egcd->gtd", comb.astype(x.dtype), ye)
    out = out.reshape(T, d)
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x, cfg.act).reshape(T, d)
    # load-balancing aux loss (Switch-style)
    me = jnp.mean(onehot[:, 0], axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef
    return shard(out.reshape(B, S, d), "batch", "seq", None), aux
