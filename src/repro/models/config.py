"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SigHeadConfig:
    """Signature pooling head (the paper's technique as a model component)."""
    channels: int = 8          # path dimension after the learned projection
    depth: int = 3             # truncation depth
    use_logsig: bool = False
    stride: int = 1            # subsample hidden trajectory before signing
    backend: str = "auto"      # engine dispatch (repro.kernels.ops)
    backward: str = "inverse"  # inverse | checkpoint | autodiff
    stream_stride: int = 1     # per-step feature emission stride (sig_stream_features)
    # path transform fused into the signature sweep ("time_augment" /
    # "lead_lag" / "basepoint", "+"-composable; None = sign the raw learned
    # path).  Projected plans must then be over the AUGMENTED alphabet
    # (transform_dim(transform, channels) letters).
    transform: Optional[str] = None
    precision: str = "fp32"    # "fp32" | "bf16_fp32" mixed-precision sweep
    # --- kernel-feature head (repro.sigkernel) ---
    kernel_landmarks: int = 0      # > 0: features are k_ω(path, landmark_j)
    landmark_steps: int = 8        # increments per learned landmark path
    kernel_level_decay: float = 0.5  # level weight λ^n in the gram weighting
    kernel_normalize: bool = True  # RKHS cosine instead of raw k_ω


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # decoder | encdec | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    act: str = "silu"
    attn_bias: bool = False    # qkv bias (qwen1.5)
    qk_norm: bool = False      # qwen3
    rope_theta: float = 1e4
    rope_type: str = "rope"    # rope | mrope | none | sinusoidal
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: float = 0.0
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_layer_start: int = 0       # layers < start are dense
    d_ff_dense: int = 0            # d_ff of dense layers in a MoE model
    capacity_factor: float = 1.25
    moe_group_size: int = 512      # tokens per dispatch group (GShard-style)
    router_aux_coef: float = 0.001
    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- hybrid / ssm ---
    ssm_state: int = 0
    mamba_head_dim: int = 64
    mamba_expand: int = 2
    conv_width: int = 4
    hybrid_attn_every: int = 6     # zamba2: shared attn block cadence
    n_shared_attn_blocks: int = 2  # zamba2: alternating shared blocks
    rwkv_head_dim: int = 64
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500     # encoder positions (stub frontend)
    decoder_max_len: int = 448
    # --- vlm ---
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    # --- paper technique ---
    sig_head: Optional[SigHeadConfig] = None
    # --- notes ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # ------------------------------------------------------------------
    # analytic parameter count (for MODEL_FLOPS = 6·N·D roofline term)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        P = self.vocab_size * d                     # embedding
        if not self.tie_embeddings:
            P += self.vocab_size * d                # lm head

        def attn_params() -> int:
            if self.mla:
                p = d * self.kv_lora_rank + d * self.qk_rope_dim     # kv down
                p += self.kv_lora_rank * nq * (self.qk_nope_dim + self.v_head_dim)
                if self.q_lora_rank:
                    p += d * self.q_lora_rank + self.q_lora_rank * nq * (
                        self.qk_nope_dim + self.qk_rope_dim)
                else:
                    p += d * nq * (self.qk_nope_dim + self.qk_rope_dim)
                p += nq * self.v_head_dim * d                        # out
                return p
            return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

        def mlp_params(ff: int) -> int:
            mult = 3 if self.act in ("silu", "swiglu", "geglu") else 2
            return mult * d * ff

        def mamba_params() -> int:
            d_in = self.mamba_expand * d
            nh = d_in // self.mamba_head_dim
            p = d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj (z,x,B,C,dt)
            p += self.conv_width * (d_in + 2 * self.ssm_state)
            p += d_in * d                                   # out proj
            p += 2 * nh                                     # A_log, D
            return p

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,o + decay LoRA; channel-mix: 2 mats
            p = 5 * d * d + 2 * d * 64 + 6 * d
            p += d * self.d_ff + self.d_ff * d + d * d     # channel mix (r,k,v)
            return p

        if self.family == "rwkv":
            P += self.n_layers * rwkv_params()
        elif self.family == "hybrid":
            n_attn = self.n_shared_attn_blocks            # weight-shared
            P += self.n_layers * (mamba_params() + 2 * d)
            P += n_attn * (attn_params() + mlp_params(self.d_ff) + 2 * d)
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (attn_params() + mlp_params(self.d_ff))
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff))
            P += enc + dec
        else:
            for layer in range(self.n_layers):
                P += attn_params()
                if self.moe and layer >= self.moe_layer_start:
                    P += self.n_experts * mlp_params(self.d_ff_expert)
                    P += self.n_shared_experts * mlp_params(self.d_ff_expert)
                    P += d * self.n_experts                # router
                else:
                    P += mlp_params(self.d_ff_dense or self.d_ff)
        P += self.n_layers * 2 * d                         # norms (approx)
        return P

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.act in ("silu", "swiglu", "geglu") else 2
        n_moe_layers = self.n_layers - self.moe_layer_start
        expert_p = mult * self.d_model * self.d_ff_expert
        inactive = n_moe_layers * (self.n_experts - self.top_k) * expert_p
        return full - inactive
