"""Decoder LM families: dense/GQA, MoE, MLA, hybrid (Mamba2+shared-attn),
RWKV6 — one init/forward/decode triple driven by ModelConfig.

All homogeneous stacks use lax.scan over layer-stacked parameters (small HLO,
fast SPMD compile at 100+ layers).  Remat policy is a forward() argument so
the perf loop can flip it without touching model code.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def layer_scan(f, init, xs):
    """lax.scan over stacked layers; REPRO_SCAN_UNROLL=1 fully unrolls so
    HLO cost analysis sees every layer (used by the roofline probes, which
    would otherwise count while-loop bodies once)."""
    unroll = bool(int(os.environ.get("REPRO_SCAN_UNROLL", "0")))
    return jax.lax.scan(f, init, xs, unroll=True if unroll else 1)

from repro.distributed.ctx import shard
from .config import ModelConfig
from .layers import (_init, attention, init_attention, init_mla, init_mlp,
                     init_moe, mla_attention, mlp, moe, rms_norm)
from .ssm import (init_mamba, init_rwkv, mamba_block, mamba_cache, rwkv_block,
                  rwkv_cache)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack(key, n, init_one):
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def _init_decoder_layer(cfg, use_moe):
    def one(key):
        ks = jax.random.split(key, 3)
        p = {"ln_attn": jnp.zeros((cfg.d_model,)),
             "ln_mlp": jnp.zeros((cfg.d_model,))}
        p["attn"] = init_mla(ks[0], cfg) if cfg.mla else init_attention(ks[0], cfg)
        if use_moe:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model,
                                cfg.d_ff_dense or cfg.d_ff, cfg.act)
        return p
    return one


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict = {
        "embed": _init(ks[0], (cfg.vocab_size, d), scale=0.02),
        "ln_f": jnp.zeros((d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(ks[1], (d, cfg.vocab_size))

    if cfg.family == "rwkv":
        params["layers"] = _stack(ks[2], cfg.n_layers,
                                  lambda k: init_rwkv(k, cfg))
    elif cfg.family == "hybrid":
        params["layers"] = _stack(ks[2], cfg.n_layers,
                                  lambda k: {"ln": jnp.zeros((d,)),
                                             "mamba": init_mamba(k, cfg)})
        def shared_one(k):
            k1, k2 = jax.random.split(k)
            return {"ln_attn": jnp.zeros((d,)), "ln_mlp": jnp.zeros((d,)),
                    "attn": init_attention(k1, cfg),
                    "mlp": init_mlp(k2, d, cfg.d_ff, cfg.act)}
        params["shared_attn"] = _stack(ks[3], cfg.n_shared_attn_blocks,
                                       shared_one)
    else:  # decoder (dense or MoE; MoE may have leading dense layers)
        n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
        n_moe = cfg.n_layers - n_dense if cfg.moe else 0
        if n_dense:
            params["dense_layers"] = _stack(
                ks[2], n_dense, _init_decoder_layer(cfg, use_moe=False))
        if n_moe:
            params["layers"] = _stack(
                ks[3], n_moe, _init_decoder_layer(cfg, use_moe=True))
        elif not cfg.moe:
            params["layers"] = params.pop("dense_layers")
    return jax.tree.map(lambda x: x.astype(dtype)
                        if x.dtype == jnp.float32 else x, params)


# ---------------------------------------------------------------------------
# forward (training path)
# ---------------------------------------------------------------------------

def _decoder_layer_fwd(p, x, cfg, positions, use_moe, cache=None):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    attn_fn = mla_attention if cfg.mla else attention
    a, new_kv = attn_fn(p["attn"], h, cfg, positions, cache=cache)
    x = x + a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if use_moe:
        m, aux = moe(p["moe"], h, cfg)
    else:
        m, aux = mlp(p["mlp"], h, cfg.act), 0.0
    return x + m, aux, new_kv


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(mode)


def _scan_layers(layer_params, x, body, remat_mode):
    fn = _remat(body, remat_mode)

    def step(carry, p):
        x, aux = carry
        x2, aux2 = fn(p, x)
        return (x2, aux + aux2), None

    (x, aux), _ = layer_scan(step, (x, 0.0), layer_params)
    return x, aux


def backbone(params, cfg: ModelConfig, tokens=None, embeds=None,
             positions=None, remat: str = "dots"):
    """Token/embedding inputs -> final hidden states (B, S, d).  Returns
    (hidden, aux_loss)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * math.sqrt(cfg.d_model) if cfg.family == "encdec" else x
    else:
        x = embeds
    B, S = x.shape[:2]
    x = shard(x, "batch", "seq", None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, S))

    aux = 0.0
    if cfg.family == "rwkv":
        def body(p, h):
            h2, _ = rwkv_block(p, h, cfg)
            return h2, 0.0
        x, aux = _scan_layers(params["layers"], x, body, remat)
    elif cfg.family == "hybrid":
        def mbody(p, h):
            h2, _ = mamba_block(p["mamba"],
                                rms_norm(h, p["ln"], cfg.norm_eps), cfg)
            return h + h2, 0.0
        per = cfg.hybrid_attn_every
        n_groups = -(-cfg.n_layers // per)
        done = 0
        for g in range(n_groups):
            take = min(per, cfg.n_layers - done)
            sl = jax.tree.map(lambda a: a[done:done + take], params["layers"])
            x, _ = _scan_layers(sl, x, mbody, remat)
            done += take
            sb = jax.tree.map(
                lambda a: a[g % cfg.n_shared_attn_blocks], params["shared_attn"])
            x, _, _ = _decoder_layer_fwd(sb, x, cfg, positions, use_moe=False)
    else:
        n_dense = cfg.moe_layer_start if cfg.moe else 0
        if cfg.moe and n_dense:
            def dbody(p, h):
                h2, a2, _ = _decoder_layer_fwd(p, h, cfg, positions, False)
                return h2, a2
            x, aux0 = _scan_layers(params["dense_layers"], x, dbody, remat)
            aux += aux0
        def body(p, h):
            h2, a2, _ = _decoder_layer_fwd(p, h, cfg, positions, cfg.moe)
            return h2, a2
        x, aux1 = _scan_layers(params["layers"], x, body, remat)
        aux += aux1
    return rms_norm(x, params["ln_f"], cfg.norm_eps), aux


def logits_fn(params, cfg, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden, w.astype(hidden.dtype))
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return shard(logits, "batch", "seq", "vocab")


def lm_loss(params, cfg: ModelConfig, batch, remat: str = "dots"):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-100 = ignore),
    optional embeds/positions.  Returns (loss, metrics)."""
    hidden, aux = backbone(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           positions=batch.get("positions"), remat=remat)
    logits = logits_fn(params, cfg, hidden).astype(jnp.float32)
    labels = batch["labels"]
    valid = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    ntok = jnp.maximum(jnp.sum(valid), 1.0)
    loss = jnp.sum(nll * valid) / ntok
    # z-loss for stability at scale
    zl = 1e-4 * jnp.sum(jax.scipy.special.logsumexp(logits, -1) ** 2 * valid) / ntok
    return loss + aux + zl, {"loss": loss, "aux": aux, "ntok": ntok}


# ---------------------------------------------------------------------------
# decode path (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    if cfg.family == "rwkv":
        return {"layers": jax.tree.map(
            lambda x: jnp.stack([x] * cfg.n_layers),
            rwkv_cache(cfg, B, dtype))}
    if cfg.family == "hybrid":
        mc = jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers),
                          mamba_cache(cfg, B, dtype))
        kv = {"k": jnp.zeros((cfg.n_shared_attn_blocks, B, max_len,
                              cfg.n_kv_heads, hd), dtype),
              "v": jnp.zeros((cfg.n_shared_attn_blocks, B, max_len,
                              cfg.n_kv_heads, hd), dtype),
              "index": jnp.zeros((cfg.n_shared_attn_blocks,), jnp.int32)}
        return {"layers": mc, "shared_attn": kv}
    if cfg.mla:
        return {"layers": {
            "c_kv": jnp.zeros((cfg.n_layers, B, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((cfg.n_layers, B, max_len, cfg.qk_rope_dim), dtype),
            "index": jnp.zeros((cfg.n_layers,), jnp.int32)}}
    return {"layers": {
        "k": jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, hd), dtype),
        "index": jnp.zeros((cfg.n_layers,), jnp.int32)}}


def decode_step(params, cfg: ModelConfig, tokens, cache, positions=None,
                embeds=None):
    """One decoding step.  tokens: (B, 1) (or embeds (B,1,d)).  Returns
    (logits (B,1,V), new_cache)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds
    B, S = x.shape[:2]
    if positions is None:
        if cfg.family == "hybrid":
            pos_scalar = cache["shared_attn"]["index"][0]
        elif "index" in cache["layers"]:
            pos_scalar = cache["layers"]["index"][0]
        else:
            pos_scalar = jnp.zeros((), jnp.int32)
        positions = jnp.broadcast_to(pos_scalar + jnp.arange(S)[None], (B, S))
        if cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, S))

    if cfg.family == "rwkv":
        def step(h, inp):
            p, c = inp
            h2, c2 = rwkv_block(p, h, cfg, cache=c)
            return h2, c2
        x, new_lc = layer_scan(step, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_lc}
    elif cfg.family == "hybrid":
        per = cfg.hybrid_attn_every
        n_groups = -(-cfg.n_layers // per)
        done = 0
        new_m, kvs = [], dict(cache["shared_attn"])
        def mstep(h, inp):
            p, c = inp
            h2, c2 = mamba_block(p["mamba"],
                                 rms_norm(h, p["ln"], cfg.norm_eps), cfg,
                                 cache=c)
            return h + h2, c2
        for g in range(n_groups):
            take = min(per, cfg.n_layers - done)
            sl = jax.tree.map(lambda a: a[done:done + take], params["layers"])
            cl = jax.tree.map(lambda a: a[done:done + take], cache["layers"])
            x, c2 = layer_scan(mstep, x, (sl, cl))
            new_m.append(c2)
            done += take
            b = g % cfg.n_shared_attn_blocks
            sb = jax.tree.map(lambda a: a[b], params["shared_attn"])
            kvc = {"k": kvs["k"][b], "v": kvs["v"][b], "index": kvs["index"][b]}
            x, _, kvn = _decoder_layer_fwd(sb, x, cfg, positions, False, kvc)
            if g < cfg.n_shared_attn_blocks:  # shared blocks share one cache
                kvs = {"k": kvs["k"].at[b].set(kvn["k"]),
                       "v": kvs["v"].at[b].set(kvn["v"]),
                       "index": kvs["index"].at[b].set(kvn["index"])}
        new_cache = {"layers": jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *new_m),
            "shared_attn": kvs}
    else:
        use_moe = cfg.moe

        def step(h, inp):
            p, c = inp
            h2, _, c2 = _decoder_layer_fwd(p, h, cfg, positions, use_moe,
                                           cache=c)
            return h2, c2

        lp = params["layers"]
        lc = cache["layers"]
        if cfg.moe and cfg.moe_layer_start:
            nd = cfg.moe_layer_start
            dcache = jax.tree.map(lambda a: a[:nd], lc)
            def dstep(h, inp):
                p, c = inp
                h2, _, c2 = _decoder_layer_fwd(p, h, cfg, positions, False, c)
                return h2, c2
            x, ndc = layer_scan(dstep, x, (params["dense_layers"], dcache))
            mcache = jax.tree.map(lambda a: a[nd:], lc)
            x, nmc = layer_scan(step, x, (lp, mcache))
            new_lc = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                  ndc, nmc)
        else:
            x, new_lc = layer_scan(step, x, (lp, lc))
        new_cache = {"layers": new_lc}

    hidden = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, cfg, hidden), new_cache
