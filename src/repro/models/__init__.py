"""Architecture pool: config-driven model builders."""
from .config import ModelConfig, SigHeadConfig
from . import transformer, encdec, layers, ssm, sig_head


def init_params(key, cfg: ModelConfig, dtype=None):
    import jax.numpy as jnp
    dtype = dtype or jnp.float32
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg, dtype)
    return transformer.init_params(key, cfg, dtype)


def loss_fn(params, cfg: ModelConfig, batch, remat: str = "dots"):
    if cfg.family == "encdec":
        return encdec.lm_loss(params, cfg, batch, remat=remat)
    return transformer.lm_loss(params, cfg, batch, remat=remat)


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=None):
    import jax.numpy as jnp
    dtype = dtype or jnp.bfloat16
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, B, max_len, dtype)
    return transformer.init_cache(cfg, B, max_len, dtype)


def decode_step(params, cfg: ModelConfig, tokens, cache, **kw):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, tokens, cache)
    return transformer.decode_step(params, cfg, tokens, cache, **kw)


__all__ = ["ModelConfig", "SigHeadConfig", "init_params", "loss_fn",
           "init_cache", "decode_step", "transformer", "encdec", "layers",
           "ssm", "sig_head"]
