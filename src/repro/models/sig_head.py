"""SigHead: the paper's technique as a first-class model component.

Pools a hidden-state trajectory (B, S, d_model) through a (projected)
truncated signature of a learned low-dimensional path — a drop-in,
fully-differentiable alternative to mean/last-token pooling for any
architecture in the pool (DESIGN.md §Arch-applicability).

:func:`sig_stream_features` is the per-step variant: the engine dispatch's
streamed forward emits the prefix signature of the learned path at every
``stream_stride``-th position, producing a (B, S_out, n_out) feature
trajectory that transformer/SSM blocks can consume as auxiliary per-token
inputs (trained end to end through the streamed §4.2 backward).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import logsignature, signature, sig_dim, logsig_dim
from repro.core.projection import projected_signature
from repro.core.signature import stream_emit_steps
from repro.core.words import WordPlan
from .config import ModelConfig, SigHeadConfig
from .layers import _init


def feature_dim(sc: SigHeadConfig) -> int:
    if sc.use_logsig:
        return logsig_dim(sc.channels, sc.depth) + sc.channels
    return sig_dim(sc.channels, sc.depth) + sc.channels


def init_sig_head(key, cfg: ModelConfig, n_out: int) -> dict:
    sc = cfg.sig_head
    k1, k2 = jax.random.split(key)
    return {"proj": _init(k1, (cfg.d_model, sc.channels)),
            "out": _init(k2, (feature_dim(sc), n_out))}


def _learned_path(p, hidden: jax.Array, sc: SigHeadConfig) -> jax.Array:
    """(B, S, d_model) -> normalised low-dimensional path (B, S', channels)."""
    path = jnp.einsum("bsd,dc->bsc", hidden, p["proj"].astype(hidden.dtype))
    path = path.astype(jnp.float32)
    if sc.stride > 1:
        path = path[:, ::sc.stride]
    # normalise scale so deep signatures stay well-conditioned
    return path / jnp.sqrt(jnp.float32(path.shape[1]))


def sig_stream_features(p, hidden: jax.Array, cfg: ModelConfig,
                        plan: WordPlan | None = None) -> jax.Array:
    """(B, S, d_model) -> (B, S_out, n_out) per-step signature features.

    Step t carries the signature of the learned path over [0, t] (the
    expanding window), emitted every ``sig_head.stream_stride`` positions by
    the streamed engine dispatch — O(B·D_sig) live training memory via the
    streamed inverse backward, whatever the backend.
    """
    sc = cfg.sig_head
    if sc.use_logsig:
        raise NotImplementedError(
            "streamed per-step log-signature features are not supported; "
            "use use_logsig=False (or pool with sig_pool)")
    path = _learned_path(p, hidden, sc)
    if plan is not None:
        feats = projected_signature(path, plan.words, sc.channels, plan=plan,
                                    stream=True,
                                    stream_stride=sc.stream_stride,
                                    backend=sc.backend, backward=sc.backward)
    else:
        feats = signature(path, sc.depth, stream=True,
                          stream_stride=sc.stream_stride,
                          backend=sc.backend, backward=sc.backward)
    # per-step displacement rides along, mirroring the pooled feature layout
    steps = stream_emit_steps(path.shape[1] - 1, sc.stream_stride)
    disp = jnp.take(path, jnp.asarray(steps) + 1, axis=1) - path[:, :1]
    feats = jnp.concatenate([feats, disp], axis=-1)
    return jnp.einsum("btf,fo->bto", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))


def sig_pool(p, hidden: jax.Array, cfg: ModelConfig,
             plan: WordPlan | None = None) -> jax.Array:
    """(B, S, d_model) -> (B, n_out) sequence-level readout."""
    sc = cfg.sig_head
    path = _learned_path(p, hidden, sc)
    # all three feature routes ride the engine dispatch (repro.kernels.ops):
    # the configured backend's kernel forward + O(1)-in-length backward is
    # exactly the path jax.grad differentiates during training.
    if plan is not None:
        feats = projected_signature(path, plan.words, sc.channels, plan=plan,
                                    backend=sc.backend, backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    elif sc.use_logsig:
        feats = logsignature(path, sc.depth, backend=sc.backend,
                             backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    else:
        feats = signature(path, sc.depth, backend=sc.backend,
                          backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    return jnp.einsum("bf,fo->bo", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))
