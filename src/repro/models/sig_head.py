"""SigHead: the paper's technique as a first-class model component.

Pools a hidden-state trajectory (B, S, d_model) through a (projected)
truncated signature of a learned low-dimensional path — a drop-in,
fully-differentiable alternative to mean/last-token pooling for any
architecture in the pool (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import logsignature, signature, sig_dim, logsig_dim
from repro.core.projection import projected_signature
from repro.core.words import WordPlan
from .config import ModelConfig, SigHeadConfig
from .layers import _init


def feature_dim(sc: SigHeadConfig) -> int:
    if sc.use_logsig:
        return logsig_dim(sc.channels, sc.depth) + sc.channels
    return sig_dim(sc.channels, sc.depth) + sc.channels


def init_sig_head(key, cfg: ModelConfig, n_out: int) -> dict:
    sc = cfg.sig_head
    k1, k2 = jax.random.split(key)
    return {"proj": _init(k1, (cfg.d_model, sc.channels)),
            "out": _init(k2, (feature_dim(sc), n_out))}


def sig_pool(p, hidden: jax.Array, cfg: ModelConfig,
             plan: WordPlan | None = None) -> jax.Array:
    """(B, S, d_model) -> (B, n_out) sequence-level readout."""
    sc = cfg.sig_head
    path = jnp.einsum("bsd,dc->bsc", hidden, p["proj"].astype(hidden.dtype))
    path = path.astype(jnp.float32)
    if sc.stride > 1:
        path = path[:, ::sc.stride]
    # normalise scale so deep signatures stay well-conditioned
    path = path / jnp.sqrt(jnp.float32(path.shape[1]))
    # all three feature routes ride the engine dispatch (repro.kernels.ops):
    # the configured backend's kernel forward + O(1)-in-length backward is
    # exactly the path jax.grad differentiates during training.
    if plan is not None:
        feats = projected_signature(path, plan.words, sc.channels, plan=plan,
                                    backend=sc.backend, backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    elif sc.use_logsig:
        feats = logsignature(path, sc.depth, backend=sc.backend,
                             backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    else:
        feats = signature(path, sc.depth, backend=sc.backend,
                          backward=sc.backward)
        feats = jnp.concatenate([feats, path[:, -1] - path[:, 0]], axis=-1)
    return jnp.einsum("bf,fo->bo", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))
