"""SigHead: the paper's technique as a first-class model component.

Pools a hidden-state trajectory (B, S, d_model) through a (projected)
truncated signature of a learned low-dimensional path — a drop-in,
fully-differentiable alternative to mean/last-token pooling for any
architecture in the pool (DESIGN.md §Arch-applicability).

:func:`sig_stream_features` is the per-step variant: the engine dispatch's
streamed forward emits the prefix signature of the learned path at every
``stream_stride``-th position, producing a (B, S_out, n_out) feature
trajectory that transformer/SSM blocks can consume as auxiliary per-token
inputs (trained end to end through the streamed §4.2 backward).

``SigHeadConfig.kernel_landmarks > 0`` switches the pooled readout to the
*kernel-feature head* (:func:`sig_kernel_pool`): features are the weighted
signature-kernel scores k_ω(path, landmark_j) against a bank of LEARNED
landmark paths — a trainable Nyström layer riding :mod:`repro.sigkernel`.
Gradients reach both the hidden trajectory (via the §4.2 inverse VJP on the
signature legs) and the landmark paths (via the Gram product VJP).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.core import logsignature, signature, sig_dim, logsig_dim
from repro.core.projection import projected_signature
from repro.core.signature import stream_emit_mask, stream_emit_steps
from repro.core.words import WordPlan
from .config import ModelConfig, SigHeadConfig
from .layers import _init


def _sig_channels(sc: SigHeadConfig) -> int:
    """Channel count the signature actually runs over: the learned-path
    channels after the configured fused transform (the displacement feature
    stays over the RAW channels)."""
    from repro.core.transforms import as_transform, transform_dim
    return transform_dim(as_transform(sc.transform), sc.channels)


def feature_dim(sc: SigHeadConfig) -> int:
    if sc.use_logsig and sc.transform is not None:
        raise NotImplementedError(
            "use_logsig=True has no fused-transform route; set transform="
            "None (or apply repro.core.transforms.apply_transform yourself)")
    if sc.kernel_landmarks > 0:
        if sc.use_logsig:
            raise NotImplementedError(
                "the kernel-feature head scores truncated signatures; "
                "use_logsig=True with kernel_landmarks > 0 is not supported")
        return sc.kernel_landmarks + sc.channels
    if sc.use_logsig:
        return logsig_dim(sc.channels, sc.depth) + sc.channels
    return sig_dim(_sig_channels(sc), sc.depth) + sc.channels


def init_sig_head(key, cfg: ModelConfig, n_out: int) -> dict:
    sc = cfg.sig_head
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"proj": _init(k1, (cfg.d_model, sc.channels)),
         "out": _init(k2, (feature_dim(sc), n_out))}
    if sc.kernel_landmarks > 0:
        # landmark paths: small random walks in the learned-path space, the
        # same scale _learned_path normalises real paths to
        steps = jax.random.normal(
            k3, (sc.kernel_landmarks, sc.landmark_steps, sc.channels))
        walk = jnp.cumsum(steps, axis=1) / jnp.sqrt(
            jnp.float32(sc.landmark_steps))
        p["landmarks"] = jnp.concatenate(
            [jnp.zeros_like(walk[:, :1]), walk], axis=1)
    return p


def _learned_path(p, hidden: jax.Array, sc: SigHeadConfig, mask=None):
    """(B, S, d_model) -> normalised low-dimensional path (B, S', channels).

    ``mask`` (B, S) is the backbone's (right-padded) attention mask; with it
    the return is ``(path, lengths)`` where ``lengths`` counts each
    example's TRUE increments after striding, and the scale normalisation
    uses each example's true point count — so the head's output for a padded
    batch is exactly its output on the unpadded sequences.
    """
    path = jnp.einsum("bsd,dc->bsc", hidden, p["proj"].astype(hidden.dtype))
    path = path.astype(jnp.float32)
    if sc.stride > 1:
        path = path[:, ::sc.stride]
    if mask is None:
        # normalise scale so deep signatures stay well-conditioned
        return path / jnp.sqrt(jnp.float32(path.shape[1]))
    lengths, norm = mask_path_lengths(mask, sc.stride)
    return path / norm[:, None, None], lengths


def mask_path_lengths(mask: jax.Array, stride: int):
    """(B, S) right-padded attention mask -> (lengths, norm): each example's
    TRUE increment count after ``[::stride]`` subsampling, and the per-
    example √point-count scale normaliser.  The one definition of the
    mask-to-ragged bookkeeping, shared by the sig head and the trainer."""
    n_pts = mask.astype(jnp.int32).sum(axis=-1)          # valid positions
    n_strided = (n_pts + stride - 1) // stride           # kept by [::stride]
    lengths = jnp.maximum(n_strided - 1, 0)              # increments
    norm = jnp.sqrt(jnp.maximum(n_strided, 1).astype(jnp.float32))
    return lengths, norm


def _ragged_disp(path: jax.Array, lengths: jax.Array) -> jax.Array:
    """(B, S', c) x (B,) -> (B, c) displacement to the true endpoint."""
    idx = lengths.astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(path, idx, axis=1)[:, 0] - path[:, 0]


def sig_stream_features(p, hidden: jax.Array, cfg: ModelConfig,
                        plan: WordPlan | None = None, mask=None) -> jax.Array:
    """(B, S, d_model) -> (B, S_out, n_out) per-step signature features.

    Step t carries the signature of the learned path over [0, t] (the
    expanding window), emitted every ``sig_head.stream_stride`` positions by
    the streamed engine dispatch — O(B·D_sig) live training memory via the
    streamed inverse backward, whatever the backend.  ``mask`` (B, S) makes
    the trajectory ragged: emissions past each example's true end are
    zeroed (signature AND displacement columns).
    """
    from repro.core.transforms import as_transform
    sc = cfg.sig_head
    if sc.use_logsig:
        raise NotImplementedError(
            "streamed per-step log-signature features are not supported; "
            "use use_logsig=False (or pool with sig_pool)")
    if sc.kernel_landmarks > 0:
        raise NotImplementedError(
            "the kernel-feature head has no streamed variant; use "
            "kernel_landmarks=0 for sig_stream_features (or pool with "
            "sig_pool)")
    spec = as_transform(sc.transform)
    if spec is not None and (spec.lead_lag or spec.basepoint):
        # lead_lag doubles / basepoint shifts the emission step axis, so the
        # emitted rows no longer align 1:1 with the strided raw positions the
        # displacement column (and the consuming block) index by
        raise NotImplementedError(
            "sig_stream_features supports transform=None or 'time_augment' "
            "only (lead_lag / basepoint change the streamed step axis); "
            "pool with sig_pool for the full transform set")
    if mask is None:
        path = _learned_path(p, hidden, sc)
        lengths = None
    else:
        path, lengths = _learned_path(p, hidden, sc, mask)
    if plan is not None:
        feats = projected_signature(path, plan.words, plan.d, plan=plan,
                                    stream=True,
                                    stream_stride=sc.stream_stride,
                                    backend=sc.backend, backward=sc.backward,
                                    lengths=lengths, transform=spec,
                                    precision=sc.precision)
    else:
        feats = signature(path, sc.depth, stream=True,
                          stream_stride=sc.stream_stride,
                          backend=sc.backend, backward=sc.backward,
                          lengths=lengths, transform=spec,
                          precision=sc.precision)
    # per-step displacement rides along, mirroring the pooled feature layout
    M = path.shape[1] - 1
    steps = jnp.asarray(stream_emit_steps(M, sc.stream_stride))
    if lengths is None:
        disp = jnp.take(path, steps + 1, axis=1) - path[:, :1]
    else:
        # clamp each gather to the example's true end: the true-terminal
        # emission slot may cover past-L steps (identity updates), and the
        # matching displacement must read X_L, not a pad-token projection
        idx = jnp.minimum(steps[None, :] + 1, lengths[:, None])
        disp = jnp.take_along_axis(path, idx[..., None], axis=1) \
            - path[:, :1]
        emit = stream_emit_mask(M, sc.stream_stride, lengths)
        disp = disp * emit[..., None].astype(disp.dtype)
    feats = jnp.concatenate([feats, disp], axis=-1)
    return jnp.einsum("btf,fo->bto", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))


@lru_cache(maxsize=None)
def _kernel_weights(channels: int, depth: int, decay: float):
    """Level-decay gram weights ω_w = decay^{|w|} (host-side, cached)."""
    from repro.sigkernel import word_weights
    lw = tuple(decay ** n for n in range(1, depth + 1))
    return word_weights(channels, depth, level_weights=lw)


def sig_kernel_pool(p, hidden: jax.Array, cfg: ModelConfig,
                    mask=None) -> jax.Array:
    """(B, S, d_model) -> (B, n_out): kernel-feature readout.

    Feature j is the weighted signature-kernel score k_ω(path, landmark_j)
    against the learned landmark bank ``p["landmarks"]`` — computed as one
    tiled Gram (never a (B, L, D_sig) intermediate), normalised to the RKHS
    cosine when ``kernel_normalize``.  The per-path displacement rides along
    exactly like the plain signature head.  ``mask`` makes the scored paths
    ragged (see :func:`sig_pool`).
    """
    from repro.kernels import ops as kops
    from repro.sigkernel import gram_diag
    sc = cfg.sig_head
    if sc.use_logsig:
        raise NotImplementedError(
            "the kernel-feature head scores truncated signatures; "
            "use_logsig=True with kernel_landmarks > 0 is not supported")
    if mask is None:
        path = _learned_path(p, hidden, sc)
        lengths = None
        disp = path[:, -1] - path[:, 0]
    else:
        path, lengths = _learned_path(p, hidden, sc, mask)
        disp = _ragged_disp(path, lengths)
    # the transform applies to query AND landmark paths (same RKHS on both
    # gram legs); the weight table runs over the augmented alphabet
    S = signature(path, sc.depth, backend=sc.backend, backward=sc.backward,
                  lengths=lengths, transform=sc.transform,
                  precision=sc.precision)
    lm = p["landmarks"].astype(jnp.float32)
    S_l = signature(lm, sc.depth, backend=sc.backend, backward=sc.backward,
                    transform=sc.transform, precision=sc.precision)
    w = jnp.asarray(_kernel_weights(_sig_channels(sc), sc.depth,
                                    sc.kernel_level_decay))
    K = kops.gram(S, S_l, w, backend=sc.backend, precision=sc.precision)
    if sc.kernel_normalize:
        # +1 is the empty-word coordinate: keeps near-constant paths finite
        qn = jnp.sqrt(gram_diag(S, w) + 1.0)
        rn = jnp.sqrt(gram_diag(S_l, w) + 1.0)
        K = K / (qn[:, None] * rn[None, :])
    feats = jnp.concatenate([K, disp], axis=-1)
    return jnp.einsum("bf,fo->bo", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))


def sig_pool(p, hidden: jax.Array, cfg: ModelConfig,
             plan: WordPlan | None = None, mask=None) -> jax.Array:
    """(B, S, d_model) -> (B, n_out) sequence-level readout.

    ``mask`` (B, S) is the backbone's right-padded attention mask: the
    signature, displacement and scale normalisation then stop at each
    example's true end (ragged pass-through — padded positions neither
    contribute features nor receive gradient).
    """
    sc = cfg.sig_head
    if sc.kernel_landmarks > 0:
        if plan is not None:
            raise NotImplementedError(
                "the kernel-feature head pools the full truncation; "
                "projected plans are not supported with kernel_landmarks > 0")
        return sig_kernel_pool(p, hidden, cfg, mask=mask)
    if mask is None:
        path = _learned_path(p, hidden, sc)
        lengths = None
        disp = path[:, -1] - path[:, 0]
    else:
        path, lengths = _learned_path(p, hidden, sc, mask)
        disp = _ragged_disp(path, lengths)
    # all three feature routes ride the engine dispatch (repro.kernels.ops):
    # the configured backend's kernel forward + O(1)-in-length backward is
    # exactly the path jax.grad differentiates during training.
    if plan is not None:
        feats = projected_signature(path, plan.words, plan.d, plan=plan,
                                    backend=sc.backend, backward=sc.backward,
                                    lengths=lengths, transform=sc.transform,
                                    precision=sc.precision)
    elif sc.use_logsig:
        if sc.transform is not None:
            raise NotImplementedError(
                "use_logsig=True has no fused-transform route; set "
                "transform=None")
        if lengths is not None:
            raise NotImplementedError(
                "use_logsig=True has no ragged (mask=) route yet; use "
                "use_logsig=False for masked pooling")
        feats = logsignature(path, sc.depth, backend=sc.backend,
                             backward=sc.backward)
    else:
        feats = signature(path, sc.depth, backend=sc.backend,
                          backward=sc.backward, lengths=lengths,
                          transform=sc.transform, precision=sc.precision)
    feats = jnp.concatenate([feats, disp], axis=-1)
    return jnp.einsum("bf,fo->bo", feats.astype(hidden.dtype),
                      p["out"].astype(hidden.dtype))
