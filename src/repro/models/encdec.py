"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, F, d_model).  Everything after the
frontend — sinusoidal positions, bidirectional encoder, causal decoder with
cross-attention — is real and scan-stacked.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.ctx import shard
from .config import ModelConfig
from .layers import (_init, _sdpa, _sdpa_decode, attention, init_attention,
                     init_mlp, mlp, rms_norm)
from .transformer import _remat, logits_fn, layer_scan


def sinusoids(length: int, channels: int) -> np.ndarray:
    t = np.arange(length)[:, None]
    inv = np.exp(-np.log(10000.0) * np.arange(channels // 2) / (channels // 2 - 1))
    ang = t * inv[None]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)

    def enc_one(k):
        k1, k2 = jax.random.split(k)
        return {"ln_attn": jnp.zeros((d,)), "ln_mlp": jnp.zeros((d,)),
                "attn": init_attention(k1, cfg),
                "mlp": init_mlp(k2, d, cfg.d_ff, cfg.act)}

    def dec_one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln_self": jnp.zeros((d,)), "ln_cross": jnp.zeros((d,)),
                "ln_mlp": jnp.zeros((d,)),
                "self_attn": init_attention(k1, cfg),
                "cross_attn": init_attention(k2, cfg),
                "mlp": init_mlp(k3, d, cfg.d_ff, cfg.act)}

    params = {
        "enc_layers": jax.vmap(enc_one)(
            jax.random.split(ks[0], cfg.n_encoder_layers)),
        "dec_layers": jax.vmap(dec_one)(
            jax.random.split(ks[1], cfg.n_layers)),
        "embed": _init(ks[2], (cfg.vocab_size, d), scale=0.02),
        "pos_dec": _init(ks[3], (cfg.decoder_max_len, d), scale=0.02),
        "ln_enc": jnp.zeros((d,)), "ln_f": jnp.zeros((d,)),
    }
    return jax.tree.map(lambda x: x.astype(dtype)
                        if x.dtype == jnp.float32 else x, params)


def _cross_attention(p, x, enc_kv, cfg):
    """x: (B,S,d); enc_kv: precomputed (k, v) each (B, F, H, hd)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, cfg.n_heads, hd)
    k, v = enc_kv
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), causal=False)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))


def cross_kv(p, enc_out, cfg):
    B, F, d = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bfd,dh->bfh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bfd,dh->bfh", enc_out, p["wv"].astype(enc_out.dtype))
    return (k.reshape(B, F, cfg.n_kv_heads, hd),
            v.reshape(B, F, cfg.n_kv_heads, hd))


def encode(params, cfg: ModelConfig, frames, remat: str = "dots"):
    """frames: (B, F, d_model) stub embeddings -> encoder states."""
    B, F, d = frames.shape
    pos = jnp.asarray(sinusoids(F, d), frames.dtype)
    x = shard(frames + pos[None], "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

    def body(p, h):
        a, _ = attention(p["attn"], rms_norm(h, p["ln_attn"], cfg.norm_eps),
                         cfg, positions, causal=False)
        h = h + a
        return h + mlp(p["mlp"], rms_norm(h, p["ln_mlp"], cfg.norm_eps),
                       cfg.act), 0.0

    fn = _remat(body, remat)

    def step(carry, p):
        h, _ = fn(p, carry)
        return h, None

    x, _ = layer_scan(step, x, params["enc_layers"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, enc_out, tokens,
                 remat: str = "dots"):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["pos_dec"][:S][None].astype(x.dtype)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(p, h):
        a, _ = attention(p["self_attn"],
                         rms_norm(h, p["ln_self"], cfg.norm_eps), cfg,
                         positions, causal=True)
        h = h + a
        kv = cross_kv(p["cross_attn"], enc_out, cfg)
        h = h + _cross_attention(p["cross_attn"],
                                 rms_norm(h, p["ln_cross"], cfg.norm_eps),
                                 kv, cfg)
        return h + mlp(p["mlp"], rms_norm(h, p["ln_mlp"], cfg.norm_eps),
                       cfg.act), 0.0

    fn = _remat(body, remat)

    def step(carry, p):
        h, _ = fn(p, carry)
        return h, None

    x, _ = layer_scan(step, x, params["dec_layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def lm_loss(params, cfg: ModelConfig, batch, remat: str = "dots"):
    enc = encode(params, cfg, batch["frames"], remat=remat)
    hidden = decode_train(params, cfg, enc, batch["tokens"], remat=remat)
    logits = jnp.einsum("bsd,vd->bsv", hidden,  # tied output embedding
                        params["embed"].astype(hidden.dtype)).astype(jnp.float32)
    labels = batch["labels"]
    valid = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    ntok = jnp.maximum(jnp.sum(valid), 1.0)
    loss = jnp.sum(nll * valid) / ntok
    return loss, {"loss": loss, "ntok": ntok}


# ---------------------------------------------------------------------------
# serving: decode one token against precomputed cross-KV
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, n_frames: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "cross_k": jnp.zeros((L, B, n_frames, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((L, B, n_frames, cfg.n_kv_heads, hd), dtype),
        "self_k": jnp.zeros((L, B, cfg.decoder_max_len, cfg.n_kv_heads, hd), dtype),
        "self_v": jnp.zeros((L, B, cfg.decoder_max_len, cfg.n_kv_heads, hd), dtype),
        "index": jnp.zeros((L,), jnp.int32),
    }


def prefill_cross(params, cfg, enc_out, cache):
    """Precompute per-layer cross K/V from encoder output into the cache."""
    def one(p):
        return cross_kv(p["cross_attn"], enc_out, cfg)
    ks, vs = jax.vmap(one)(params["dec_layers"])  # vmapped over layers? params stacked
    return dict(cache, cross_k=ks.astype(cache["cross_k"].dtype),
                cross_v=vs.astype(cache["cross_v"].dtype))


def decode_step(params, cfg: ModelConfig, tokens, cache):
    """tokens (B,1) -> (logits, cache).  Cross-KV must be prefilled."""
    B, S = tokens.shape
    idx = cache["index"][0]
    x = jnp.take(params["embed"], tokens, axis=0)
    pos_emb = jax.lax.dynamic_slice_in_dim(params["pos_dec"], idx,
                                           1, axis=0)
    x = x + pos_emb[None].astype(x.dtype)
    positions = jnp.broadcast_to(idx + jnp.arange(S)[None], (B, S))

    def step(h, inp):
        p, ck, cv, sk, sv, li = inp
        a, new_kv = attention(
            p["self_attn"], rms_norm(h, p["ln_self"], cfg.norm_eps), cfg,
            positions, cache={"k": sk, "v": sv, "index": li})
        h = h + a
        h = h + _cross_attention(
            p["cross_attn"], rms_norm(h, p["ln_cross"], cfg.norm_eps),
            (ck, cv), cfg)
        h = h + mlp(p["mlp"], rms_norm(h, p["ln_mlp"], cfg.norm_eps), cfg.act)
        return h, (new_kv["k"], new_kv["v"], new_kv["index"])

    x, (nk, nv, ni) = layer_scan(
        step, x, (params["dec_layers"], cache["cross_k"], cache["cross_v"],
                  cache["self_k"], cache["self_v"], cache["index"]))
    hidden = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", hidden,
                        params["embed"].astype(hidden.dtype))
    new_cache = dict(cache, self_k=nk, self_v=nv, index=ni)
    return logits.astype(jnp.float32), new_cache
