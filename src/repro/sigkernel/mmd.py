"""Signature maximum mean discrepancy (two-sample statistic + training loss).

MMD²_ω(P, Q) = E k_ω(x, x') + E k_ω(y, y') − 2 E k_ω(x, y) with the weighted
signature kernel of :mod:`repro.sigkernel.gram`.  The unbiased estimator
drops the diagonal of the within-sample Grams (Gretton et al.'s U-statistic),
so it can be slightly negative under H0 — that is expected.

Everything is differentiable end to end: the signature legs ride the engine
dispatch (§4.2 inverse VJP on any backend) and the Gram product has a
closed-form VJP, so ``jax.grad`` of the statistic w.r.t. either sample's
paths works on ``backend="jax"`` and the pallas backends alike.  The trainer
exposes it as a distribution-matching loss via ``TrainLoopConfig.loss =
"sig_mmd"`` (:mod:`repro.train.trainer`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gram import (gram_from_signatures, resolve_weights, signature_features,
                   unpack_ragged)


def mmd_from_signatures(Sx: jax.Array, Sy: jax.Array, weights: jax.Array, *,
                        unbiased: bool = True, route: str = "auto",
                        backend: str = "auto",
                        block_words: int = 512) -> jax.Array:
    """MMD² from precomputed signature coordinate matrices (B_x, D), (B_y, D)."""
    m, n = Sx.shape[0], Sy.shape[0]
    kw = dict(route=route, backend=backend, block_words=block_words)
    Kxx = gram_from_signatures(Sx, Sx, weights, **kw)
    Kyy = gram_from_signatures(Sy, Sy, weights, **kw)
    Kxy = gram_from_signatures(Sx, Sy, weights, **kw)
    if unbiased:
        if m < 2 or n < 2:
            raise ValueError(
                f"the unbiased MMD needs >= 2 samples per side, got {m}, {n}")
        sxx = (Kxx.sum() - jnp.trace(Kxx)) / (m * (m - 1))
        syy = (Kyy.sum() - jnp.trace(Kyy)) / (n * (n - 1))
    else:
        sxx = Kxx.mean()
        syy = Kyy.mean()
    return sxx + syy - 2.0 * Kxy.mean()


def sig_mmd(x: jax.Array, y: jax.Array, depth: int | None = None, *,
            words=None, weights=None, level_weights=None, gamma=None,
            unbiased: bool = True, route: str = "auto",
            backend: str = "auto", backward: str = "inverse",
            block_words: int = 512, x_lengths=None,
            y_lengths=None) -> jax.Array:
    """Signature-MMD² between two path samples x (B_x, M+1, d), y (B_y, M'+1, d).

    Kernel configuration matches :func:`repro.sigkernel.sig_gram` (depth or
    word set, plus weights / level_weights / gamma).  Returns a scalar;
    differentiable w.r.t. both path batches (and explicit ``weights``).
    ``x_lengths`` / ``y_lengths`` (or :class:`repro.ragged.RaggedPaths`
    samples) make either side ragged — the statistic compares the TRUE
    variable-length paths, with zero gradient past each example's end.
    """
    x, x_lengths = unpack_ragged(x, x_lengths)
    plan, w = resolve_weights(x.shape[-1], depth, words,
                              weights, level_weights, gamma)
    Sx = signature_features(x, depth, words=plan, backend=backend,
                            backward=backward, lengths=x_lengths)
    Sy = signature_features(y, depth, words=plan, backend=backend,
                            backward=backward, lengths=y_lengths)
    return mmd_from_signatures(Sx, Sy, w, unbiased=unbiased, route=route,
                               backend=backend, block_words=block_words)
