"""Weighted / projected signature Gram matrices (kernel-method front end).

pathsig computes signatures directly in the word basis, so the truncated
signature kernel is a *weighted inner product over word coordinates*:

    k_ω(x, y) = Σ_{w ∈ I} ω_w ⟨S(x), w⟩ ⟨S(y), w⟩  =  (S_x diag(ω) S_yᵀ)_{xy}

which makes projected word sets I (paper §7.1) and anisotropic level weights
(paper §7.2 / Def. 7.1) kernel *hyperparameters* for free: restrict I and
you restrict the RKHS; scale channel i by γ_i and every word coordinate picks
up Π γ_{w_j}.  This module builds the weight vectors, computes the signature
legs through the engine dispatch (so they carry the §4.2 inverse VJP on any
backend), and routes the Gram product either through the naive oracle
``S_x @ diag(ω) @ S_yᵀ`` or through the tiled word-blocked route
(:func:`repro.kernels.ops.gram`) that never materialises the
(B_x, B_y, D_sig) intermediate.

Multi-device: under an installed ``sharding_ctx(mesh)`` the signature legs
batch-shard over the mesh and the tiled route becomes the cross-device
``ppermute`` ring of ``repro.kernels.ops`` — (B_x/P, B_y/P) tiles,
O(B·D_sig) communication, never a replicated Gram-sized intermediate.
``sig_mmd``, ``krr`` and the feature maps ride it unchanged (they all go
through :func:`gram_from_signatures`); ``route="oracle"`` stays the naive
single-device reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tensor_ops as tops
from repro.core.words import WordPlan, all_words, make_plan, sig_dim
from repro.kernels import ops

ROUTES = ("auto", "oracle", "tiled")


def word_weights(d: int | None = None, depth: int | None = None, *,
                 words=None, level_weights=None, gamma=None,
                 dtype=np.float32) -> np.ndarray:
    """The coordinate weight vector ω over a word basis (host-side).

    - ``words=None``: ω over the full truncation W_{<=N} in level-major
      order (matching the flat signature layout); needs ``d`` and ``depth``.
    - ``level_weights``: sequence (λ_1, ..., λ_N); ω_w *= λ_{|w|} — uniform
      per-level reweighting (e.g. λ_n = λ^n signature scaling).
    - ``gamma``: per-channel weights (γ_0, ..., γ_{d-1}), strictly positive;
      ω_w *= Π_j γ_{w_j} — the anisotropic kernel of paper §7.2 (scaling
      channel i of the *path* by √γ_i is the same reweighting).
    """
    if words is None:
        if d is None or depth is None:
            raise ValueError("word_weights needs either words= or (d, depth)")
        words = all_words(d, depth)
    words = [tuple(w) for w in words]
    if any(len(word) == 0 for word in words):
        raise ValueError("the empty word is implicit (its coordinate is the "
                         "constant 1); remove it from the word set")
    w = np.ones(len(words), dtype)
    if level_weights is not None:
        lw = np.asarray(level_weights, dtype)
        top = max((len(word) for word in words), default=0)
        if lw.ndim != 1 or len(lw) < top:
            raise ValueError(f"level_weights needs one entry per level "
                             f"1..{top}, got shape {lw.shape}")
        w *= lw[np.array([len(word) - 1 for word in words], dtype=np.intp)]
    if gamma is not None:
        g = np.asarray(gamma, dtype)
        if (g <= 0).any():
            raise ValueError("anisotropic weights must be strictly positive")
        for i, word in enumerate(words):
            w[i] *= np.prod(g[list(word)])
    return w


def _as_plan(words, d: int) -> WordPlan:
    if isinstance(words, WordPlan):
        return words
    return make_plan(tuple(tuple(w) for w in words), d)


def unpack_ragged(paths, lengths=None):
    """(RaggedPaths | array, lengths-or-None) -> (values, lengths-or-None);
    explicit ``lengths`` wins over the container's.  Thin wrapper over the
    core protocol helper so there is ONE definition of what counts as a
    ragged container."""
    from repro.core.signature import _unpack_ragged
    values, rl = _unpack_ragged(paths)
    if rl is not None:
        return values, (rl if lengths is None else lengths)
    return jnp.asarray(values), lengths


def signature_features(paths: jax.Array, depth: int | None = None, *,
                       words=None, backend: str = "auto",
                       backward: str = "inverse",
                       lengths=None) -> jax.Array:
    """The Gram legs: (B, M+1, d) paths -> (B, |I|) signature coordinates.

    ``words=None`` gives the full truncation (needs ``depth``); otherwise the
    projected coordinates of the word set / plan.  Routed through the engine
    dispatch, so the result is differentiable with the §4.2 inverse VJP on
    every backend.  ``lengths`` (B,) makes the batch ragged (exact
    zero-masked padding; a :class:`repro.ragged.RaggedPaths` may be passed
    directly as ``paths``).
    """
    paths, lengths = unpack_ragged(paths, lengths)
    if paths.ndim != 3:
        raise ValueError(f"expected batched paths (B, M+1, d), "
                         f"got {paths.shape}")
    incs = tops.path_increments(paths)
    if words is not None:
        plan = _as_plan(words, paths.shape[-1])
        return ops.projected(incs, plan, backend=backend, backward=backward,
                             lengths=lengths)
    if depth is None:
        raise ValueError("signature_features needs depth= or words=")
    return ops.signature(incs, depth, backend=backend, backward=backward,
                         lengths=lengths)


def resolve_weights(paths_d: int, depth: int | None, words, weights,
                    level_weights, gamma) -> tuple[WordPlan | None, jax.Array]:
    """-> (plan-or-None, ω) shared by gram / mmd / features / krr."""
    plan = _as_plan(words, paths_d) if words is not None else None
    if plan is None and depth is None:
        raise ValueError("need depth= (full truncation) or words=")
    if weights is not None:
        w = jnp.asarray(weights)
        if level_weights is not None or gamma is not None:
            raise ValueError("pass either explicit weights= or "
                             "level_weights=/gamma=, not both")
        n = len(plan.words) if plan is not None else sig_dim(paths_d, depth)
        if w.shape != (n,):
            raise ValueError(f"weights shape {w.shape} != ({n},) — one "
                             "weight per word coordinate")
        return plan, w
    wv = word_weights(paths_d, depth,
                      words=plan.words if plan is not None else None,
                      level_weights=level_weights, gamma=gamma)
    return plan, jnp.asarray(wv)


def gram_from_signatures(Sx: jax.Array, Sy: jax.Array, weights: jax.Array, *,
                         route: str = "auto", backend: str = "auto",
                         block_words: int = 512) -> jax.Array:
    """(B_x, D), (B_y, D), (D,) -> (B_x, B_y) weighted Gram, routed."""
    if route not in ROUTES:
        raise ValueError(f"unknown route {route!r}; expected one of {ROUTES}")
    if route == "oracle":
        # the naive reference: S_x @ diag(ω) @ S_yᵀ in one matmul
        return (Sx * weights[None, :]) @ Sy.T
    return ops.gram(Sx, Sy, weights, backend=backend,
                    block_words=block_words)


def sig_gram(x: jax.Array, y: jax.Array | None = None,
             depth: int | None = None, *, words=None, weights=None,
             level_weights=None, gamma=None, route: str = "auto",
             backend: str = "auto", backward: str = "inverse",
             block_words: int = 512, x_lengths=None,
             y_lengths=None) -> jax.Array:
    """Batched signature Gram matrix K[i, j] = k_ω(x_i, y_j).

    x: (B_x, M+1, d) paths; y: (B_y, M'+1, d) paths or None (symmetric Gram
    of x with itself, signatures computed once).  The kernel is configured by
    ``depth`` (full truncation) or ``words`` (projected set), plus
    ``weights`` / ``level_weights`` / ``gamma`` (see :func:`word_weights`).

    ``route="oracle"`` is the naive ``S_x @ diag(ω) @ S_yᵀ`` reference;
    ``"tiled"`` (= ``"auto"``) blocks over the word axis through the engine
    dispatch so peak live memory is O(B_x·B_y + B·block_words).  Fully
    differentiable: the signature legs carry the §4.2 inverse VJP of the
    chosen ``backend``/``backward`` and the product has a closed-form VJP.

    ``x_lengths`` / ``y_lengths`` make either path batch ragged — the legs
    are computed with exact zero-masked padding, so the Gram of a padded
    batch IS the Gram of the unpadded paths.  Either argument may also ride
    in as a :class:`repro.ragged.RaggedPaths`.
    """
    x, x_lengths = unpack_ragged(x, x_lengths)
    plan, w = resolve_weights(x.shape[-1], depth, words,
                              weights, level_weights, gamma)
    Sx = signature_features(x, depth, words=plan, backend=backend,
                            backward=backward, lengths=x_lengths)
    Sy = Sx if y is None else signature_features(
        y, depth, words=plan, backend=backend, backward=backward,
        lengths=y_lengths)
    return gram_from_signatures(Sx, Sy, w, route=route, backend=backend,
                                block_words=block_words)


def gram_diag(S: jax.Array, weights: jax.Array) -> jax.Array:
    """(B, D) -> (B,) the Gram diagonal k_ω(x, x) = Σ_k ω_k S_k², without
    forming the full matrix — the normaliser for RKHS cosine scores."""
    return ((S * S) * weights[None, :]).sum(axis=-1)
