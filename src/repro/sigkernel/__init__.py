"""repro.sigkernel: signature kernel methods as a first-class subsystem.

The truncated signature kernel k_ω(x, y) = Σ_w ω_w ⟨S(x), w⟩⟨S(y), w⟩ is a
weighted inner product over word coordinates — exactly the representation the
word-basis engines compute.  This package layers kernel-method workloads on
the engine dispatch: weighted/projected Gram matrices (:mod:`gram`), the
signature-MMD two-sample statistic / training loss (:mod:`mmd`), low-rank
feature maps (:mod:`features`), and kernel ridge regression + reference
scoring for serving (:mod:`krr`).
"""
from .gram import (gram_diag, gram_from_signatures, resolve_weights,
                   sig_gram, signature_features, word_weights)
from .mmd import mmd_from_signatures, sig_mmd
from .features import (NystromFeatures, WordSubsetFeatures, nystrom_features,
                       random_word_features)
from .krr import (SigKRR, fit_sig_krr, krr_fit, krr_predict,
                  reference_scores)

__all__ = [
    "sig_gram", "gram_from_signatures", "gram_diag", "signature_features",
    "word_weights", "resolve_weights", "sig_mmd", "mmd_from_signatures",
    "WordSubsetFeatures", "random_word_features", "NystromFeatures",
    "nystrom_features", "SigKRR", "fit_sig_krr", "krr_fit", "krr_predict",
    "reference_scores",
]
