"""Low-rank feature maps for the signature kernel: kernel methods at O(B).

Exact Gram matrices cost O(B²) kernel evaluations; both maps here give
explicit features φ with φ(x)·φ(y) ≈ k_ω(x, y), so downstream methods
(linear models, MMD via feature means, retrieval) scale linearly in batch:

- :func:`random_word_features` — sample n word coordinates from W_{<=N} and
  ride the projected-signature engine (``core/projection.py`` through the
  dispatch): an unbiased Monte-Carlo estimate of the weighted inner product,
  exact when every word is kept.  The paper's word projections *are* the
  feature map — no extra kernel machinery needed.
- :func:`nystrom_features` — Nyström landmarks: φ(x) = K_xm (K_mm)^{-½},
  exact on the span of the landmark signatures.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tensor_ops as tops
from repro.core.words import WordPlan, all_words, make_plan
from repro.kernels import ops
from .gram import gram_from_signatures, resolve_weights, signature_features, \
    word_weights


@dataclasses.dataclass(frozen=True)
class WordSubsetFeatures:
    """Feature map φ(x)_k = scale_k · ⟨S(x), w_k⟩ over a sampled word set."""
    plan: WordPlan
    scale: jax.Array           # (n_features,)
    backend: str = "auto"
    backward: str = "inverse"

    @property
    def n_features(self) -> int:
        return len(self.plan.words)

    def __call__(self, paths: jax.Array) -> jax.Array:
        paths = jnp.asarray(paths)
        coords = ops.projected(tops.path_increments(paths), self.plan,
                               backend=self.backend, backward=self.backward)
        return coords * self.scale[None, :]


def random_word_features(d: int, depth: int, n_features: int, *,
                         seed: int = 0, level_weights=None, gamma=None,
                         backend: str = "auto",
                         backward: str = "inverse") -> WordSubsetFeatures:
    """Uniform word-subset projection features for k_ω on W_{<=N}.

    Samples ``n_features`` words without replacement (host-side, seeded) and
    scales coordinate k by sqrt(ω_k · D/n) so that E[φ(x)·φ(y)] = k_ω(x, y).
    ``n_features >= D_sig`` keeps every word — the map is then exact.
    """
    vocab = all_words(d, depth)
    D = len(vocab)
    w = word_weights(words=vocab, level_weights=level_weights, gamma=gamma)
    if n_features < 1:
        raise ValueError(f"n_features must be >= 1, got {n_features}")
    if n_features >= D:
        idx = np.arange(D)
    else:
        idx = np.sort(np.random.default_rng(seed).choice(
            D, size=n_features, replace=False))
    words = tuple(vocab[i] for i in idx)
    scale = np.sqrt(w[idx] * (D / len(idx))).astype(np.float32)
    return WordSubsetFeatures(plan=make_plan(words, d),
                              scale=jnp.asarray(scale), backend=backend,
                              backward=backward)


@dataclasses.dataclass(frozen=True)
class NystromFeatures:
    """φ(x) = k_ω(x, landmarks) · (K_mm)^{-½}: rank-m kernel features."""
    landmark_sigs: jax.Array   # (m, D_I) signature coordinates
    transform: jax.Array       # (m, m) = U diag(s^{-½}) Uᵀ-style map
    weights: jax.Array         # (D_I,)
    depth: int | None
    plan: WordPlan | None
    backend: str = "auto"
    backward: str = "inverse"
    block_words: int = 512

    @property
    def n_features(self) -> int:
        return self.transform.shape[1]

    def __call__(self, paths: jax.Array) -> jax.Array:
        S = signature_features(jnp.asarray(paths), self.depth,
                               words=self.plan, backend=self.backend,
                               backward=self.backward)
        Kxm = gram_from_signatures(S, self.landmark_sigs, self.weights,
                                   backend=self.backend,
                                   block_words=self.block_words)
        return Kxm @ self.transform


def nystrom_features(landmarks: jax.Array, depth: int | None = None, *,
                     words=None, weights=None, level_weights=None, gamma=None,
                     rel_tol: float = 1e-10, backend: str = "auto",
                     backward: str = "inverse",
                     block_words: int = 512) -> NystromFeatures:
    """Fit a Nyström feature map from landmark paths (m, M+1, d).

    Eigendecomposes the (m, m) landmark Gram; eigendirections below
    ``rel_tol`` · λ_max are zeroed (pseudo-inverse), keeping shapes static.
    φ(x)·φ(y) = K_xm (K_mm)⁺ K_my — exact whenever x, y are landmarks.
    """
    landmarks = jnp.asarray(landmarks)
    plan, w = resolve_weights(landmarks.shape[-1], depth, words, weights,
                              level_weights, gamma)
    S_m = signature_features(landmarks, depth, words=plan, backend=backend,
                             backward=backward)
    K = gram_from_signatures(S_m, S_m, w, backend=backend,
                             block_words=block_words)
    s, U = jnp.linalg.eigh(K)                      # ascending eigenvalues
    good = s > jnp.maximum(s[-1], 0.0) * rel_tol
    inv_sqrt = jnp.where(good, 1.0 / jnp.sqrt(jnp.where(good, s, 1.0)), 0.0)
    return NystromFeatures(landmark_sigs=S_m, transform=U * inv_sqrt[None, :],
                           weights=w, depth=depth, plan=plan, backend=backend,
                           backward=backward, block_words=block_words)
