"""Kernel ridge regression and reference scoring on signature Grams.

The serving-shaped kernel methods: fit once against a reference set (solve
the regularised Gram system), then score / predict incoming paths with one
(B, R) cross-Gram per batch — which is exactly what
:class:`repro.serve.engine.SigScoreEngine` runs online from
``SignatureStream`` terminal states.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.words import WordPlan
from .gram import (gram_diag, gram_from_signatures, resolve_weights,
                   signature_features)


def krr_fit(K: jax.Array, targets: jax.Array, reg: float = 1e-3) -> jax.Array:
    """Solve (K + reg·I) α = y on an (m, m) Gram.  targets: (m,) or (m, p)."""
    m = K.shape[0]
    if K.shape != (m, m):
        raise ValueError(f"K must be square, got {K.shape}")
    if targets.shape[0] != m:
        raise ValueError(f"targets rows {targets.shape[0]} != Gram size {m}")
    return jnp.linalg.solve(K + reg * jnp.eye(m, dtype=K.dtype),
                            targets.astype(K.dtype))


def krr_predict(K_query_ref: jax.Array, alpha: jax.Array) -> jax.Array:
    """(B, m) cross-Gram × (m[, p]) dual coefficients -> (B[, p]) predictions."""
    return K_query_ref @ alpha


def reference_scores(S_query: jax.Array, S_ref: jax.Array,
                     weights: jax.Array, *, normalize: bool = True,
                     backend: str = "auto", block_words: int = 512,
                     eps: float = 1e-12) -> jax.Array:
    """(B, D) query signatures vs (R, D) references -> (B, R) kernel scores.

    ``normalize=True`` returns the RKHS cosine
    k(x, r) / sqrt(k(x, x) k(r, r)) — scale-free retrieval scores.
    """
    K = gram_from_signatures(S_query, S_ref, weights, backend=backend,
                             block_words=block_words)
    if not normalize:
        return K
    qn = jnp.sqrt(jnp.maximum(gram_diag(S_query, weights), eps))
    rn = jnp.sqrt(jnp.maximum(gram_diag(S_ref, weights), eps))
    return K / (qn[:, None] * rn[None, :])


@dataclasses.dataclass(frozen=True)
class SigKRR:
    """A fitted signature kernel ridge regressor (reference sigs + duals)."""
    ref_sigs: jax.Array        # (m, D_I)
    alpha: jax.Array           # (m,) or (m, p)
    weights: jax.Array         # (D_I,)
    depth: int | None
    plan: WordPlan | None
    reg: float
    backend: str = "auto"
    backward: str = "inverse"
    block_words: int = 512

    def predict(self, paths: jax.Array) -> jax.Array:
        """(B, M+1, d) paths -> (B[, p]) predictions."""
        S = signature_features(jnp.asarray(paths), self.depth,
                               words=self.plan, backend=self.backend,
                               backward=self.backward)
        K = gram_from_signatures(S, self.ref_sigs, self.weights,
                                 backend=self.backend,
                                 block_words=self.block_words)
        return krr_predict(K, self.alpha)

    def scores(self, paths: jax.Array, *, normalize: bool = True) -> jax.Array:
        """(B, M+1, d) paths -> (B, m) kernel scores against the references."""
        S = signature_features(jnp.asarray(paths), self.depth,
                               words=self.plan, backend=self.backend,
                               backward=self.backward)
        return reference_scores(S, self.ref_sigs, self.weights,
                                normalize=normalize, backend=self.backend,
                                block_words=self.block_words)


def fit_sig_krr(paths: jax.Array, targets: jax.Array,
                depth: int | None = None, *, words=None, weights=None,
                level_weights=None, gamma=None, reg: float = 1e-3,
                backend: str = "auto", backward: str = "inverse",
                block_words: int = 512) -> SigKRR:
    """Fit KRR on reference paths (m, M+1, d) with targets (m,) or (m, p)."""
    paths = jnp.asarray(paths)
    plan, w = resolve_weights(paths.shape[-1], depth, words, weights,
                              level_weights, gamma)
    S = signature_features(paths, depth, words=plan, backend=backend,
                           backward=backward)
    K = gram_from_signatures(S, S, w, backend=backend,
                             block_words=block_words)
    alpha = krr_fit(K, jnp.asarray(targets), reg)
    return SigKRR(ref_sigs=S, alpha=alpha, weights=w, depth=depth, plan=plan,
                  reg=reg, backend=backend, backward=backward,
                  block_words=block_words)
