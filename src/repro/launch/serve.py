"""Production serving launcher: batched decode against a KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --steps 32

Production meshes are validated compile-only via launch/dryrun.py (decode_32k
and long_500k cells lower exactly this serve_step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.models as M
from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config, reduce_config
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32,
                    help="new tokens per sequence")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--ckpt-dir", default="",
                    help="restore params from a training checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        step = latest_step(args.ckpt_dir)
        # restore params only; optimizer state is discarded for serving
        from repro.optim import sgd
        params, _, _ = ck.restore(params, sgd().init(params), step)
        print(f"[serve] restored params from step {step}")

    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"family={cfg.family}, batch={args.batch}")
    engine = ServeEngine(cfg, params, max_len=args.max_len,
                         temperature=args.temperature)
    rng = jax.random.PRNGKey(args.seed)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 1, cfg.vocab_size, jnp.int32)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.steps, rng=rng)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.steps
    print(f"[serve] {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
