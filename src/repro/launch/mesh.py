"""Production mesh construction (see MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (uses however many devices exist).

    Validates the device count up front — ``jax.make_mesh`` with too few
    devices otherwise surfaces as an opaque XLA reshape failure.
    """
    n = len(jax.devices())
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got data={data}, "
                         f"model={model}")
    if data * model > n:
        raise ValueError(
            f"make_dev_mesh(data={data}, model={model}) needs "
            f"{data * model} devices but only {n} are visible — launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data * model}"
            f" (CPU) or shrink the mesh")
    return jax.make_mesh((data, model), ("data", "model"))


def make_sig_mesh(batch: int | None = None):
    """1-axis mesh for the signature stack: install it with
    ``sharding_ctx(make_sig_mesh())`` and every entry point in
    ``repro.kernels.ops`` shards the "batch" logical axis over it (the
    default rules map "batch" onto the 'data' axis).

    ``batch=None`` uses every visible device.
    """
    n = len(jax.devices())
    if batch is None:
        batch = n
    if batch < 1:
        raise ValueError(f"batch axis must be >= 1, got {batch}")
    if batch > n:
        raise ValueError(
            f"make_sig_mesh(batch={batch}) needs {batch} devices but only "
            f"{n} are visible — launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={batch} (CPU)"
            f" or shrink the axis")
    return jax.make_mesh((batch,), ("data",))
