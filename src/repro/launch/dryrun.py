import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only this launcher sees 512 placeholder devices; tests/benches see 1.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.models as M  # noqa: E402
from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.distributed.ctx import sharding_ctx  # noqa: E402
from repro.distributed.hlo import collective_stats, remat_duplication  # noqa: E402
from repro.distributed.sharding import (batch_specs, cache_specs,  # noqa: E402
                                        opt_state_specs, param_specs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.optim import adafactor, adamw  # noqa: E402
from repro.serve import make_prefill_step, make_serve_step  # noqa: E402
from repro.train import make_train_step  # noqa: E402

# v5e hardware model (roofline constants)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 2 * 50e9          # bytes/s / chip (bidirectional ring per axis)


def rules_for(arch: str, shape: str, overrides: dict | None = None) -> dict:
    rules: dict = {}
    cfg = get_config(arch)
    kind = SP.SHAPES.get(shape, {}).get("kind")
    # NB (§Perf cell A it3/it4, REFUTED): turning dense TP off for MoE and
    # sharding tokens over 'model' replicates dense compute (it3, t_comp
    # 0.67->3.1s) or forces remat'd dispatch one-hots to reshard (it4,
    # t_coll 2.6->3.2s).  Megatron TP for the dense parts + EP stays.
    if (not cfg.moe) and kind in ("train", "prefill") and \
            cfg.param_count() <= 60e9:
        # §Perf cell C, generalized: models far narrower than the mesh are
        # collective-bound under 16-way TP (every projection's bwd gathers
        # its ~268MB input).  Pure DP over all 256 chips + ZeRO-3 over both
        # axes: per-layer weight gathers are small and overlap with compute.
        # Tokens must shard over 'model' too or dense compute replicates:
        # batch when divisible (train), else the sequence axis (prefill).
        rules.update({"heads": None, "kv_heads": None, "ff": None,
                      "fsdp": ("data", "model")})
        if SP.SHAPES[shape]["batch"] % 256 == 0:
            rules["batch"] = ("data", "model")
        else:
            rules["seq"] = "model"
    if kind == "decode":
        # weight-stationary decode (§Perf cell B): no FSDP re-gather of the
        # params every token, KV cache sharded over 'model' on the sequence
        # axis (softmax/PV reductions over the sharded axis become tiny
        # partial-sum all-reduces under SPMD).  State-cache families
        # (hybrid/rwkv) keep kv_seq unsharded: their caches are recurrent
        # states, and seq-sharding the two zamba shared-attn KV blocks
        # forces a per-step cache reshard (measured 0.026 -> 0.199s; with
        # kv_seq=None it is 0.00034s).
        rules.update({"fsdp": None})
        if cfg.family not in ("hybrid", "rwkv"):
            rules.update({"kv_seq": "model"})
    if shape == "long_500k" and cfg.family not in ("hybrid", "rwkv"):
        # context parallelism: B=1 cells shard the KV/state seq over BOTH
        # axes ('data' carries no batch when B=1).  hybrid/rwkv long-context
        # state is O(1) in seq — the decode rules above already apply.
        rules.update({"kv_seq": ("data", "model"), "batch": ("pod",)})
    if overrides:
        rules.update(overrides)
    return rules


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               opt_name: str = "adafactor", remat: str = "dots",
               rule_overrides: dict | None = None, mesh=None,
               keep_hlo: bool = False):
    """Lower + compile one (arch × shape × mesh) cell.  Returns result dict."""
    cfg = get_config(arch)
    ok, why = SP.cell_is_runnable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": why}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(arch, shape, rule_overrides)
    kind = SP.SHAPES[shape]["kind"]
    t0 = time.time()

    with sharding_ctx(mesh, rules):
        params_sds = SP.params_specs_for(cfg)
        p_specs = param_specs(params_sds, mesh, rules)
        if kind == "train":
            opt = adafactor() if opt_name == "adafactor" else adamw()
            opt_sds = SP.opt_state_specs_for(opt, params_sds)
            o_specs = opt_state_specs(opt_sds, p_specs, mesh)
            batch_sds = SP.batch_specs_for(cfg, shape)
            b_specs = batch_specs(batch_sds, mesh, rules)
            step = make_train_step(cfg, opt, remat=remat)
            jitted = jax.jit(step, in_shardings=(p_specs, o_specs, b_specs),
                             out_shardings=(p_specs, o_specs, None))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif kind == "prefill":
            batch_sds = SP.batch_specs_for(cfg, shape)
            b_specs = batch_specs(batch_sds, mesh, rules)
            step = make_prefill_step(cfg, remat=remat)
            jitted = jax.jit(step, in_shardings=(p_specs, b_specs),
                             out_shardings=None)
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            tok_sds, cache_sds, rng_sds = SP.decode_inputs_for(cfg, shape)
            c_specs = cache_specs(cache_sds, mesh, rules)
            t_specs = batch_specs({"tokens": tok_sds}, mesh, rules)["tokens"]
            step = make_serve_step(cfg)
            # cache is donated (aliased in/out) exactly as in production
            # decode loops — without it every step double-buffers the cache
            jitted = jax.jit(step, in_shardings=(p_specs, c_specs, t_specs,
                                                 None),
                             out_shardings=(None, c_specs),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds, rng_sds)
        compiled = lowered.compile()

    t_compile = time.time() - t0
    n_dev = mesh.devices.size
    ca = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo, default_group=n_dev)

    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    # cost_analysis on the SPMD-partitioned module is per-device
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll.total_wire_bytes / ICI_BW
    model_flops = SP.flops_estimate(cfg, shape)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "axes": list(mesh.axis_names), "devices": n_dev,
        "compile_s": round(t_compile, 1),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_accessed,
        "collective_wire_bytes_per_dev": coll.total_wire_bytes,
        "collectives": {k: {"count": v[0], "result_bytes": v[1],
                            "wire_bytes": v[2]}
                        for k, v in coll.by_kind.items()},
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(flops * n_dev, 1.0),
        "remat_dot_duplication": remat_duplication(hlo),
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        },
        "opt": opt_name if kind == "train" else None,
        "remat": remat if kind != "decode" else None,
        "rules": {k: str(v) for k, v in rules.items()},
    }
    if keep_hlo:
        result["hlo_text"] = hlo
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run launcher")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SP.SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each cell")
    ap.add_argument("--opt", default="adafactor",
                    choices=["adafactor", "adamw"])
    ap.add_argument("--remat", default="dots",
                    choices=["dots", "full", "none"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SP.SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                try:
                    res = lower_cell(arch, shape, multi_pod=mp,
                                     opt_name=args.opt, remat=args.remat)
                except Exception as e:  # a dry-run failure is a bug: report it
                    n_fail += 1
                    res = {"arch": arch, "shape": shape, "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {tag}: {e}")
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=2)
                if "error" not in res:
                    if res.get("skipped"):
                        print(f"[SKIP] {tag}: {res['skipped']}")
                    else:
                        print(f"[OK]   {tag} compile={res['compile_s']}s "
                              f"dom={res['dominant']} "
                              f"tc={res['t_compute_s']:.3e} "
                              f"tm={res['t_memory_s']:.3e} "
                              f"tx={res['t_collective_s']:.3e}")
                        if args.verbose:
                            print(json.dumps(res, indent=2))
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
