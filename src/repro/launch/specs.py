"""Input / state specs for every (architecture × shape) dry-run cell.

Everything is ShapeDtypeStruct-based (jax.eval_shape): the 405B configs are
lowered and compiled without a single real allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro.configs import get_config
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,   batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,  batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,  batch=128),
    "long_500k":   dict(kind="decode",  seq=524288, batch=1),
}

# long_500k needs sub-quadratic sequence mixing (DESIGN.md §4: skips)
LONG_OK = {"zamba2-7b", "rwkv6-1.6b"}


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md §4)"
    return True, ""


def all_cells():
    for arch in __import__("repro.configs", fromlist=["ARCH_IDS"]).ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_for(cfg: ModelConfig, shape_name: str) -> dict:
    """Training / prefill batch as ShapeDtypeStructs."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    i32, bf16 = jnp.int32, jnp.bfloat16
    if cfg.family == "encdec":
        # seq axis = encoder frames (stub frontend); decoder length fixed
        return {"frames": _sds((B, S, cfg.d_model), bf16),
                "tokens": _sds((B, cfg.decoder_max_len), i32),
                "labels": _sds((B, cfg.decoder_max_len), i32)}
    if cfg.rope_type == "mrope":
        return {"embeds": _sds((B, S, cfg.d_model), bf16),
                "positions": _sds((3, B, S), i32),
                "labels": _sds((B, S), i32)}
    return {"tokens": _sds((B, S), i32), "labels": _sds((B, S), i32)}


def decode_inputs_for(cfg: ModelConfig, shape_name: str):
    """(tokens, cache) ShapeDtypeStructs for a serve_step cell.

    The cache length is rounded up to a multiple of 512 so the kv_seq axis
    is cleanly divisible by any mesh-axis product (16, 256, 512) — uneven
    shardings get silently dropped by the divisibility guard and the cache
    then fails to fit in HBM (§Perf cell B).
    """
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, B, S, jnp.bfloat16))
    else:
        cache_len = -(-(S + 1) // 512) * 512
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, B, cache_len, jnp.bfloat16))
    tokens = _sds((B, 1), jnp.int32)
    rng = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return tokens, cache, rng


def params_specs_for(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg, dtype))


def opt_state_specs_for(opt, params_sds):
    return jax.eval_shape(opt.init, params_sds)


def hbm_bytes_estimate(cfg: ModelConfig, shape_name: str, n_dev: int,
                       kind: str | None = None) -> float:
    """Fusion-aware per-device HBM traffic per step (napkin model).

    XLA's `bytes accessed` counts every HLO op unfused and overestimates real
    DRAM traffic by 1-2 orders of magnitude; this analytic estimate assumes
    perfect elementwise fusion: parameters, saved activations (remat=dots),
    logits, optimizer state, and KV/state caches each move once per use.
    """
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    kind = kind or info["kind"]
    P = cfg.param_count()
    p_bytes = 2.0 * P / n_dev                     # bf16 params per device
    d, ff = cfg.d_model, (cfg.d_ff_expert if cfg.moe else cfg.d_ff)
    hd = cfg.resolved_head_dim

    if kind == "decode":
        # params once + cache read/write once
        if cfg.family == "rwkv":
            cache = cfg.n_layers * B * (d // cfg.rwkv_head_dim) * \
                cfg.rwkv_head_dim ** 2 * 4
        elif cfg.family == "hybrid":
            d_in = cfg.mamba_expand * d
            cache = cfg.n_layers * B * (d_in // cfg.mamba_head_dim) * \
                cfg.mamba_head_dim * cfg.ssm_state * 4
            cache += cfg.n_shared_attn_blocks * B * S * 2 * \
                cfg.n_kv_heads * hd * 2
        elif cfg.mla:
            cache = cfg.n_layers * B * S * (cfg.kv_lora_rank +
                                            cfg.qk_rope_dim) * 2
        else:
            cache = cfg.n_layers * B * S * 2 * cfg.n_kv_heads * hd * 2
        act_p = cfg.active_param_count() if cfg.moe else P
        return 2.0 * act_p / n_dev + 2.0 * cache / n_dev

    tokens_dev = B * S / n_dev
    # saved dot outputs per token per layer (remat="dots" policy)
    attn_save = cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd + d
    ff_mult = (cfg.top_k + cfg.n_shared_experts) if cfg.moe else 1
    mlp_save = ff_mult * (3 * ff) + d
    act = tokens_dev * cfg.n_layers * (attn_save + mlp_save) * 2  # bf16
    logits = tokens_dev * cfg.vocab_size * 4
    if kind == "prefill":
        return p_bytes + act + logits
    # train: params fwd+bwd+update, adafactor state ~1.5 passes, acts saved
    # then re-read in bwd, logits fwd+bwd
    opt_bytes = 4.0 * P / n_dev * 0.5             # factored second moment
    return 3 * p_bytes + 2 * opt_bytes + 2.5 * act + 2 * logits


def flops_estimate(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS: 6·N·D (dense train), 6·N_active·D (MoE); 2·N·D forward
    for prefill; 2·N_active per token for decode."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if cfg.family == "encdec" and info["kind"] != "decode":
        # encoder sees S frames, decoder decoder_max_len tokens
        tokens = B * (S + cfg.decoder_max_len) / 2  # rough split of params
    else:
        tokens = B * S
    if info["kind"] == "train":
        return 6.0 * n * tokens
    if info["kind"] == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * B  # decode: one token per sequence
