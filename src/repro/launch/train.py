"""Production training launcher.

On real hardware this process runs once per host (jax.distributed initialises
from the cluster env); on CPU it runs the same code on a 1x1 dev mesh, so the
launch path itself is exercised by tests and examples.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --reduced --steps 20 --batch 4 --seq 64 --ckpt-dir runs/ckpt

Production invocation (per pod host):
    python -m repro.launch.train --arch llama3-405b --mesh 16x16 \
        --batch 256 --seq 4096 --opt adafactor --remat dots \
        --ckpt-dir gs://bucket/run1 --microbatch 4 --compress-grads
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config, reduce_config
from repro.data.pipeline import ShardedLoader, TokenStream
from repro.distributed.ctx import sharding_ctx
from repro.distributed.sharding import batch_specs, opt_state_specs, \
    param_specs
from repro.optim import adafactor, adamw, linear_warmup_cosine
from repro.train import make_train_step


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.lower().split("x"))
    n = int(np.prod(dims))
    if n > len(jax.devices()):
        raise SystemExit(f"mesh {spec} needs {n} devices, have "
                         f"{len(jax.devices())} (use launch/dryrun.py for "
                         f"compile-only validation of production meshes)")
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving reduced config (CPU)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="dots",
                    choices=["dots", "full", "none"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = parse_mesh(args.mesh)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"batch={args.batch}x{args.seq}")

    opt = (adamw if args.opt == "adamw" else adafactor)(
        lr=linear_warmup_cosine(args.lr, max(1, args.steps // 10),
                                args.steps))

    with sharding_ctx(mesh, {}):
        params_sds = jax.eval_shape(
            lambda: M.init_params(jax.random.PRNGKey(args.seed), cfg,
                                  jnp.float32))
        p_specs = param_specs(params_sds, mesh, {})
        o_specs = opt_state_specs(jax.eval_shape(opt.init, params_sds),
                                  p_specs, mesh)
        params = jax.jit(
            lambda k: M.init_params(k, cfg, jnp.float32),
            out_shardings=p_specs)(jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(opt.init, out_shardings=o_specs)(params)

        step_fn = jax.jit(
            make_train_step(cfg, opt, remat=args.remat,
                            microbatch=args.microbatch),
            in_shardings=(p_specs, o_specs, None),
            out_shardings=(p_specs, o_specs, None),
            donate_argnums=(0, 1))

        ckpt = None
        start = 0
        if args.ckpt_dir:
            ckpt = Checkpointer(args.ckpt_dir,
                                process_index=jax.process_index(),
                                process_count=jax.process_count())
            if args.resume and latest_step(args.ckpt_dir) is not None:
                start = latest_step(args.ckpt_dir)
                params, opt_state, _ = ckpt.restore(params, opt_state, start)
                print(f"[train] resumed from step {start}")

        stream = ShardedLoader(
            TokenStream(cfg.vocab_size, args.batch, args.seq, args.seed,
                        step=start),
            jax.process_index(), jax.process_count())

        tokens_per_step = args.batch * args.seq
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = next(stream)
            params, opt_state, m = step_fn(params, opt_state, batch)
            jax.block_until_ready(m["loss"])
            dt = time.perf_counter() - t0
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"  step {step:>5} loss {float(m['loss']):.4f} "
                      f"|g| {float(m['grad_norm']):.3f} "
                      f"{tokens_per_step/dt:,.0f} tok/s")
            if ckpt and args.ckpt_every and step and \
                    step % args.ckpt_every == 0:
                ckpt.save(params, opt_state, step,
                          extra={"data": stream.state()})
        if ckpt:
            ckpt.save(params, opt_state, args.steps,
                      extra={"data": stream.state()})
            ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
