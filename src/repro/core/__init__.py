"""pathsig core: truncated & projected path signatures in JAX (the paper's
primary contribution), plus the word algebra driving the Pallas kernels."""
from .words import (Word, all_words, anisotropic_words, dag_words,
                    deconcatenations, decode, encode, flat_index,
                    generated_words, level_offsets, lyndon_words, lyndon_dim,
                    make_plan, make_tiled_plan, prefix_closure,
                    shuffle_product, sig_dim, truncation_plan, WordPlan,
                    TiledPlan)
from .signature import (as_lengths, length_mask, mask_increments,
                        ragged_terminal, signature,
                        signature_from_increments, signature_combine,
                        signature_inverse, stream_emit_mask,
                        stream_emit_slots, stream_emit_steps)
from .projection import projected_signature, projected_signature_from_increments
from .logsignature import logsignature, logsignature_projected, logsig_dim
from .windows import (windowed_signature, windowed_projection,
                      windowed_signature_chen, expanding_windows,
                      sliding_windows, dyadic_windows, select_route)
from .stream import (SignatureStream, signature_stream_init,
                     signature_stream_extend, signature_stream_rolling_drop)
from .transforms import (freeze_tail, lead_lag, time_augment,
                         basepoint_augment, sparse_leadlag_generators)
from . import tensor_ops

__all__ = [
    "Word", "WordPlan", "TiledPlan", "all_words", "anisotropic_words",
    "dag_words", "decode", "encode", "flat_index", "generated_words",
    "level_offsets", "lyndon_words", "lyndon_dim", "make_plan",
    "make_tiled_plan", "prefix_closure", "shuffle_product",
    "deconcatenations", "sig_dim", "truncation_plan",
    "signature", "signature_from_increments", "signature_combine",
    "signature_inverse", "stream_emit_steps", "projected_signature",
    "projected_signature_from_increments", "logsignature",
    "logsignature_projected", "logsig_dim", "windowed_signature",
    "windowed_projection", "windowed_signature_chen", "expanding_windows",
    "sliding_windows", "dyadic_windows", "select_route", "SignatureStream",
    "signature_stream_init", "signature_stream_extend",
    "signature_stream_rolling_drop", "lead_lag", "time_augment",
    "basepoint_augment", "freeze_tail", "sparse_leadlag_generators",
    "tensor_ops", "as_lengths", "length_mask", "mask_increments",
    "ragged_terminal", "stream_emit_mask", "stream_emit_slots",
]
