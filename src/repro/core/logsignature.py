"""Log-signatures in the Lyndon (expanded) basis (paper §3.3).

Two routes:

- ``logsignature``: dense — full truncated signature, truncated tensor log,
  then read the Lyndon-word coordinates.  Oracle path.
- ``logsignature_projected``: the paper's projection trick — the signature is
  computed over W_{<=N-1} ∪ Lyndon_N only (the top level, which dominates cost
  since |W_n| = d^n, is restricted to Lyndon words), and the level-N log
  coefficients are assembled from word factorisations:

      log(S)[w] = sum_{k=1..n} (-1)^{k+1}/k  sum_{w = u_1∘…∘u_k, u_i≠eps}
                  prod_i S[u_i]

  Every proper factor of w has length <= N-1 and is therefore available.
"""
from __future__ import annotations

import itertools
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ops as tops
from .projection import projected_signature_from_increments
from .signature import signature_from_increments
from .words import (Word, all_words, encode, level_offsets, lyndon_words,
                    make_plan, sig_dim)


@lru_cache(maxsize=None)
def _lyndon_flat_indices(d: int, depth: int) -> np.ndarray:
    offs = level_offsets(d, depth)
    idx = [int(offs[len(w)] + encode(w, d)) for w in lyndon_words(d, depth)]
    return np.asarray(idx, dtype=np.int32)


def logsignature(path: jax.Array, depth: int, *, basepoint: bool = False,
                 backward: str = "inverse",
                 backend: str = "jax") -> jax.Array:
    """Dense route: log of the full truncated signature at Lyndon words.

    The underlying truncated signature rides the engine dispatch
    (:mod:`repro.kernels.ops`); the tensor log is plain jnp algebra, so the
    whole route stays differentiable on every backend.
    """
    if path.ndim == 2:
        return logsignature(path[None], depth, basepoint=basepoint,
                            backward=backward, backend=backend)[0]
    if basepoint:
        path = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
    d = path.shape[-1]
    flat = signature_from_increments(tops.path_increments(path), depth,
                                     backward=backward, backend=backend)
    logs = tops.tensor_log(tops.flat_to_levels(flat, d, depth))
    log_flat = tops.levels_to_flat(logs)
    return jnp.take(log_flat, jnp.asarray(_lyndon_flat_indices(d, depth)),
                    axis=1)


# ---------------------------------------------------------------------------
# projected route (paper §3.3 trick)
# ---------------------------------------------------------------------------

def _compositions(word: Word, k: int):
    """All ways to split `word` into k non-empty contiguous factors."""
    n = len(word)
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = (0,) + cuts + (n,)
        yield tuple(word[bounds[i]:bounds[i + 1]] for i in range(k))


@lru_cache(maxsize=None)
def _projected_tables(d: int, depth: int):
    """Plan + factorisation index tables for the projected log-signature.

    Word set: all words to depth-1, plus Lyndon words at depth.  For each
    depth-N Lyndon word we tabulate every composition into k >= 2 factors as
    rows of output-coefficient indices (into the plan's output vector), padded
    with -1 (interpreted as multiplying by 1).
    """
    lw = lyndon_words(d, depth)
    top = [w for w in lw if len(w) == depth]
    words = all_words(d, depth - 1) + top if depth > 1 else top
    plan = make_plan(words, d)
    pos = {w: i for i, w in enumerate(plan.words)}

    rows, coefs = [], []
    for w in top:
        for k in range(2, depth + 1):
            for parts in _compositions(w, k):
                rows.append([pos[p] for p in parts] + [-1] * (depth - k))
                coefs.append(((-1) ** (k + 1)) / k)
    comp_idx = np.asarray(rows, dtype=np.int32) if rows else \
        np.zeros((0, depth), np.int32)
    comp_coef = np.asarray(coefs, dtype=np.float32)
    # scatter target: which top word each composition row belongs to
    tgt = []
    for wi, w in enumerate(top):
        cnt = sum(1 for k in range(2, depth + 1)
                  for _ in _compositions(w, k))
        tgt.extend([wi] * cnt)
    comp_tgt = np.asarray(tgt, dtype=np.int32)
    top_rows = np.asarray([pos[w] for w in top], dtype=np.int32)
    lown = sig_dim(d, depth - 1) if depth > 1 else 0
    lyn_low = [w for w in lw if len(w) < depth]
    low_rows = np.asarray([pos[w] for w in lyn_low] if depth > 1 else [],
                          dtype=np.int32)
    return plan, comp_idx, comp_coef, comp_tgt, top_rows, low_rows, lown


def logsignature_projected(path: jax.Array, depth: int, *,
                           basepoint: bool = False,
                           backward: str = "inverse",
                           backend: str = "jax") -> jax.Array:
    """Paper route: never materialises non-Lyndon level-N coefficients.

    On the jax engine the hybrid dense+top engine computes the §3.3 word set;
    on the pallas engines the word kernel runs over the same plan via the
    dispatch layer, with the §4.2 inverse-reconstruction backward.
    """
    if path.ndim == 2:
        return logsignature_projected(path[None], depth, basepoint=basepoint,
                                      backward=backward, backend=backend)[0]
    if basepoint:
        path = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
    d = path.shape[-1]
    plan, comp_idx, comp_coef, comp_tgt, top_rows, low_rows, lown = \
        _projected_tables(d, depth)
    incs = tops.path_increments(path)
    from repro.kernels import ops  # deferred: ops imports this package
    engine, _ = ops.resolve_backend(backend)
    if engine != "jax":
        coeffs = ops.projected(incs, plan, backend=backend,
                               backward=backward)            # (B, |I|)
    elif depth >= 2:
        # hybrid engine (§Perf kernel note): dense reshape-broadcast Horner
        # for W_{<=N-1}, per-word chains only for Lyndon_N.  plan.words is
        # all_words(N-1) ++ Lyndon_N in exactly the hybrid output order.
        from .hybrid import hybrid_low_plus_top
        top = [w for w in lyndon_words(d, depth) if len(w) == depth]
        coeffs = hybrid_low_plus_top(incs, top, depth, backward=backward)
    else:
        coeffs = projected_signature_from_increments(
            incs, plan, backward=backward)                   # (B, |I|)

    # levels < N: dense truncated log on the low part (ordered level-major,
    # exactly the flat layout of a depth-(N-1) signature).
    outs = []
    if depth > 1:
        low = coeffs[:, :lown]
        logs_low = tops.tensor_log(tops.flat_to_levels(low, d, depth - 1))
        low_flat = tops.levels_to_flat(logs_low)
        lyn_low_idx = jnp.asarray(_lyndon_flat_indices(d, depth - 1))
        outs.append(jnp.take(low_flat, lyn_low_idx, axis=1))

    # level N at Lyndon words: k=1 term + composition sums over low factors.
    top = jnp.take(coeffs, jnp.asarray(top_rows), axis=1)  # (B, |Lyndon_N|)
    if comp_idx.shape[0]:
        padded = jnp.concatenate(
            [coeffs, jnp.ones((coeffs.shape[0], 1), coeffs.dtype)], axis=1)
        idx = jnp.asarray(comp_idx)
        idx = jnp.where(idx < 0, coeffs.shape[1], idx)      # -1 -> ones column
        factors = jnp.take(padded, idx, axis=1)             # (B, R, depth)
        prods = jnp.prod(factors, axis=2) * jnp.asarray(comp_coef)[None]
        corr = jnp.zeros_like(top).at[:, jnp.asarray(comp_tgt)].add(prods)
        top = top + corr
    outs.append(top)
    return jnp.concatenate(outs, axis=1)


def logsig_dim(d: int, depth: int) -> int:
    return len(lyndon_words(d, depth))
