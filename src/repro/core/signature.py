"""Truncated path signatures with O(1)-in-length backprop (paper §3-4).

Public API
----------
``signature(path, depth, ...)``              (B, M+1, d) -> (B, D_sig)
``signature_from_increments(incs, depth)``   (B, M, d)   -> (B, D_sig)
``signature(..., stream=True)``              -> (B, M_out, D_sig) prefix
signatures at every ``stream_stride``-th step (terminal step always emitted;
see :func:`stream_emit_steps`).  Streaming is a first-class axis: every
backend routes through the engine dispatch, and the ``inverse`` backward is
the §4.2 reverse sweep generalised to cotangents arriving at every emitted
step (:func:`stream_inverse_bwd_scan`) — one reverse scan, O(B·D_sig) live
memory.

Three backward modes:

- ``"inverse"`` (default, the paper's §4.2): store only the terminal
  signature; reconstruct S_{0,t_{j-1}} = S_{0,t_j} ⊗ exp(-ΔX_j) during the
  backward sweep.  Memory O(B·D_sig), independent of M.
- ``"checkpoint"`` (beyond paper): O(√M) chunk boundaries are stored and the
  backward recomputes within chunks — immune to inverse-reconstruction drift
  on very long/large-increment paths.
- ``"autodiff"``: plain scan autodiff, O(M·B·D_sig) memory (keras_sig-style
  scaling; used as the memory-law baseline in benchmarks).
"""
from __future__ import annotations

import math
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ops as tops
from .words import sig_dim


def stream_emit_steps(M: int, stride: int = 1) -> np.ndarray:
    """0-based scan steps emitted by a streamed forward: stride-1, 2·stride-1,
    ..., with the terminal step M-1 always included.  len == ceil(M/stride);
    step j holds the prefix signature S_{0,t_{j+1}} (over j+1 increments)."""
    if stride < 1:
        raise ValueError(f"stream_stride must be >= 1, got {stride}")
    if M == 0:
        return np.zeros((0,), np.int64)
    steps = np.arange(stride - 1, M, stride, dtype=np.int64)
    if steps.size == 0 or steps[-1] != M - 1:
        steps = np.append(steps, M - 1)
    return steps


# ---------------------------------------------------------------------------
# ragged (variable-length) support: the length axis as masks over a padded
# batch.  A zero increment is the identity Chen update, so zero-masking the
# padded tail makes the terminal signature of a padded batch EXACTLY the
# per-example unpadded signature on every engine — and because the mask
# multiply is the outermost op, cotangents w.r.t. padded steps are exactly
# zero through any custom VJP underneath.
# ---------------------------------------------------------------------------

def as_lengths(lengths, B: int) -> jax.Array:
    """Normalise a ``lengths=`` argument to a (B,) int32 array (a scalar
    broadcasts across the batch)."""
    lengths = jnp.asarray(lengths)
    if lengths.ndim == 0:
        lengths = jnp.broadcast_to(lengths, (B,))
    if lengths.shape != (B,):
        raise ValueError(f"lengths must be scalar or shape ({B},), got "
                         f"{lengths.shape}")
    return lengths.astype(jnp.int32)


def length_mask(lengths: jax.Array, M: int) -> jax.Array:
    """(B,) per-example increment counts -> (B, M) bool, True where the scan
    step index lies inside the example's true path."""
    return jnp.arange(M, dtype=jnp.int32)[None, :] < lengths[:, None]


def mask_increments(increments: jax.Array, lengths) -> jax.Array:
    """Zero every increment at or past each example's true end.  Exact: a
    zero increment is the identity update, and ∂(x·mask)/∂x = mask zeroes
    the padded-tail cotangents."""
    if lengths is None:
        return increments
    B, M, _ = increments.shape
    m = length_mask(as_lengths(lengths, B), M)
    return increments * m[..., None].astype(increments.dtype)


def stream_emit_slots(M: int, stride: int, lengths: jax.Array) -> jax.Array:
    """(B,) emitted-step slot holding each example's TRUE terminal signature.

    Emitted slot j covers min((j+1)·stride, M) increments; with the padded
    tail zero-masked, the first slot covering >= length increments already
    equals the example's terminal state.  That slot is
    ceil(length / stride) - 1, clamped into [0, M_out).
    """
    M_out = -(-M // stride)
    slots = (lengths + (stride - 1)) // stride - 1
    return jnp.clip(slots, 0, max(M_out - 1, 0)).astype(jnp.int32)


def stream_emit_mask(M: int, stride: int, lengths: jax.Array) -> jax.Array:
    """(B, M_out) bool: True up to and including each example's true-terminal
    slot (:func:`stream_emit_slots`); emissions past the end are masked."""
    M_out = -(-M // stride)
    slots = stream_emit_slots(M, stride, lengths)
    return jnp.arange(M_out, dtype=jnp.int32)[None, :] <= slots[:, None]


def ragged_terminal(stream_out: jax.Array, lengths, stride: int = 1,
                    M: int | None = None) -> jax.Array:
    """Gather each example's true terminal state from a streamed output.

    ``stream_out`` is (B, M_out, D) as emitted by ``stream=True``;
    ``M`` is the padded increment count (default: inferred from M_out·stride,
    exact whenever stride == 1).  Returns (B, D).
    """
    B, M_out, _ = stream_out.shape
    if M is None:
        M = M_out * stride
    slots = stream_emit_slots(M, stride, as_lengths(lengths, B))
    return jnp.take_along_axis(stream_out, slots[:, None, None],
                               axis=1)[:, 0]


def _as_batched(x: jax.Array) -> tuple[jax.Array, bool]:
    if x.ndim == 2:
        return x[None], True
    if x.ndim == 3:
        return x, False
    raise ValueError(f"expected (M, d) or (B, M, d), got {x.shape}")


def _unpack_ragged(path):
    """Duck-typed :class:`repro.ragged.RaggedPaths` unpacking (kept import-
    free: ``repro.ragged`` imports this module)."""
    if hasattr(path, "values") and hasattr(path, "lengths"):
        return path.values, path.lengths
    return path, None


# ---------------------------------------------------------------------------
# forward scan
# ---------------------------------------------------------------------------

def _scan_forward(increments: jax.Array, depth: int,
                  stream: bool) -> jax.Array:
    """Plain levelwise-Horner Chen scan.  increments: (B, M, d)."""
    B, M, d = increments.shape

    def step(levels, dx):
        new = tops.horner_step(levels, dx)
        return new, (tops.levels_to_flat(new) if stream else None)

    init = tops.zero_levels((B,), d, depth, increments.dtype)
    final, ys = jax.lax.scan(step, init, jnp.moveaxis(increments, 1, 0))
    if stream:
        return jnp.moveaxis(ys, 0, 1)  # (B, M, D_sig)
    return tops.levels_to_flat(final)


# ---------------------------------------------------------------------------
# precision: "fp32" | "bf16_fp32" (bf16-quantised increments, fp32
# accumulation).  The quantisation IS the semantics: every engine computes
# fp32 Horner updates on bf16-rounded increments, so engines agree to float
# tolerance and the error vs the fp32 oracle is bounded per level (~ n·2^-8
# at level n; see tests/test_precision.py).
# ---------------------------------------------------------------------------

PRECISIONS = ("fp32", "bf16_fp32")


def canon_precision(precision: str) -> str:
    p = {"bf16": "bf16_fp32"}.get(precision, precision)
    if p not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}: expected one of "
                         f"{PRECISIONS}")
    return p


def quantise_increments(x: jax.Array, precision: str) -> jax.Array:
    """Round increments to the storage dtype of ``precision`` (returned in
    the original dtype so downstream fp32 accumulation is unchanged)."""
    if canon_precision(precision) == "bf16_fp32":
        return jax.lax.stop_gradient(
            x.astype(jnp.bfloat16)).astype(x.dtype) + (x - jax.lax.stop_gradient(x))
    return x


# ---------------------------------------------------------------------------
# fused-transform forward: the augmented increment is built in registers per
# Horner sub-step, so the (B, M_aug, d_aug) intermediate never exists and the
# scan runs M (not M_aug) iterations.  ``increments`` must already include
# the basepoint increment (dispatch prepends x0); ``taux`` is
# transforms.transform_time_aux output (pass zeros when spec.time is False).
# ---------------------------------------------------------------------------

def _fused_build_increment(dx: jax.Array, taux: jax.Array, spec, phase: int,
                           ja) -> jax.Array:
    """One augmented increment (B, d_aug) from a raw increment (B, d)."""
    parts = []
    if spec.time:
        dt, n_valid = taux[:, :1], taux[:, 1:]
        parts.append(dt * (ja < n_valid).astype(dx.dtype))
    if spec.lead_lag:
        z = jnp.zeros_like(dx)
        parts += [z, dx] if phase == 0 else [dx, z]   # [lag, lead] channels
    else:
        parts.append(dx)
    return jnp.concatenate(parts, axis=-1)


def _fused_scan_forward(increments: jax.Array, taux: jax.Array, spec,
                        depth: int, stream: bool) -> jax.Array:
    """Fused levelwise-Horner Chen scan: ``spec.sub_steps`` Horner sub-steps
    per scan iteration.  increments: (B, M, d) raw; output over the
    *augmented* axis when streamed: (B, M_aug, D_sig)."""
    from .transforms import transform_dim
    B, M, d = increments.shape
    sub = spec.sub_steps
    d_aug = transform_dim(dataclasses_replace_nobp(spec), d)

    def step(levels, xs):
        dx, j = xs
        ys = []
        for p in range(sub):
            e = _fused_build_increment(dx, taux, spec, p,
                                       (sub * j + p).astype(taux.dtype))
            levels = tops.horner_step(levels, e)
            if stream:
                ys.append(tops.levels_to_flat(levels))
        return levels, (jnp.stack(ys, 0) if stream else None)

    init = tops.zero_levels((B,), d_aug, depth, increments.dtype)
    idx = jnp.arange(M, dtype=jnp.int32)
    final, ys = jax.lax.scan(step, init, (jnp.moveaxis(increments, 1, 0), idx))
    if stream:  # ys: (M, sub, B, D) -> (B, M_aug, D)
        return jnp.moveaxis(ys.reshape(M * sub, B, -1), 0, 1)
    return tops.levels_to_flat(final)


def dataclasses_replace_nobp(spec):
    """The kernel-level view of a transform spec: basepoint is an increment
    prepend handled by dispatch, so the scan/kernels only see lead_lag/time."""
    import dataclasses
    if spec.basepoint:
        return dataclasses.replace(spec, basepoint=False)
    return spec


@lru_cache(maxsize=None)
def _make_fused_inverse_vjp(depth: int, spec):
    """Fused forward + §4.2 reverse sweep.  The backward transiently
    materialises the augmented increments (reusing :func:`inverse_bwd_scan`
    unchanged), then pulls the cotangent back through the transform's linear
    adjoint (:func:`repro.core.transforms.fused_adjoint`)."""
    @jax.custom_vjp
    def sig(increments, taux):
        return _fused_scan_forward(increments, taux, spec, depth, False)

    def fwd(increments, taux):
        out = sig(increments, taux)
        return out, (increments, taux, out)

    def bwd(res, g_flat):
        from .transforms import fused_augment, fused_adjoint
        increments, taux, out_flat = res
        e = fused_augment(increments, taux, spec)
        g_e = inverse_bwd_scan(e, out_flat, g_flat, depth)
        g_incs = fused_adjoint(g_e, spec, increments.shape[-1])
        return g_incs, jnp.zeros_like(taux)

    sig.defvjp(fwd, bwd)
    return sig


@lru_cache(maxsize=None)
def _make_fused_stream_inverse_vjp(depth: int, spec, stride: int):
    """Streamed fused forward (emissions strided over the AUGMENTED step
    axis) + the generalised §4.2 reverse sweep."""
    @jax.custom_vjp
    def sig(increments, taux):
        out = _fused_scan_forward(increments, taux, spec, depth, True)
        return _subsample_stream(out, out.shape[1], stride)

    def fwd(increments, taux):
        out = sig(increments, taux)
        return out, (increments, taux, out[:, -1])

    def bwd(res, g_steps):
        from .transforms import fused_augment, fused_adjoint
        increments, taux, terminal = res
        e = fused_augment(increments, taux, spec)
        g_e = stream_inverse_bwd_scan(e, terminal, g_steps, depth, stride)
        g_incs = fused_adjoint(g_e, spec, increments.shape[-1])
        return g_incs, jnp.zeros_like(taux)

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# custom VJP: inverse reconstruction (paper §4.2)
# ---------------------------------------------------------------------------

def inverse_bwd_scan(increments: jax.Array, out_flat: jax.Array,
                     g_flat: jax.Array, depth: int) -> jax.Array:
    """§4.2 backward sweep: reconstruct S_{0,t_{j-1}} = S_{0,t_j} ⊗ exp(-ΔX_j)
    and accumulate cotangents in one reverse scan.

    Engine-agnostic: any forward producing ``out_flat`` (pure-JAX scan or a
    Pallas kernel) can pair with this backward — memory stays O(B·D_sig).
    """
    B, M, d = increments.shape
    S_T = tops.flat_to_levels(out_flat, d, depth)
    G_T = tops.flat_to_levels(g_flat, d, depth)

    def step(carry, dx):
        S, G = carry  # S = S_{0,t_j}, G = ∂L/∂S_{0,t_j}
        S_prev = tops.horner_step(S, -dx)          # Prop. 4.6
        _, vjp_fn = jax.vjp(tops.horner_step, S_prev, dx)
        G_prev, g_dx = vjp_fn(G)
        return (S_prev, G_prev), g_dx

    (_, _), g_rev = jax.lax.scan(
        step, (S_T, G_T), jnp.moveaxis(increments, 1, 0), reverse=True)
    return jnp.moveaxis(g_rev, 0, 1)


@lru_cache(maxsize=None)
def _make_inverse_vjp(depth: int):
    @jax.custom_vjp
    def sig(increments):
        return _scan_forward(increments, depth, stream=False)

    def fwd(increments):
        out = sig(increments)
        return out, (increments, out)

    def bwd(res, g_flat):
        increments, out_flat = res
        return (inverse_bwd_scan(increments, out_flat, g_flat, depth),)

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# streamed custom VJP: §4.2 generalised to cotangents at every emitted step
# ---------------------------------------------------------------------------

def stream_inverse_bwd_scan(increments: jax.Array, terminal_flat: jax.Array,
                            g_steps: jax.Array, depth: int,
                            stride: int = 1) -> jax.Array:
    """§4.2 reverse sweep for a *streamed* forward: cotangents ``g_steps``
    (B, M_out, D_sig) arrive at every emitted step, and one reverse scan
    reconstructs S_{0,t_{j-1}} = S_{0,t_j} ⊗ exp(-ΔX_j) while folding in the
    step-j cotangent just before pulling it back — still O(B·D_sig) live
    memory.  The non-streamed :func:`inverse_bwd_scan` is the special case
    where only the terminal cotangent is non-zero.

    Engine-agnostic: any forward emitting :func:`stream_emit_steps` (the JAX
    scan or the streamed Pallas kernel) pairs with this backward; only the
    terminal signature ``terminal_flat`` (B, D_sig) is needed as residual.
    """
    B, M, d = increments.shape
    steps = stream_emit_steps(M, stride)
    if len(steps) == M:
        g_dense = g_steps
    else:  # scatter strided cotangents onto the full time axis
        g_dense = jnp.zeros((B, M, g_steps.shape[-1]), g_steps.dtype
                            ).at[:, jnp.asarray(steps)].set(g_steps)
    S_T = tops.flat_to_levels(terminal_flat, d, depth)
    G_T = [jnp.zeros_like(a) for a in S_T]

    def step(carry, xs):
        S, G = carry  # S = S_{0,t_j}, G = ∂L/∂S_{0,t_j} from steps > j
        dx, g_j = xs
        G = [a + b for a, b in zip(G, tops.flat_to_levels(g_j, d, depth))]
        S_prev = tops.horner_step(S, -dx)          # Prop. 4.6
        _, vjp_fn = jax.vjp(tops.horner_step, S_prev, dx)
        G_prev, g_dx = vjp_fn(G)
        return (S_prev, G_prev), g_dx

    (_, _), g_rev = jax.lax.scan(
        step, (S_T, G_T), (jnp.moveaxis(increments, 1, 0),
                           jnp.moveaxis(g_dense, 1, 0)), reverse=True)
    return jnp.moveaxis(g_rev, 0, 1)


def _subsample_stream(out: jax.Array, M: int, stride: int) -> jax.Array:
    """(B, M, D) full stream -> (B, M_out, D) at the emitted steps."""
    if stride == 1:
        return out
    return out[:, jnp.asarray(stream_emit_steps(M, stride))]


@lru_cache(maxsize=None)
def _make_stream_inverse_vjp(depth: int, stride: int):
    @jax.custom_vjp
    def sig(increments):
        out = _scan_forward(increments, depth, stream=True)
        return _subsample_stream(out, increments.shape[1], stride)

    def fwd(increments):
        out = sig(increments)
        return out, (increments, out[:, -1])  # terminal step always emitted

    def bwd(res, g_steps):
        increments, terminal = res
        return (stream_inverse_bwd_scan(increments, terminal, g_steps, depth,
                                        stride),)

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# custom VJP: sqrt(M) checkpointing (beyond paper)
# ---------------------------------------------------------------------------

def _chunk_scan(levels, incs, depth: int):
    """Advance a levels state through one chunk of increments (c, B, d)."""
    def step(lv, dx):
        return tops.horner_step(lv, dx), None
    out, _ = jax.lax.scan(step, levels, incs)
    return out


def _fold_chunks(increments: jax.Array, chunk: int):
    """(B, M, d) -> time-major (n_chunks, chunk, B, d), zero-padded."""
    B, M, d = increments.shape
    n_chunks = -(-M // chunk)
    pad = n_chunks * chunk - M
    incs = jnp.pad(increments, ((0, 0), (0, pad), (0, 0)))  # zero incs = identity
    return jnp.moveaxis(incs, 1, 0).reshape(n_chunks, chunk, B, d)


def checkpoint_bwd_scan(increments: jax.Array, boundaries, g_flat: jax.Array,
                        depth: int, chunk: int) -> jax.Array:
    """√M-checkpoint backward: recompute within chunks from stored boundary
    states (levels stacked along a leading n_chunks axis).  Shared by the
    pure-JAX and Pallas-forward checkpoint VJPs."""
    B, M, d = increments.shape
    incs = _fold_chunks(increments, chunk)
    n_chunks = incs.shape[0]
    G = tops.flat_to_levels(g_flat, d, depth)

    def chunk_fn(levels, c_incs):
        return _chunk_scan(levels, c_incs, depth)

    def outer(G, xs):
        bound, c_incs = xs
        _, vjp_fn = jax.vjp(chunk_fn, bound, c_incs)
        G_prev, g_incs = vjp_fn(G)
        return G_prev, g_incs

    _, g_rev = jax.lax.scan(outer, G, (boundaries, incs), reverse=True)
    g = jnp.moveaxis(g_rev.reshape(n_chunks * chunk, B, d), 0, 1)
    return g[:, :M]


@lru_cache(maxsize=None)
def _make_checkpoint_vjp(depth: int, chunk: int):
    @jax.custom_vjp
    def sig(increments):
        return _scan_forward(increments, depth, stream=False)

    def fwd(increments):
        B, M, d = increments.shape
        incs = _fold_chunks(increments, chunk)

        def outer(levels, c_incs):
            new = _chunk_scan(levels, c_incs, depth)
            return new, [lv for lv in levels]  # boundary BEFORE the chunk

        init = tops.zero_levels((B,), d, depth, increments.dtype)
        final, boundaries = jax.lax.scan(outer, init, incs)
        return tops.levels_to_flat(final), (increments, boundaries)

    def bwd(res, g_flat):
        increments, boundaries = res
        return (checkpoint_bwd_scan(increments, boundaries, g_flat, depth,
                                    chunk),)

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def default_chunk(M: int) -> int:
    """√M chunk length for the checkpoint backward (paper-adjacent default)."""
    return max(1, int(math.isqrt(max(M, 1))))


def unsupported_stream_backward(backward: str) -> NotImplementedError:
    """The error raised for stream=True × backward cells without a kernel
    (kept in one place so dispatch and the pure-JAX route agree)."""
    return NotImplementedError(
        f"stream=True does not support backward={backward!r}: the streamed "
        "output already materialises every emitted prefix, so use "
        "backward='inverse' (one generalised §4.2 reverse scan, O(B·D_sig) "
        "live memory) or backward='autodiff'")


def _fused_jax_signature(increments: jax.Array, depth: int, spec, *, x0,
                         stream: bool, stream_stride: int, backward: str,
                         lengths, precision: str) -> jax.Array:
    """Fused-transform route of the pure-JAX engine: basepoint is an
    increment prepend, lead_lag/time are built per sub-step inside the scan.
    Streaming, lengths masking, and emissions are over the AUGMENTED axis."""
    from .transforms import (fused_augment, transform_dim, transform_lengths,
                             transform_time_aux)
    B, M, d = increments.shape
    increments = quantise_increments(increments, precision)
    if lengths is not None:
        lengths = as_lengths(lengths, B)
        increments = mask_increments(increments, lengths)
    if spec.basepoint:
        if x0 is None:
            raise ValueError("transform with basepoint needs x0= (the path "
                             "start point, shape (B, d)); repro.core."
                             "signature.signature passes it automatically")
        x0 = quantise_increments(jnp.asarray(x0).astype(increments.dtype),
                                 precision)
        increments = jnp.concatenate([x0[:, None, :], increments], axis=1)
    kspec = dataclasses_replace_nobp(spec)
    M_bp = increments.shape[1]
    lengths_bp = None if lengths is None else lengths + int(spec.basepoint)
    taux = transform_time_aux(kspec, B, M_bp, lengths_bp)
    M_aug = M_bp * kspec.sub_steps
    aug_lengths = transform_lengths(spec, lengths)
    if stream:
        if M_aug == 0:  # no steps -> no emissions
            out = jnp.zeros((B, 0, sig_dim(transform_dim(kspec, d), depth)),
                            increments.dtype)
        elif backward == "inverse":
            out = _make_fused_stream_inverse_vjp(depth, kspec, stream_stride)(
                increments, taux)
        elif backward == "autodiff":
            out = _subsample_stream(
                _fused_scan_forward(increments, taux, kspec, depth, True),
                M_aug, stream_stride)
        elif backward == "checkpoint":
            raise unsupported_stream_backward(backward)
        else:
            raise ValueError(f"unknown backward mode {backward!r}")
        if lengths is not None and M_aug:
            out = out * stream_emit_mask(M_aug, stream_stride,
                                         aug_lengths)[..., None].astype(out.dtype)
    elif backward == "inverse":
        out = _make_fused_inverse_vjp(depth, kspec)(increments, taux)
    elif backward == "autodiff":
        out = _fused_scan_forward(increments, taux, kspec, depth, False)
    elif backward == "checkpoint":
        # materialise-then-sweep fallback (documented in the ops support
        # matrix): the augment is linear, so autodiff through it IS the
        # transform adjoint, and the √M-checkpoint VJP is reused unchanged.
        e = fused_augment(increments, taux, kspec)
        out = _make_checkpoint_vjp(depth, default_chunk(M_aug))(e)
    else:
        raise ValueError(f"unknown backward mode {backward!r}")
    return out


def signature_from_increments(increments: jax.Array, depth: int, *,
                              stream: bool = False, stream_stride: int = 1,
                              backward: str = "inverse",
                              backend: str = "jax",
                              lengths=None, transform=None, x0=None,
                              precision: str = "fp32") -> jax.Array:
    """Truncated signature from increments (B, M, d) -> (B, D_sig).

    ``backend`` other than ``"jax"`` routes through the engine dispatch in
    :mod:`repro.kernels.ops` (Pallas kernels with the same custom VJPs) —
    including ``stream=True``, which emits every ``stream_stride``-th prefix
    signature as (B, M_out, D_sig).  ``stream`` with ``backward="checkpoint"``
    raises (see the support matrix in :mod:`repro.kernels.ops`).

    ``lengths`` (B,) makes the batch ragged: increments at or past each
    example's length are zero-masked (exact — zero is the identity update),
    so the terminal output is the per-example unpadded signature, gradients
    past the true end are exactly zero, and streamed emissions are masked
    after each example's true-terminal slot (:func:`stream_emit_slots`).

    ``transform`` (see :func:`repro.core.transforms.as_transform`) fuses
    ``basepoint`` / ``lead_lag`` / ``time_augment`` into the sweep: each
    augmented increment is built in registers per Horner sub-step, the
    (B, M_aug, d_aug) intermediate never exists, and streamed emissions /
    lengths are over the AUGMENTED step axis.  ``x0`` (B, d) is the path
    start, required iff the transform includes ``basepoint``.  ``precision``
    is ``"fp32"`` | ``"bf16_fp32"`` (bf16-quantised increments, fp32
    accumulation).
    """
    increments, squeeze = _as_batched(increments)
    if depth < 1:
        raise ValueError("depth must be >= 1")
    precision = canon_precision(precision)
    if backend != "jax":
        from repro.kernels import ops  # deferred: ops imports this module
        out = ops.signature(increments, depth, backend=backend,
                            backward=backward, stream=stream,
                            stream_stride=stream_stride, lengths=lengths,
                            transform=transform, x0=x0, precision=precision)
        return out[0] if squeeze else out
    from .transforms import as_transform
    spec = as_transform(transform)
    if spec is not None:
        out = _fused_jax_signature(increments, depth, spec, x0=x0,
                                   stream=stream, stream_stride=stream_stride,
                                   backward=backward, lengths=lengths,
                                   precision=precision)
        return out[0] if squeeze else out
    increments = quantise_increments(increments, precision)
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
        increments = mask_increments(increments, lengths)
    if stream:
        M = increments.shape[1]
        if M == 0:  # no steps -> no emissions (the custom VJPs need M >= 1)
            out = jnp.zeros((increments.shape[0], 0, sig_dim(
                increments.shape[-1], depth)), increments.dtype)
        elif backward == "inverse":
            out = _make_stream_inverse_vjp(depth, stream_stride)(increments)
        elif backward == "autodiff":
            out = _subsample_stream(_scan_forward(increments, depth,
                                                  stream=True),
                                    M, stream_stride)
        elif backward == "checkpoint":
            raise unsupported_stream_backward(backward)
        else:
            raise ValueError(f"unknown backward mode {backward!r}")
        if lengths is not None and M:
            out = out * stream_emit_mask(M, stream_stride,
                                         lengths)[..., None].astype(out.dtype)
    elif backward == "inverse":
        out = _make_inverse_vjp(depth)(increments)
    elif backward == "checkpoint":
        M = increments.shape[1]
        out = _make_checkpoint_vjp(depth, default_chunk(M))(increments)
    elif backward == "autodiff":
        out = _scan_forward(increments, depth, stream=False)
    else:
        raise ValueError(f"unknown backward mode {backward!r}")
    return out[0] if squeeze else out


def signature(path: jax.Array, depth: int, *, stream: bool = False,
              stream_stride: int = 1, basepoint: bool = False,
              backward: str = "inverse", backend: str = "jax",
              lengths=None, transform=None,
              precision: str = "fp32") -> jax.Array:
    """Truncated signature of a piecewise-linear path (B, M+1, d).

    ``basepoint=True`` prepends X_0 = 0 (so translation information is kept).
    ``backend`` selects the compute engine via :mod:`repro.kernels.ops`
    (``"jax"`` | ``"pallas"`` | ``"pallas_interpret"`` | ``"auto"``).
    ``stream=True`` returns all prefix signatures, strided by
    ``stream_stride`` (terminal always included).  ``lengths`` (B,) gives
    each example's true increment count for ragged batches (the padded tail
    is zero-masked — exact; ``basepoint=True`` adds one increment, which is
    accounted for here).  A :class:`repro.ragged.RaggedPaths` may be passed
    directly as ``path`` (its lengths are used unless overridden).

    ``transform`` (``"time_augment"`` / ``"lead_lag"`` / ``"basepoint"``,
    composable — see :func:`repro.core.transforms.as_transform`) applies the
    path transforms FUSED into the sweep; the basepoint start ``x0`` is taken
    from the path automatically.  ``precision`` is ``"fp32"`` |
    ``"bf16_fp32"``.  ``basepoint=True`` is the legacy point-prepend; prefer
    ``transform="basepoint"``.
    """
    values, rl = _unpack_ragged(path)
    if rl is not None and lengths is None:
        lengths = rl
    path, squeeze = _as_batched(values)
    if lengths is not None:
        lengths = as_lengths(lengths, path.shape[0])
    if basepoint:
        path = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
        if lengths is not None:
            lengths = lengths + 1
    incs = tops.path_increments(path)
    from .transforms import as_transform
    spec = as_transform(transform)
    x0 = path[:, 0] if spec is not None and spec.basepoint else None
    out = signature_from_increments(incs, depth, stream=stream,
                                    stream_stride=stream_stride,
                                    backward=backward, backend=backend,
                                    lengths=lengths, transform=spec, x0=x0,
                                    precision=precision)
    return out[0] if squeeze else out


def signature_combine(flat_a: jax.Array, flat_b: jax.Array, d: int,
                      depth: int) -> jax.Array:
    """Chen combine: sig of concatenated paths from the two parts' sigs."""
    a = tops.flat_to_levels(flat_a, d, depth)
    b = tops.flat_to_levels(flat_b, d, depth)
    return tops.levels_to_flat(tops.chen_mul(a, b))


def signature_inverse(flat: jax.Array, d: int, depth: int) -> jax.Array:
    """Group inverse (= signature of the time-reversed path, Lemma 4.5)."""
    s = tops.flat_to_levels(flat, d, depth)
    return tops.levels_to_flat(tops.tensor_inverse(s))
