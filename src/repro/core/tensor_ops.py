"""Levelwise truncated tensor-algebra operations (paper §2.1-2.2).

Representation: a truncated element of T_{<=N}(R^d) with scalar part 1 is a
``levels`` list ``[a_1, ..., a_N]`` with ``a_n`` of shape ``(..., d**n)``
(level 0 is implicit and equal to 1 unless stated otherwise).  The flat
representation concatenates levels along the last axis into ``(..., D_sig)``,
matching the paper's word-basis layout (level-major, lexicographic within a
level, per Prop. A.2 the base-d encoding IS the lexicographic order).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .words import sig_dim


def levels_to_flat(levels: list[jax.Array]) -> jax.Array:
    return jnp.concatenate(levels, axis=-1)


def flat_to_levels(flat: jax.Array, d: int, depth: int) -> list[jax.Array]:
    out, off = [], 0
    for n in range(1, depth + 1):
        out.append(flat[..., off:off + d**n])
        off += d**n
    assert off == flat.shape[-1], (off, flat.shape)
    return out


def zero_levels(batch_shape: tuple[int, ...], d: int, depth: int,
                dtype=jnp.float32) -> list[jax.Array]:
    return [jnp.zeros((*batch_shape, d**n), dtype) for n in range(1, depth + 1)]


def _outer(a: jax.Array, b: jax.Array) -> jax.Array:
    """Concatenation product of word-basis coefficient blocks.

    a: (..., d^k), b: (..., d^m)  ->  (..., d^(k+m)) with
    out[..., u∘v] = a[..., u] * b[..., v]   (Prop. A.3: index = u*d^m + v).
    """
    return (a[..., :, None] * b[..., None, :]).reshape(*a.shape[:-1],
                                                       a.shape[-1] * b.shape[-1])


def chen_mul(a: list[jax.Array], b: list[jax.Array], *, a0: float = 1.0,
             b0: float = 1.0, min_level_a: int = 0,
             min_level_b: int = 0) -> list[jax.Array]:
    """Truncated tensor product (A ⊗ B)_n = sum_k A_k ⊗ B_{n-k}.

    ``a0``/``b0`` are the scalar (level-0) parts; ``min_level_*`` lets callers
    declare that levels below it are zero (skips work, e.g. powers of A).
    """
    depth = len(a)
    assert len(b) == depth
    out: list[jax.Array] = []
    for n in range(1, depth + 1):
        acc = None
        for k in range(0, n + 1):
            if k < min_level_a and k > 0:
                continue
            if (n - k) < min_level_b and (n - k) > 0:
                continue
            if k == 0:
                term = a0 * b[n - 1] if a0 != 0.0 else None
            elif k == n:
                term = b0 * a[n - 1] if b0 != 0.0 else None
            else:
                term = _outer(a[k - 1], b[n - k - 1])
            if term is not None:
                acc = term if acc is None else acc + term
        if acc is None:
            # a (batched) zero of the right shape
            ref = a[n - 1] if a[n - 1] is not None else b[n - 1]
            acc = jnp.zeros_like(ref)
        out.append(acc)
    return out


def tensor_exp(dx: jax.Array, depth: int) -> list[jax.Array]:
    """exp(dx) levels: (dx^{⊗n} / n!) for n = 1..depth (Prop. 3.1)."""
    out = [dx]
    for n in range(2, depth + 1):
        out.append(_outer(out[-1], dx) / n)
    return out


def tensor_log(s: list[jax.Array]) -> list[jax.Array]:
    """log(1 + A) = sum_{k>=1} (-1)^{k+1} A^{⊗k} / k, truncated (paper §3.3)."""
    depth = len(s)
    power = list(s)                   # A^1, min level 1
    out = [lvl for lvl in s]          # k = 1 term
    for k in range(2, depth + 1):
        power = chen_mul(power, s, a0=0.0, b0=0.0, min_level_a=k - 1,
                         min_level_b=1)
        coef = ((-1) ** (k + 1)) / k
        out = [o + coef * p for o, p in zip(out, power)]
    return out


def tensor_inverse(s: list[jax.Array]) -> list[jax.Array]:
    """(1 + A)^{-1} = sum_{k>=0} (-A)^{⊗k}, truncated.

    For group-like elements this equals the signature of the time-reversed
    path (paper Lemma 4.5).
    """
    depth = len(s)
    neg = [-lvl for lvl in s]
    power = list(neg)
    out = list(neg)
    for k in range(2, depth + 1):
        power = chen_mul(power, neg, a0=0.0, b0=0.0, min_level_a=k - 1,
                         min_level_b=1)
        out = [o + p for o, p in zip(out, power)]
    return out


# ---------------------------------------------------------------------------
# naive reference signature engines (oracles + in-repo competitor baselines)
# ---------------------------------------------------------------------------

def path_increments(path: jax.Array) -> jax.Array:
    """(B, M+1, d) sampled path -> (B, M, d) increments ΔX_j."""
    return path[..., 1:, :] - path[..., :-1, :]


@partial(jax.jit, static_argnames=("depth",))
def signature_exp_chen(increments: jax.Array, depth: int) -> jax.Array:
    """Naive oracle: materialise exp(ΔX_j) and Chen-multiply along the path.

    This is the textbook recursion (paper eq. (2)) that pathsig's Horner
    scheme avoids; it is the correctness oracle for every other engine.
    Returns the flat (B, D_sig) truncated signature.
    """
    def step(levels, dx):
        e = tensor_exp(dx, depth)
        return chen_mul(levels, e), None

    init = zero_levels(increments.shape[:-2], increments.shape[-1], depth,
                       increments.dtype)
    final, _ = jax.lax.scan(step, init, jnp.moveaxis(increments, -2, 0))
    return levels_to_flat(final)


@partial(jax.jit, static_argnames=("depth",))
def signature_cumulative(increments: jax.Array, depth: int) -> jax.Array:
    """keras_sig-style baseline: returns ALL prefix signatures S_{0,t_j}.

    Shape (M, B, D_sig); memory O(B·M·D_sig) — the scaling the paper's Table 2
    contrasts against.  Used by benchmarks/table2_memory.py.
    """
    def step(levels, dx):
        new = chen_mul(levels, tensor_exp(dx, depth))
        return new, levels_to_flat(new)

    init = zero_levels(increments.shape[:-2], increments.shape[-1], depth,
                       increments.dtype)
    _, ys = jax.lax.scan(step, init, jnp.moveaxis(increments, -2, 0))
    return ys


def horner_step(levels: list[jax.Array], dx: jax.Array) -> list[jax.Array]:
    """One Chen update S <- S ⊗ exp(dx) in Horner form (paper Alg. 1).

    Never materialises exp(dx).  For each target level n:

        acc_1 = dx / n                       (innermost: S[eps]·ΔX^(i_1)/n)
        acc_j = (S^{(j-1)} + acc_{j-1}) ⊗ dx / (n-j+1),   j = 2..n
        S_new^{(n)} = S^{(n)} + acc_n

    which is the levelwise vectorisation of the paper's per-word Horner rule:
    coefficient w = (i_1..i_n) of acc_n equals
    ΔX^(i_n)(S[w_{1:n-1}] + ΔX^(i_{n-1})/2 (… + ΔX^(i_1)/n)).
    """
    depth = len(levels)
    new = []
    for n in range(1, depth + 1):
        acc = dx / n
        for j in range(2, n + 1):
            acc = _outer(levels[j - 2] + acc, dx) / (n - j + 1)
        new.append(levels[n - 1] + acc)
    return new


def inverse_horner_step(levels: list[jax.Array], dx: jax.Array) -> list[jax.Array]:
    """S ⊗ exp(-dx): exact inverse of horner_step (paper Prop. 4.6)."""
    return horner_step(levels, -dx)
