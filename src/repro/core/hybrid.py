"""Hybrid dense+word-table signature engine (beyond paper, §Perf kernel note).

Computes ALL coefficients of W_{<=N-1} with the dense levelwise-Horner
engine (pure reshape-broadcast — no gathers, and a gather/scatter-free VJP)
and only a prescribed set of level-N words via per-word Horner chains whose
prefixes are *read out of the dense buffer*.  This is exactly the shape of
the paper's §3.3 projected log-signature (all low levels + Lyndon_N), where
the generic word-table engine pays gather/scatter costs on every closure
row even though 40-60%% of the closure is simply "all words below N".

Memory law is unchanged: the custom VJP stores only the terminal state and
reconstructs backward via the group inverse (paper §4.2) — the top-level
coefficients invert as S_top_{j-1} = S_top_j − h(S_dense_{j-1}, ΔX_j).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ops as tops
from .words import Word, encode, level_offsets, sig_dim


@lru_cache(maxsize=None)
def _top_tables(d: int, depth: int, top_words: tuple[Word, ...]):
    """letters[K, depth] and dense-flat prefix indices[K, depth-1]."""
    K = len(top_words)
    offs = level_offsets(d, depth)
    letters = np.zeros((K, depth), np.int32)
    pidx = np.zeros((K, max(depth - 1, 1)), np.int32)
    for r, w in enumerate(top_words):
        assert len(w) == depth, (w, depth)
        for j, ch in enumerate(w):
            letters[r, j] = ch
        for j in range(1, depth):            # prefix w_{1:j}, flat index
            pidx[r, j - 1] = offs[j] + encode(w[:j], d)
    return letters, pidx


def _top_increment(flat_prev: jax.Array, dx: jax.Array, letters: np.ndarray,
                   pidx: np.ndarray, depth: int) -> jax.Array:
    """Horner chain h for each top word (paper Alg. 1), prefixes read from
    the dense flat buffer of the PREVIOUS step.  flat_prev: (B, D_{N-1});
    dx: (B, d) -> (B, K)."""
    # j = 1 (innermost): S[eps] = 1
    acc = jnp.take(dx, letters[:, 0], axis=1) / float(depth)
    for j in range(2, depth + 1):
        pfx = jnp.take(flat_prev, pidx[:, j - 2], axis=1)
        dxl = jnp.take(dx, letters[:, j - 1], axis=1)
        acc = (pfx + acc) * dxl / float(depth - j + 1)
    return acc


def _step(levels: list[jax.Array], top: jax.Array, dx: jax.Array,
          letters: np.ndarray, pidx: np.ndarray, depth: int):
    flat_prev = tops.levels_to_flat(levels)
    top = top + _top_increment(flat_prev, dx, letters, pidx, depth)
    levels = tops.horner_step(levels, dx)
    return levels, top


@lru_cache(maxsize=None)
def _make_hybrid(d: int, depth: int, top_words: tuple[Word, ...]):
    letters, pidx = _top_tables(d, depth, top_words)
    K = len(top_words)

    def scan(increments):
        B, M, _ = increments.shape
        init = (tops.zero_levels((B,), d, depth - 1, increments.dtype),
                jnp.zeros((B, K), increments.dtype))

        def body(carry, dx):
            levels, top = carry
            return _step(levels, top, dx, letters, pidx, depth), None

        (levels, top), _ = jax.lax.scan(body, init,
                                        jnp.moveaxis(increments, 1, 0))
        return jnp.concatenate([tops.levels_to_flat(levels), top], axis=1)

    @jax.custom_vjp
    def hybrid(increments):
        return scan(increments)

    def fwd(increments):
        out = hybrid(increments)
        return out, (increments, out)

    def bwd(res, g):
        increments, out = res
        B, M, _ = increments.shape
        lown = sig_dim(d, depth - 1)
        S_lv = tops.flat_to_levels(out[:, :lown], d, depth - 1)
        S_top = out[:, lown:]
        G_lv = tops.flat_to_levels(g[:, :lown], d, depth - 1)
        G_top = g[:, lown:]

        def step_fn(levels, top, dx):
            return _step(levels, top, dx, letters, pidx, depth)

        def body(carry, dx):
            (S, T), (Gl, Gt) = carry
            S_prev = tops.horner_step(S, -dx)             # Prop. 4.6
            flat_prev = tops.levels_to_flat(S_prev)
            T_prev = T - _top_increment(flat_prev, dx, letters, pidx, depth)
            _, vjp_fn = jax.vjp(step_fn, S_prev, T_prev, dx)
            Gl_p, Gt_p, g_dx = vjp_fn((Gl, Gt))
            return ((S_prev, T_prev), (Gl_p, Gt_p)), g_dx

        (_, _), g_rev = jax.lax.scan(body, ((S_lv, S_top), (G_lv, G_top)),
                                     jnp.moveaxis(increments, 1, 0),
                                     reverse=True)
        return (jnp.moveaxis(g_rev, 0, 1),)

    hybrid.defvjp(fwd, bwd)
    return hybrid


def hybrid_low_plus_top(increments: jax.Array, top_words, depth: int,
                        *, backward: str = "inverse") -> jax.Array:
    """(B, M, d) -> (B, D_{N-1} + K): the full W_{<=N-1} coefficient block
    (level-major flat order) concatenated with the level-N `top_words`.

    `backward="inverse"` uses the O(B·D) reconstruction VJP; "autodiff"
    differentiates through the scan (O(M·B·D) — baseline/testing).
    """
    if depth < 2:
        raise ValueError("hybrid engine needs depth >= 2 (no dense part "
                         "below depth 1)")
    d = increments.shape[-1]
    top_words = tuple(tuple(w) for w in top_words)
    if backward == "autodiff":
        letters, pidx = _top_tables(d, depth, top_words)
        B, M, _ = increments.shape
        init = (tops.zero_levels((B,), d, depth - 1, increments.dtype),
                jnp.zeros((B, len(top_words)), increments.dtype))

        def body(carry, dx):
            levels, top = carry
            return _step(levels, top, dx, letters, pidx, depth), None

        (levels, top), _ = jax.lax.scan(body, init,
                                        jnp.moveaxis(increments, 1, 0))
        return jnp.concatenate([tops.levels_to_flat(levels), top], axis=1)
    return _make_hybrid(d, depth, top_words)(increments)
