"""Signature projections onto arbitrary word sets (paper §3.1, §7).

The engine updates the coefficients of the *prefix closure* of a requested
word set I with the per-word Horner rule (paper Alg. 1), exactly as the CUDA
kernels do, but vectorised over (batch, closure-rows).  All index tables come
from :func:`repro.core.words.make_plan` on the host.

Coefficient buffer layout: ``S`` has shape (B, 1 + W) where row 0 is the
constant S[eps] = 1 and row 1..W are the closure words in level-major order.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from .signature import (_fold_chunks, _subsample_stream, as_lengths,
                        default_chunk, mask_increments, stream_emit_mask,
                        stream_emit_steps, unsupported_stream_backward)
from .words import WordPlan, make_plan
from . import tensor_ops as tops


def _plan_tables(plan: WordPlan):
    # NB: numpy, not jnp — these tables are captured by lru_cached closures,
    # and jnp constants materialised inside a jit trace would leak tracers.
    return (np.asarray(plan.prefix_idx), np.asarray(plan.letters),
            np.asarray(plan.inv), np.asarray(plan.emit))


def projected_step(S: jax.Array, dx: jax.Array, prefix_idx, letters, inv,
                   emit) -> jax.Array:
    """One Chen update of all closure coefficients (paper Alg. 1, batched).

    S: (B, 1+W) with S[:, 0] == 1;  dx: (B, d).
    """
    depth = prefix_idx.shape[1]
    B = S.shape[0]
    acc = jnp.zeros((B, prefix_idx.shape[0]), S.dtype)
    h = acc
    for j in range(depth):  # static unroll over Horner steps
        pfx = jnp.take(S, prefix_idx[:, j], axis=1)       # S_old[w_{1:j}]
        dxl = jnp.take(dx, letters[:, j], axis=1)         # ΔX^(i_{j+1})
        acc = (pfx + acc) * dxl * inv[None, :, j]         # /(n - j)
        h = h + acc * emit[None, :, j]                    # collect at j = n-1
    return S.at[:, 1:].add(h)


def _scan_projected(increments: jax.Array, plan: WordPlan,
                    stream: bool, stream_stride: int = 1) -> jax.Array:
    B, M, d = increments.shape
    tables = _plan_tables(plan)

    def step(S, dx):
        new = projected_step(S, dx, *tables)
        return new, (new if stream else None)

    S0 = jnp.concatenate([jnp.ones((B, 1), increments.dtype),
                          jnp.zeros((B, plan.closure_size), increments.dtype)],
                         axis=1)
    final, ys = jax.lax.scan(step, S0, jnp.moveaxis(increments, 1, 0))
    out_rows = jnp.asarray(plan.out_rows)
    if stream:
        out = jnp.moveaxis(jnp.take(ys, out_rows, axis=2), 0, 1)
        return _subsample_stream(out, M, stream_stride)
    return jnp.take(final, out_rows, axis=1)


def _closure_init(B: int, plan: WordPlan, dtype) -> jax.Array:
    return jnp.concatenate([jnp.ones((B, 1), dtype),
                            jnp.zeros((B, plan.closure_size), dtype)], axis=1)


def projected_inverse_bwd_scan(increments: jax.Array, S_T: jax.Array,
                               g_out: jax.Array, plan: WordPlan) -> jax.Array:
    """§4.2 backward for word projections: invert the closure update step by
    step (the closure is prefix-closed, so the inverse step is exact) while
    accumulating cotangents.  ``S_T`` is the terminal closure buffer
    (B, 1 + W); any forward that produces it (JAX scan or the Pallas word
    kernel run over the closure) can pair with this backward."""
    tables = _plan_tables(plan)

    def step_fn(S, dx):
        return projected_step(S, dx, *tables)

    # scatter the projection cotangent back onto the closure buffer
    G_T = jnp.zeros_like(S_T).at[:, jnp.asarray(plan.out_rows)].add(g_out)

    def step(carry, dx):
        S, G = carry
        S_prev = step_fn(S, -dx)
        _, vjp_fn = jax.vjp(step_fn, S_prev, dx)
        G_prev, g_dx = vjp_fn(G)
        return (S_prev, G_prev), g_dx

    (_, _), g_rev = jax.lax.scan(step, (S_T, G_T),
                                 jnp.moveaxis(increments, 1, 0),
                                 reverse=True)
    return jnp.moveaxis(g_rev, 0, 1)


@lru_cache(maxsize=None)
def _make_projected_vjp(plan: WordPlan):
    tables = _plan_tables(plan)

    def step_fn(S, dx):
        return projected_step(S, dx, *tables)

    @jax.custom_vjp
    def proj(increments):
        return _scan_projected(increments, plan, stream=False)

    def fwd(increments):
        B, M, d = increments.shape

        def step(S, dx):
            return step_fn(S, dx), None

        S0 = _closure_init(B, plan, increments.dtype)
        S_T, _ = jax.lax.scan(step, S0, jnp.moveaxis(increments, 1, 0))
        out = jnp.take(S_T, jnp.asarray(plan.out_rows), axis=1)
        return out, (increments, S_T)

    def bwd(res, g_out):
        increments, S_T = res
        return (projected_inverse_bwd_scan(increments, S_T, g_out, plan),)

    proj.defvjp(fwd, bwd)
    return proj


def projected_stream_inverse_bwd_scan(increments: jax.Array, S_T: jax.Array,
                                      g_steps: jax.Array, plan: WordPlan,
                                      stride: int = 1) -> jax.Array:
    """§4.2 backward for *streamed* word projections: cotangents arrive at
    every emitted step; one reverse scan inverts the closure update while
    folding each step's (closure-scattered) cotangent in just before the
    pull-back.  ``S_T`` is the terminal closure buffer (B, 1 + W) — the only
    residual besides the increments, whichever forward produced it (JAX scan
    or the streamed Pallas word kernel over the closure)."""
    B, M, d = increments.shape
    tables = _plan_tables(plan)
    out_rows = jnp.asarray(plan.out_rows)

    def step_fn(S, dx):
        return projected_step(S, dx, *tables)

    # scatter the per-step projection cotangents onto closure buffers, then
    # (for stride > 1) onto the full time axis
    g_close = jnp.zeros((*g_steps.shape[:2], S_T.shape[-1]), g_steps.dtype
                        ).at[:, :, out_rows].add(g_steps)
    steps = stream_emit_steps(M, stride)
    if len(steps) == M:
        g_dense = g_close
    else:
        g_dense = jnp.zeros((B, M, S_T.shape[-1]), g_steps.dtype
                            ).at[:, jnp.asarray(steps)].set(g_close)

    def step(carry, xs):
        S, G = carry
        dx, g_j = xs
        G = G + g_j
        S_prev = step_fn(S, -dx)
        _, vjp_fn = jax.vjp(step_fn, S_prev, dx)
        G_prev, g_dx = vjp_fn(G)
        return (S_prev, G_prev), g_dx

    (_, _), g_rev = jax.lax.scan(step, (S_T, jnp.zeros_like(S_T)),
                                 (jnp.moveaxis(increments, 1, 0),
                                  jnp.moveaxis(g_dense, 1, 0)), reverse=True)
    return jnp.moveaxis(g_rev, 0, 1)


@lru_cache(maxsize=None)
def _make_projected_stream_vjp(plan: WordPlan, stride: int):
    tables = _plan_tables(plan)

    @jax.custom_vjp
    def proj(increments):
        return _scan_projected(increments, plan, stream=True,
                               stream_stride=stride)

    def fwd(increments):
        B, M, d = increments.shape
        out_rows = jnp.asarray(plan.out_rows)

        def step(S, dx):
            new = projected_step(S, dx, *tables)
            return new, jnp.take(new, out_rows, axis=1)

        S_T, ys = jax.lax.scan(step, _closure_init(B, plan, increments.dtype),
                               jnp.moveaxis(increments, 1, 0))
        out = _subsample_stream(jnp.moveaxis(ys, 0, 1), M, stride)
        return out, (increments, S_T)

    def bwd(res, g_steps):
        increments, S_T = res
        return (projected_stream_inverse_bwd_scan(increments, S_T, g_steps,
                                                  plan, stride),)

    proj.defvjp(fwd, bwd)
    return proj


@lru_cache(maxsize=None)
def _make_projected_checkpoint_vjp(plan: WordPlan, chunk: int):
    """√M-checkpoint VJP for projections (beyond paper): store closure states
    at chunk boundaries, recompute within chunks on the backward — immune to
    inverse-reconstruction drift on very long paths."""
    tables = _plan_tables(plan)

    def chunk_fn(S, incs):  # incs: (c, B, d)
        def step(S, dx):
            return projected_step(S, dx, *tables), None
        out, _ = jax.lax.scan(step, S, incs)
        return out

    def fold(increments):
        return _fold_chunks(increments, chunk)

    @jax.custom_vjp
    def proj(increments):
        return _scan_projected(increments, plan, stream=False)

    def fwd(increments):
        B, M, d = increments.shape
        incs = fold(increments)

        def outer(S, c_incs):
            return chunk_fn(S, c_incs), S  # boundary BEFORE the chunk

        S_T, boundaries = jax.lax.scan(outer, _closure_init(
            B, plan, increments.dtype), incs)
        out = jnp.take(S_T, jnp.asarray(plan.out_rows), axis=1)
        return out, (increments, boundaries)

    def bwd(res, g_out):
        increments, boundaries = res
        B, M, d = increments.shape
        incs = fold(increments)
        n_chunks = incs.shape[0]
        G = jnp.zeros((B, 1 + plan.closure_size), g_out.dtype
                      ).at[:, jnp.asarray(plan.out_rows)].add(g_out)

        def outer(G, xs):
            bound, c_incs = xs
            _, vjp_fn = jax.vjp(chunk_fn, bound, c_incs)
            G_prev, g_incs = vjp_fn(G)
            return G_prev, g_incs

        _, g_rev = jax.lax.scan(outer, G, (boundaries, incs), reverse=True)
        g = jnp.moveaxis(g_rev.reshape(n_chunks * chunk, B, d), 0, 1)
        return (g[:, :M],)

    proj.defvjp(fwd, bwd)
    return proj


def projected_signature_from_increments(increments: jax.Array,
                                        plan: WordPlan, *,
                                        stream: bool = False,
                                        stream_stride: int = 1,
                                        backward: str = "inverse",
                                        backend: str = "jax",
                                        lengths=None, transform=None,
                                        x0=None,
                                        precision: str = "fp32") -> jax.Array:
    """π_I(S_{0,T}(X)) for the plan's word set I.  (B, M, d) -> (B, |I|).

    ``backend`` other than ``"jax"`` routes through the engine dispatch in
    :mod:`repro.kernels.ops` — including ``stream=True``, which emits every
    ``stream_stride``-th per-step projection as (B, M_out, |I|).
    ``lengths`` (B,) makes the batch ragged (zero-masked padded tails,
    exact terminals, masked post-end emissions, zero grads past the end).
    ``transform`` fuses path transforms into the sweep (the plan must be
    over the AUGMENTED alphabet; ``x0=`` is the path start, needed iff the
    transform has a basepoint); ``precision`` is ``"fp32"`` | ``"bf16_fp32"``
    — both route through :func:`repro.kernels.ops.projected`.
    """
    from .transforms import as_transform
    from .signature import canon_precision
    increments, squeeze = _as_batched(increments)
    spec = as_transform(transform)
    precision = canon_precision(precision)
    if backend != "jax" or spec is not None or precision != "fp32":
        from repro.kernels import ops  # deferred: ops imports this module
        out = ops.projected(increments, plan, backend=backend,
                            backward=backward, stream=stream,
                            stream_stride=stream_stride, lengths=lengths,
                            transform=spec, x0=x0, precision=precision)
        return out[0] if squeeze else out
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
        increments = mask_increments(increments, lengths)
    if stream:
        if backward == "inverse":
            out = _make_projected_stream_vjp(plan, stream_stride)(increments)
        elif backward == "autodiff":
            out = _scan_projected(increments, plan, stream=True,
                                  stream_stride=stream_stride)
        elif backward == "checkpoint":
            raise unsupported_stream_backward(backward)
        else:
            raise ValueError(f"unknown backward mode {backward!r}")
        if lengths is not None and increments.shape[1]:
            out = out * stream_emit_mask(
                increments.shape[1], stream_stride,
                lengths)[..., None].astype(out.dtype)
    elif backward == "autodiff":
        out = _scan_projected(increments, plan, stream=False)
    elif backward == "inverse":
        out = _make_projected_vjp(plan)(increments)
    elif backward == "checkpoint":
        out = _make_projected_checkpoint_vjp(
            plan, default_chunk(increments.shape[1]))(increments)
    else:
        raise ValueError(f"unknown backward mode {backward!r}")
    return out[0] if squeeze else out


def projected_signature(path: jax.Array, words, d: int | None = None, *,
                        plan: WordPlan | None = None, stream: bool = False,
                        stream_stride: int = 1, backward: str = "inverse",
                        backend: str = "jax", lengths=None, transform=None,
                        precision: str = "fp32") -> jax.Array:
    """Signature coefficients of an arbitrary word set (paper §7.1).

    ``words`` is an iterable of letter tuples (0-based) or a prebuilt plan.
    ``lengths`` (B,) makes the batch ragged; a
    :class:`repro.ragged.RaggedPaths` may be passed directly as ``path``.
    ``transform`` fuses path transforms into the sweep; the words (and any
    prebuilt ``plan``) must be over the AUGMENTED alphabet — when ``d`` is
    omitted it defaults to the augmented channel count.  The basepoint start
    ``x0`` is taken from the path automatically.  ``precision`` is
    ``"fp32"`` | ``"bf16_fp32"``.
    """
    from .signature import _unpack_ragged
    from .transforms import as_transform, transform_dim
    values, rl = _unpack_ragged(path)
    if rl is not None and lengths is None:
        lengths = rl
    path, squeeze = _as_batched(values)
    spec = as_transform(transform)
    if plan is None:
        if d is None:
            d = transform_dim(spec, path.shape[-1])
        plan = make_plan(tuple(tuple(w) for w in words), d)
    incs = tops.path_increments(path)
    x0 = path[:, 0] if spec is not None and spec.basepoint else None
    out = projected_signature_from_increments(incs, plan, stream=stream,
                                              stream_stride=stream_stride,
                                              backward=backward,
                                              backend=backend,
                                              lengths=lengths, transform=spec,
                                              x0=x0, precision=precision)
    return out[0] if squeeze else out


def _as_batched(x: jax.Array) -> tuple[jax.Array, bool]:
    if x.ndim == 2:
        return x[None], True
    if x.ndim == 3:
        return x, False
    raise ValueError(f"expected (M, d) or (B, M, d), got {x.shape}")
