"""Path transforms used with signatures (paper §8 and standard practice).

All three transforms take an optional ``lengths=`` (B,) for ragged (padded)
batches.  Without it, a padded batch is silently corrupted: the time channel
keeps climbing over the padded tail and lead-lag interleaves the garbage
points.  With it, each transform (a) freezes the padded tail at the
example's true endpoint so the transformed tail has zero increments, and
(b) returns ``(path, new_lengths)`` — the length bookkeeping every
transform implies (``time_augment`` keeps lengths, ``lead_lag`` doubles
them, ``basepoint_augment`` adds one increment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .signature import as_lengths


def freeze_tail(path: jax.Array, lengths) -> jax.Array:
    """(B, M+1, d) padded batch -> same batch with every point past each
    example's true end replaced by its true endpoint X_{L_b} (so the padded
    tail has exactly zero increments)."""
    B, M1, _ = path.shape
    lengths = as_lengths(lengths, B)
    idx = jnp.minimum(jnp.arange(M1, dtype=jnp.int32)[None, :],
                      lengths[:, None])
    return jnp.take_along_axis(path, idx[..., None], axis=1)


def lead_lag(path: jax.Array, lengths=None):
    """Lead-lag transform (paper Def. 8.1): (B, M+1, d) -> (B, 2M+1, 2d).

    Channel order: [lag_1..lag_d, lead_1..lead_d], i.e. hat{X}_{2k} =
    (X_k, X_k), hat{X}_{2k+1} = (X_k, X_{k+1}).

    With ``lengths``, the interleave stops at each example's true end (the
    tail is frozen first) and the return is ``(path, 2·lengths)``.
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = lead_lag(path[None], lengths)
            return out[0], nl
        return lead_lag(path[None])[0]
    if lengths is not None:
        lengths = as_lengths(lengths, path.shape[0])
        path = freeze_tail(path, lengths)
    B, M1, d = path.shape
    M = M1 - 1
    lag_even, lead_even = path[:, :-1], path[:, :-1]     # k = 0..M-1
    lag_odd, lead_odd = path[:, :-1], path[:, 1:]
    even = jnp.concatenate([lag_even, lead_even], axis=-1)  # (B, M, 2d)
    odd = jnp.concatenate([lag_odd, lead_odd], axis=-1)
    inter = jnp.stack([even, odd], axis=2).reshape(B, 2 * M, 2 * d)
    last = jnp.concatenate([path[:, -1:], path[:, -1:]], axis=-1)
    out = jnp.concatenate([inter, last], axis=1)
    if lengths is not None:
        return out, 2 * lengths
    return out


def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0,
                 lengths=None):
    """Append a monotone time channel: (B, M+1, d) -> (B, M+1, d+1).

    With ``lengths``, the time channel runs t0 -> t1 over each example's
    TRUE span (t1 is reached at point L_b, then held — zero increments past
    the end) and the return is ``(path, lengths)``.
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = time_augment(path[None], t0, t1, lengths)
            return out[0], nl
        return time_augment(path[None], t0, t1)[0]
    B, M1, _ = path.shape
    if lengths is None:
        t = jnp.linspace(t0, t1, M1, dtype=path.dtype)[None, :, None]
        return jnp.concatenate([jnp.broadcast_to(t, (B, M1, 1)), path],
                               axis=-1)
    lengths = as_lengths(lengths, B)
    path = freeze_tail(path, lengths)
    k = jnp.arange(M1, dtype=path.dtype)[None, :]
    frac = jnp.minimum(k, lengths[:, None].astype(path.dtype)) \
        / jnp.maximum(lengths[:, None].astype(path.dtype), 1.0)
    t = (t0 + (t1 - t0) * frac)[..., None].astype(path.dtype)
    return jnp.concatenate([t, path], axis=-1), lengths


def basepoint_augment(path: jax.Array, lengths=None):
    """Prepend X = 0 so the signature sees the starting level.

    With ``lengths``, the tail is frozen and the return is
    ``(path, lengths + 1)`` (the prepended point adds one increment).
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = basepoint_augment(path[None], lengths)
            return out[0], nl
        return basepoint_augment(path[None])[0]
    if lengths is not None:
        lengths = as_lengths(lengths, path.shape[0])
        path = freeze_tail(path, lengths)
    out = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
    if lengths is not None:
        return out, lengths + 1
    return out


def sparse_leadlag_generators(d: int) -> list[tuple[int, ...]]:
    """Generator set G of paper §8 for independent components.

    Channels: 0..d-1 = lag (ell_i), d..2d-1 = lead (L_i).
    G = {(L_i)} ∪ {(ell_i, L_i), (L_i, ell_i)}.
    """
    gens: list[tuple[int, ...]] = [(d + i,) for i in range(d)]
    for i in range(d):
        gens.append((i, d + i))
        gens.append((d + i, i))
    return gens
