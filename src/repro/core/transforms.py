"""Path transforms used with signatures (paper §8 and standard practice)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def lead_lag(path: jax.Array) -> jax.Array:
    """Lead-lag transform (paper Def. 8.1): (B, M+1, d) -> (B, 2M+1, 2d).

    Channel order: [lag_1..lag_d, lead_1..lead_d], i.e. hat{X}_{2k} =
    (X_k, X_k), hat{X}_{2k+1} = (X_k, X_{k+1}).
    """
    if path.ndim == 2:
        return lead_lag(path[None])[0]
    B, M1, d = path.shape
    M = M1 - 1
    lag_even, lead_even = path[:, :-1], path[:, :-1]     # k = 0..M-1
    lag_odd, lead_odd = path[:, :-1], path[:, 1:]
    even = jnp.concatenate([lag_even, lead_even], axis=-1)  # (B, M, 2d)
    odd = jnp.concatenate([lag_odd, lead_odd], axis=-1)
    inter = jnp.stack([even, odd], axis=2).reshape(B, 2 * M, 2 * d)
    last = jnp.concatenate([path[:, -1:], path[:, -1:]], axis=-1)
    return jnp.concatenate([inter, last], axis=1)


def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0) -> jax.Array:
    """Append a monotone time channel: (B, M+1, d) -> (B, M+1, d+1)."""
    if path.ndim == 2:
        return time_augment(path[None], t0, t1)[0]
    B, M1, _ = path.shape
    t = jnp.linspace(t0, t1, M1, dtype=path.dtype)[None, :, None]
    return jnp.concatenate([jnp.broadcast_to(t, (B, M1, 1)), path], axis=-1)


def basepoint_augment(path: jax.Array) -> jax.Array:
    """Prepend X = 0 so the signature sees the starting level."""
    if path.ndim == 2:
        return basepoint_augment(path[None])[0]
    return jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)


def sparse_leadlag_generators(d: int) -> list[tuple[int, ...]]:
    """Generator set G of paper §8 for independent components.

    Channels: 0..d-1 = lag (ell_i), d..2d-1 = lead (L_i).
    G = {(L_i)} ∪ {(ell_i, L_i), (L_i, ell_i)}.
    """
    gens: list[tuple[int, ...]] = [(d + i,) for i in range(d)]
    for i in range(d):
        gens.append((i, d + i))
        gens.append((d + i, i))
    return gens
