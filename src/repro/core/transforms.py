"""Path transforms used with signatures (paper §8 and standard practice).

All three transforms take an optional ``lengths=`` (B,) for ragged (padded)
batches.  Without it, a padded batch is silently corrupted: the time channel
keeps climbing over the padded tail and lead-lag interleaves the garbage
points.  With it, each transform (a) freezes the padded tail at the
example's true endpoint so the transformed tail has zero increments, and
(b) returns ``(path, new_lengths)`` — the length bookkeeping every
transform implies (``time_augment`` keeps lengths, ``lead_lag`` doubles
them, ``basepoint_augment`` adds one increment).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .signature import as_lengths, mask_increments


def freeze_tail(path: jax.Array, lengths) -> jax.Array:
    """(B, M+1, d) padded batch -> same batch with every point past each
    example's true end replaced by its true endpoint X_{L_b} (so the padded
    tail has exactly zero increments)."""
    B, M1, _ = path.shape
    lengths = as_lengths(lengths, B)
    idx = jnp.minimum(jnp.arange(M1, dtype=jnp.int32)[None, :],
                      lengths[:, None])
    return jnp.take_along_axis(path, idx[..., None], axis=1)


def lead_lag(path: jax.Array, lengths=None):
    """Lead-lag transform (paper Def. 8.1): (B, M+1, d) -> (B, 2M+1, 2d).

    Channel order: [lag_1..lag_d, lead_1..lead_d], i.e. hat{X}_{2k} =
    (X_k, X_k), hat{X}_{2k+1} = (X_k, X_{k+1}).

    With ``lengths``, the interleave stops at each example's true end (the
    tail is frozen first) and the return is ``(path, 2·lengths)``.
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = lead_lag(path[None], lengths)
            return out[0], nl
        return lead_lag(path[None])[0]
    if lengths is not None:
        lengths = as_lengths(lengths, path.shape[0])
        path = freeze_tail(path, lengths)
    B, M1, d = path.shape
    M = M1 - 1
    lag_even, lead_even = path[:, :-1], path[:, :-1]     # k = 0..M-1
    lag_odd, lead_odd = path[:, :-1], path[:, 1:]
    even = jnp.concatenate([lag_even, lead_even], axis=-1)  # (B, M, 2d)
    odd = jnp.concatenate([lag_odd, lead_odd], axis=-1)
    inter = jnp.stack([even, odd], axis=2).reshape(B, 2 * M, 2 * d)
    last = jnp.concatenate([path[:, -1:], path[:, -1:]], axis=-1)
    out = jnp.concatenate([inter, last], axis=1)
    if lengths is not None:
        return out, 2 * lengths
    return out


def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0,
                 lengths=None):
    """Append a monotone time channel: (B, M+1, d) -> (B, M+1, d+1).

    With ``lengths``, the time channel runs t0 -> t1 over each example's
    TRUE span (t1 is reached at point L_b, then held — zero increments past
    the end) and the return is ``(path, lengths)``.
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = time_augment(path[None], t0, t1, lengths)
            return out[0], nl
        return time_augment(path[None], t0, t1)[0]
    B, M1, _ = path.shape
    if lengths is None:
        t = jnp.linspace(t0, t1, M1, dtype=path.dtype)[None, :, None]
        return jnp.concatenate([jnp.broadcast_to(t, (B, M1, 1)), path],
                               axis=-1)
    lengths = as_lengths(lengths, B)
    path = freeze_tail(path, lengths)
    k = jnp.arange(M1, dtype=path.dtype)[None, :]
    frac = jnp.minimum(k, lengths[:, None].astype(path.dtype)) \
        / jnp.maximum(lengths[:, None].astype(path.dtype), 1.0)
    t = (t0 + (t1 - t0) * frac)[..., None].astype(path.dtype)
    return jnp.concatenate([t, path], axis=-1), lengths


def basepoint_augment(path: jax.Array, lengths=None):
    """Prepend X = 0 so the signature sees the starting level.

    With ``lengths``, the tail is frozen and the return is
    ``(path, lengths + 1)`` (the prepended point adds one increment).
    """
    if path.ndim == 2:
        if lengths is not None:
            out, nl = basepoint_augment(path[None], lengths)
            return out[0], nl
        return basepoint_augment(path[None])[0]
    if lengths is not None:
        lengths = as_lengths(lengths, path.shape[0])
        path = freeze_tail(path, lengths)
    out = jnp.concatenate([jnp.zeros_like(path[:, :1]), path], axis=1)
    if lengths is not None:
        return out, lengths + 1
    return out


# ---------------------------------------------------------------------------
# Transform spec: the composable description the fused kernels understand.
#
# The engines in repro.kernels build each *augmented increment* on the fly
# inside the time loop (registers / VMEM), so the (B, M_aug, d_aug)
# intermediate of the path-level functions above never exists.  The functions
# above stay as the materialising oracle; everything below is the shared
# bookkeeping both sides agree on.
#
# Canonical composition order (matching the oracle):
#   basepoint  ->  lead_lag  ->  time_augment
# so the final channel layout is [t, lag_1..lag_d, lead_1..lead_d] (or the
# obvious subsets).  At increment level:
#   * basepoint prepends one increment equal to X_0 (the path start);
#   * lead_lag maps raw increment g_j to two sub-increments:
#       phase 0: (lag = 0,   lead = g_j)      # lead moves first
#       phase 1: (lag = g_j, lead = 0)
#   * time_augment prepends a constant-dt channel, dt = (t1-t0)/M_aug
#     (per-example dt = (t1-t0)/len_aug for ragged batches, zero past the
#     true end — exactly the oracle's frozen-tail time column).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Transform:
    """Composable path-transform spec (hashable: usable as a static/jit arg).

    ``basepoint`` prepends X = 0; ``lead_lag`` doubles channels and steps;
    ``time`` prepends a monotone t0 -> t1 channel.  Parse user input with
    :func:`as_transform`.
    """
    basepoint: bool = False
    lead_lag: bool = False
    time: bool = False
    t0: float = 0.0
    t1: float = 1.0

    def __bool__(self) -> bool:
        return self.basepoint or self.lead_lag or self.time

    @property
    def sub_steps(self) -> int:
        """Augmented increments produced per raw increment."""
        return 2 if self.lead_lag else 1


_TRANSFORM_NAMES = {
    "basepoint": "basepoint",
    "basepoint_augment": "basepoint",
    "lead_lag": "lead_lag",
    "leadlag": "lead_lag",
    "time": "time",
    "time_augment": "time",
}


def as_transform(spec) -> Transform | None:
    """Normalise a ``transform=`` argument.

    Accepts ``None``, a :class:`Transform`, a name (``"time_augment"`` |
    ``"lead_lag"`` | ``"basepoint"``), a ``"+"``-joined combination
    (``"time_augment+lead_lag"``), or an iterable of names.  Returns ``None``
    for the identity transform.
    """
    if spec is None:
        return None
    if isinstance(spec, Transform):
        return spec if spec else None
    if isinstance(spec, str):
        spec = [p for p in spec.replace(",", "+").split("+") if p]
    flags: dict[str, bool] = {}
    for name in spec:
        key = _TRANSFORM_NAMES.get(str(name).strip().lower())
        if key is None:
            raise ValueError(
                f"unknown transform {name!r}: expected one of "
                f"{sorted(set(_TRANSFORM_NAMES))}")
        flags[key] = True
    return Transform(**flags) if flags else None


def transform_dim(spec, d: int) -> int:
    """Augmented channel count d_aug for raw channel count d."""
    spec = as_transform(spec)
    if spec is None:
        return d
    return (2 * d if spec.lead_lag else d) + (1 if spec.time else 0)


def transform_steps(spec, M: int) -> int:
    """Augmented increment count M_aug for raw increment count M."""
    spec = as_transform(spec)
    if spec is None:
        return M
    return (M + int(spec.basepoint)) * spec.sub_steps


def transform_lengths(spec, lengths):
    """Per-example augmented increment counts for raw ``lengths`` (B,)."""
    spec = as_transform(spec)
    if spec is None or lengths is None:
        return lengths
    return (lengths + int(spec.basepoint)) * spec.sub_steps


def apply_transform(path: jax.Array, spec, lengths=None):
    """Path-level (materialising) application of ``spec`` — the oracle the
    fused engines are tested against.  Returns ``path`` or
    ``(path, new_lengths)`` when ``lengths`` is given."""
    spec = as_transform(spec)
    if spec is None:
        return path if lengths is None else (path, lengths)
    if spec.basepoint:
        out = basepoint_augment(path, lengths)
        path, lengths = out if lengths is not None else (out, None)
    if spec.lead_lag:
        out = lead_lag(path, lengths)
        path, lengths = out if lengths is not None else (out, None)
    if spec.time:
        out = time_augment(path, spec.t0, spec.t1, lengths)
        path, lengths = out if lengths is not None else (out, None)
    return path if lengths is None else (path, lengths)


def transform_time_aux(spec, B: int, n_steps: int, lengths=None,
                       dtype=jnp.float32) -> jax.Array:
    """(B, 2) per-example ``[dt, n_valid_aug]`` aux the fused engines read.

    ``n_steps`` counts increments AFTER any basepoint prepend (so does
    ``lengths`` when given).  Step ``ja`` of the augmented path gets time
    increment ``dt * (ja < n_valid_aug)``, which reproduces the oracle's
    frozen-tail time column exactly.
    """
    spec = as_transform(spec)
    sub = spec.sub_steps if spec is not None else 1
    if lengths is None:
        n_valid = jnp.full((B,), sub * n_steps, dtype)
    else:
        n_valid = (sub * as_lengths(lengths, B)).astype(dtype)
    t0, t1 = (spec.t0, spec.t1) if spec is not None else (0.0, 1.0)
    dt = (t1 - t0) / jnp.maximum(n_valid, 1.0)
    return jnp.stack([dt, n_valid], axis=-1).astype(dtype)


def fused_augment(increments: jax.Array, taux, spec) -> jax.Array:
    """Increment-level materialisation of the lead_lag/time part of ``spec``
    (basepoint must already be prepended): (B, M, d) -> (B, M_aug, d_aug).

    This is what the fused engines compute step-by-step without ever
    building; the custom-VJP backwards materialise it transiently to reuse
    the §4.2 reverse sweeps, then pull the cotangent back through
    :func:`fused_adjoint`.  ``taux`` is :func:`transform_time_aux` output
    (ignored unless ``spec.time``).
    """
    spec = as_transform(spec)
    g = increments
    if spec is None:
        return g
    B, M, d = g.shape
    if spec.lead_lag:
        z = jnp.zeros_like(g)
        lead = jnp.concatenate([z, g], axis=-1)   # phase 0: lead moves
        lag = jnp.concatenate([g, z], axis=-1)    # phase 1: lag moves
        g = jnp.stack([lead, lag], axis=2).reshape(B, 2 * M, 2 * d)
    if spec.time:
        M_aug = g.shape[1]
        dt, n_valid = taux[:, 0], taux[:, 1]
        valid = jnp.arange(M_aug, dtype=n_valid.dtype)[None, :] < n_valid[:, None]
        tcol = (dt[:, None] * valid.astype(g.dtype))[..., None]
        g = jnp.concatenate([tcol.astype(g.dtype), g], axis=-1)
    return g


def fused_adjoint(g_aug: jax.Array, spec, d: int) -> jax.Array:
    """Adjoint of :func:`fused_augment` in the raw increments: (B, M_aug,
    d_aug) cotangent -> (B, M, d).  The augment is linear, so this is exact:
    the time channel is dropped (dt is data-independent) and each raw step
    collects its lead-phase lead rows plus its lag-phase lag rows."""
    spec = as_transform(spec)
    g = g_aug
    if spec is None:
        return g
    if spec.time:
        g = g[..., 1:]
    if spec.lead_lag:
        B, M2, d2 = g.shape
        r = g.reshape(B, M2 // 2, 2, d2)
        g = r[:, :, 0, d:] + r[:, :, 1, :d]
    return g


def augment_increments(increments: jax.Array, spec, x0=None, lengths=None):
    """Full increment-level materialisation of ``spec`` including basepoint:
    (B, M, d) -> (B, M_aug, d_aug), equal (to float tolerance) to
    ``path_increments(apply_transform(path, spec, ...))``.

    ``x0`` (B, d) is the path start, required iff ``spec.basepoint`` (the
    basepoint increment is 0 -> X_0 = x0).  ``lengths`` are RAW increment
    counts; the padded tail is zero-masked first.  Returns
    ``(aug, aug_lengths)`` when ``lengths`` is given.
    """
    spec = as_transform(spec)
    B = increments.shape[0]
    if spec is None:
        if lengths is not None:
            return mask_increments(increments, lengths), as_lengths(lengths, B)
        return increments
    if lengths is not None:
        lengths = as_lengths(lengths, B)
        increments = mask_increments(increments, lengths)
    g = increments
    if spec.basepoint:
        if x0 is None:
            raise ValueError("transform with basepoint needs x0= (the path "
                             "start point, shape (B, d))")
        g = jnp.concatenate([x0[:, None, :].astype(g.dtype), g], axis=1)
    lengths_bp = None if lengths is None else lengths + int(spec.basepoint)
    taux = transform_time_aux(spec, B, g.shape[1], lengths_bp, g.dtype) \
        if spec.time else None
    aug = fused_augment(g, taux, spec)
    if lengths is None:
        return aug
    return aug, transform_lengths(spec, lengths)


def augment_adjoint(g_aug: jax.Array, spec, d: int):
    """Adjoint of :func:`augment_increments` in ``(increments, x0)``:
    returns ``(g_increments, g_x0)`` (``g_x0`` is None without basepoint)."""
    spec = as_transform(spec)
    if spec is None:
        return g_aug, None
    g = fused_adjoint(g_aug, spec, d)
    if spec.basepoint:
        return g[:, 1:], g[:, 0]
    return g, None


def sparse_leadlag_generators(d: int) -> list[tuple[int, ...]]:
    """Generator set G of paper §8 for independent components.

    Channels: 0..d-1 = lag (ell_i), d..2d-1 = lead (L_i).
    G = {(L_i)} ∪ {(ell_i, L_i), (L_i, ell_i)}.
    """
    gens: list[tuple[int, ...]] = [(d + i,) for i in range(d)]
    for i in range(d):
        gens.append((i, d + i))
        gens.append((d + i, i))
    return gens
