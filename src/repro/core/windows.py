"""Windowed signature computation (paper §5) with unified route selection.

Given index pairs (l_i, r_i), pathsig returns all S_{t_{l_i}, t_{r_i}}(X) in a
single evaluation.  Two physical routes compute the same answer:

- ``"fold"``  — materialise per-window increment slices (zero-padded to the
  longest window; zero increments are identity Chen updates, so padding is
  exact) and fold the window axis into the batch axis: windows become an
  extra axis of parallelism, exactly the paper's saturation argument.
  Work ∝ Σ-of-padded-lengths = K · L_max.
- ``"chen"``  — the Signatory-style identity S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r}
  over ONE streamed forward pass of the whole path (the engine dispatch's
  ``stream=True`` axis, so it runs on every backend and stays differentiable
  through the streamed §4.2 reverse sweep).  Work ∝ M + c·K — for heavily
  overlapping sliding windows this is O(M + K) instead of O(Σ L_i).

``route="auto"`` picks between them with a host-side cost model (windows are
host arrays, so the choice is static and free): the chen route wins when the
total padded sliced length exceeds the streamed pass plus the per-window
combines by a safety factor (the paper notes the chen route is numerically
delicate, so ties go to fold).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ops as tops
from .projection import projected_signature_from_increments
from .signature import (_unpack_ragged, as_lengths, mask_increments,
                        signature_combine, signature_from_increments,
                        signature_inverse)
from .transforms import as_transform
from .words import WordPlan, flat_index, sig_dim

ROUTES = ("auto", "fold", "chen")

# cost-model constants, calibrated against the measured BENCH_fig3.json grid
# (tests/test_windows.py::test_auto_route_within_15pct_of_best re-checks the
# calibration against the committed measurements):
#   * a streamed chen-route step costs ~_CHEN_STEP_COST fold-route scan steps
#     (the streamed pass emits + stores a prefix signature per stride, the
#     fold pass only accumulates) — implied unit costs from the fig3 records
#     are 1.45/2.17/2.33/3.22, median ~2.4;
#   * a window's inverse + Chen combine costs ~_CHEN_COMBINE_STEPS steps;
#   * the chen route must still win by _CHEN_ADVANTAGE before we accept its
#     numerics (S^{-1} ⊗ S cancellation on long prefixes) — a margin, not a
#     cost, now that _CHEN_STEP_COST carries the physics;
#   * the fold route pays a fixed dispatch cost the streamed pass does not
#     (window gather + (B, K, L, d) -> (B*K, L, d) reshape + fold-into-batch
#     launch), ~0.3 ms on the fig3 grid ≈ _FOLD_OVERHEAD_STEPS fold steps.
#     Without it the sub-millisecond records (small M·K) are unfittable: the
#     measured grid has chen winning at M=48 but losing at M=144, which no
#     pure work-ratio rule can reproduce.
_CHEN_COMBINE_STEPS = 4
_CHEN_STEP_COST = 2.5
_CHEN_ADVANTAGE = 1.5
_FOLD_OVERHEAD_STEPS = 256


def _check_windows(windows, M: int) -> np.ndarray:
    """Validate (K, 2) index pairs against a path of M increments."""
    windows_np = np.asarray(windows, dtype=np.int32).reshape(-1, 2)
    if windows_np.shape[0]:
        if (windows_np[:, 0] < 0).any() or (windows_np[:, 1] > M).any():
            raise ValueError(
                f"window indices must lie in [0, {M}] (M = number of path "
                f"increments); got {windows_np.tolist()}")
        if (windows_np[:, 0] > windows_np[:, 1]).any():
            raise ValueError(f"windows must satisfy l <= r; got "
                             f"{windows_np.tolist()}")
    return windows_np


def select_route(route: str, windows_np: np.ndarray, M: int,
                 chen_cost_scale: float = 1.0,
                 backward: str = "inverse") -> str:
    """Host-side cost model: fold work = K · L_max padded scan steps plus a
    fixed _FOLD_OVERHEAD_STEPS dispatch charge, chen work = one length-M
    streamed pass + ~_CHEN_COMBINE_STEPS steps per window, with each chen
    step costing _CHEN_STEP_COST fold steps
    (calibrated against BENCH_fig3.json measurements; scaled by
    ``chen_cost_scale`` when the streamed pass runs over a larger basis than
    the fold route, e.g. full truncation vs a small closure).

    ``backward="checkpoint"`` pins ``"auto"`` to the fold route: the chen
    route rides the streamed forward, which has no checkpoint backward (the
    support matrix in :mod:`repro.kernels.ops`)."""
    if route not in ROUTES:
        raise ValueError(f"unknown route {route!r}; expected one of {ROUTES}")
    if route != "auto":
        return route
    if windows_np.shape[0] == 0 or backward == "checkpoint":
        return "fold"
    lengths = windows_np[:, 1] - windows_np[:, 0]
    K, L_max = len(lengths), int(lengths.max())
    fold_work = K * max(L_max, 1) + _FOLD_OVERHEAD_STEPS
    chen_work = _CHEN_STEP_COST * (M + _CHEN_COMBINE_STEPS * K) \
        * chen_cost_scale
    return "chen" if fold_work > _CHEN_ADVANTAGE * chen_work else "fold"


def _window_increments(path: jax.Array, windows_np: np.ndarray,
                       lengths=None) -> jax.Array:
    """(B, M+1, d) x validated (K, 2) -> (B, K, L_max, d) zero-padded slices.

    ``windows_np`` must come from :func:`_check_windows` (host-side: shapes
    are static).  With ``lengths``, increments past each example's true end
    read as zero, so every window is exactly clipped to [l, min(r, L_b)].
    """
    L_max = int((windows_np[:, 1] - windows_np[:, 0]).max())
    windows = jnp.asarray(windows_np)
    K = windows.shape[0]
    incs = mask_increments(tops.path_increments(path), lengths)  # (B, M, d)
    M = incs.shape[1]
    lengths = windows[:, 1] - windows[:, 0]                # (K,)
    # gather indices: l_i + t, clamped; mask t >= length
    t = jnp.arange(L_max)[None, :]                         # (1, L)
    idx = jnp.clip(windows[:, :1] + t, 0, M - 1)           # (K, L)
    mask = (t < lengths[:, None]).astype(incs.dtype)       # (K, L)
    g = jnp.take(incs, idx.reshape(-1), axis=1)            # (B, K*L, d)
    g = g.reshape(incs.shape[0], K, L_max, incs.shape[2])
    return g * mask[None, :, :, None]


def _fold_window_ctx(path: jax.Array, windows_np: np.ndarray, spec,
                     lengths):
    """Per-window ragged context for the transform-fused fold route:
    -> (wlen (B, K) clipped window lengths, x0 (B, K, d) window starts).

    The transform applies PER WINDOW (each window is its own sub-path: time
    restarts at 0, lead-lag pairs don't straddle the window boundary, the
    basepoint is the window's first path value) — identical to calling
    ``signature(window_slice, transform=...)`` window by window.  Clipping
    follows the ragged semantics: window [l, r] on example b reads
    [min(l, L_b), min(r, L_b)].
    """
    B = path.shape[0]
    windows = jnp.asarray(windows_np)
    l_idx = jnp.broadcast_to(windows[None, :, 0], (B, windows.shape[0]))
    r_idx = jnp.broadcast_to(windows[None, :, 1], (B, windows.shape[0]))
    if lengths is not None:
        l_idx = jnp.minimum(l_idx, lengths[:, None])
        r_idx = jnp.minimum(r_idx, lengths[:, None])
    wlen = r_idx - l_idx
    x0 = None
    if spec is not None and spec.basepoint:
        x0 = jnp.take_along_axis(path, l_idx[..., None], axis=1)  # (B, K, d)
    return wlen, x0


def _chen_endpoint_states(path: jax.Array, windows_np: np.ndarray, depth: int,
                          backward: str, backend: str, lengths=None,
                          precision: str = "fp32"):
    """One streamed forward over the whole path -> (S_{0,l}, S_{0,r}) flats
    of shape (B, K, D_sig) each.  Differentiable on every backend via the
    streamed custom VJP in the dispatch layer.  With ``lengths``, increments
    are zero-masked first, so the streamed state freezes at each example's
    true terminal and S_{0,t} for t > L_b reads S_{0,L_b} — exactly the
    clipped-window semantics of the fold route."""
    incs = mask_increments(tops.path_increments(path), lengths)
    stream = signature_from_increments(incs, depth, stream=True,
                                       backward=backward, backend=backend,
                                       precision=precision)  # (B, M, D)
    # prepend the identity signature so index t reads S_{0,t} (t = 0 valid)
    ident = jnp.zeros_like(stream[:, :1])
    stream = jnp.concatenate([ident, stream], axis=1)       # (B, M+1, D)
    windows = jnp.asarray(windows_np)
    s_l = jnp.take(stream, windows[:, 0], axis=1)           # (B, K, D)
    s_r = jnp.take(stream, windows[:, 1], axis=1)
    return s_l, s_r


def _chen_route_signature(path: jax.Array, windows_np: np.ndarray, depth: int,
                          backward: str, backend: str, lengths=None,
                          precision: str = "fp32") -> jax.Array:
    """S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r} from the streamed forward."""
    d = path.shape[-1]
    s_l, s_r = _chen_endpoint_states(path, windows_np, depth, backward,
                                     backend, lengths, precision=precision)
    D = s_l.shape[-1]
    inv = signature_inverse(s_l.reshape(-1, D), d, depth)
    out = signature_combine(inv, s_r.reshape(-1, D), d, depth)
    return out.reshape(s_l.shape)


def _pin_transform_route(route: str, spec) -> str:
    """Transforms pin ``"auto"`` to the fold route: Chen's identity is over
    prefix signatures of ONE transformed path, but the per-window transform
    restarts time / lead-lag / basepoint at each window's own start, so
    S_{0,l}^{-1} ⊗ S_{0,r} of the transformed whole path is a DIFFERENT
    object than the transformed window's signature."""
    if spec is None:
        return route
    if route == "chen":
        raise NotImplementedError(
            "route='chen' cannot apply per-window transforms (the streamed "
            "prefix states are of the whole transformed path, not of each "
            "window's own transformed sub-path); use route='fold' or 'auto'")
    return "fold"


def windowed_signature(path: jax.Array, windows, depth: int, *,
                       route: str = "auto", backward: str = "inverse",
                       backend: str = "jax", lengths=None, transform=None,
                       precision: str = "fp32") -> jax.Array:
    """(B, M+1, d) x (K, 2) -> (B, K, D_sig) in one batched evaluation.

    ``route`` picks the physical plan (see module docstring): ``"fold"``
    slices + folds windows into the batch axis, ``"chen"`` combines endpoint
    states of one streamed pass, ``"auto"`` chooses by the host-side cost
    model.  Both routes ride the engine dispatch (:mod:`repro.kernels.ops`),
    so every backend's kernel forward + O(1)-in-length backward applies.  An
    empty window set yields an empty (B, 0, D_sig) result.

    ``lengths`` (B,) makes the batch ragged: window [l, r] is exactly
    clipped to [min(l, L_b), min(r, L_b)] per example on BOTH routes (a
    :class:`repro.ragged.RaggedPaths` may be passed directly as ``path``).

    ``transform`` applies a path transform PER WINDOW, fused into the fold
    route's engine sweep (the (B, K, L_aug, d_aug) augmented intermediate
    never materialises; the spec rides into the dispatch with each window's
    own clipped length and basepoint): identical to calling
    ``signature(window_slice, transform=...)`` per window.  Transforms pin
    ``route="auto"`` to fold; an explicit ``route="chen"`` raises.
    ``precision`` threads through to the engines on both routes.
    """
    values, rl = _unpack_ragged(path)
    if rl is not None and lengths is None:
        lengths = rl
    path = values
    if path.ndim == 2:
        return windowed_signature(path[None], windows, depth, route=route,
                                  backward=backward, backend=backend,
                                  lengths=lengths, transform=transform,
                                  precision=precision)[0]
    spec = as_transform(transform)
    route = _pin_transform_route(route, spec)
    B, d = path.shape[0], path.shape[-1]
    M = path.shape[1] - 1
    if lengths is not None:
        lengths = as_lengths(lengths, B)
    windows = _check_windows(windows, M)
    if windows.shape[0] == 0:
        from .transforms import transform_dim
        d_eff = transform_dim(spec, d) if spec else d
        return jnp.zeros((B, 0, sig_dim(d_eff, depth)), path.dtype)
    if select_route(route, windows, M, backward=backward) == "chen":
        return _chen_route_signature(path, windows, depth, backward, backend,
                                     lengths, precision=precision)
    g = _window_increments(path, windows, lengths)         # (B, K, L, d)
    K, L, d = g.shape[1:]
    if spec is None:
        flat = signature_from_increments(g.reshape(B * K, L, d), depth,
                                         backward=backward, backend=backend,
                                         precision=precision)
        return flat.reshape(B, K, -1)
    wlen, x0 = _fold_window_ctx(path, windows, spec, lengths)
    flat = signature_from_increments(
        g.reshape(B * K, L, d), depth, backward=backward, backend=backend,
        lengths=wlen.reshape(-1), transform=spec,
        x0=None if x0 is None else x0.reshape(B * K, d),
        precision=precision)
    return flat.reshape(B, K, -1)


def windowed_projection(path: jax.Array, windows, plan: WordPlan, *,
                        route: str = "auto", backward: str = "inverse",
                        backend: str = "jax", lengths=None, transform=None,
                        precision: str = "fp32") -> jax.Array:
    """Windowed + word-projected signatures in one call (B, K, |I|).

    The chen route computes the FULL truncated streamed signature at the
    plan's depth and projects the combined windows onto the requested words
    (Chen's identity needs all suffix coefficients, which an arbitrary word
    set does not retain), so its cost model is scaled by D_sig / closure —
    ``route="auto"`` only takes it when the overlap still pays for that.
    ``lengths`` clips windows per example exactly like
    :func:`windowed_signature`.

    ``transform`` / ``precision`` mirror :func:`windowed_signature`: the
    transform applies per window, fused into the fold route's sweep (the
    plan's words index the AUGMENTED alphabet); transforms pin ``"auto"``
    to fold and an explicit ``route="chen"`` raises.
    """
    values, rl = _unpack_ragged(path)
    if rl is not None and lengths is None:
        lengths = rl
    path = values
    if path.ndim == 2:
        return windowed_projection(path[None], windows, plan, route=route,
                                   backward=backward, backend=backend,
                                   lengths=lengths, transform=transform,
                                   precision=precision)[0]
    spec = as_transform(transform)
    route = _pin_transform_route(route, spec)
    B, d = path.shape[0], path.shape[-1]
    M = path.shape[1] - 1
    if lengths is not None:
        lengths = as_lengths(lengths, B)
    windows = _check_windows(windows, M)
    if windows.shape[0] == 0:
        return jnp.zeros((B, 0, len(plan.words)), path.dtype)
    scale = sig_dim(d, plan.depth) / float(1 + plan.closure_size)
    if select_route(route, windows, M, chen_cost_scale=scale,
                    backward=backward) == "chen":
        full = _chen_route_signature(path, windows, plan.depth, backward,
                                     backend, lengths, precision=precision)
        idx = jnp.asarray([flat_index(w, d) for w in plan.words])
        return jnp.take(full, idx, axis=-1)
    g = _window_increments(path, windows, lengths)
    K, L, d = g.shape[1:]
    if spec is None:
        out = projected_signature_from_increments(g.reshape(B * K, L, d),
                                                  plan, backward=backward,
                                                  backend=backend,
                                                  precision=precision)
        return out.reshape(B, K, -1)
    wlen, x0 = _fold_window_ctx(path, windows, spec, lengths)
    out = projected_signature_from_increments(
        g.reshape(B * K, L, d), plan, backward=backward, backend=backend,
        lengths=wlen.reshape(-1), transform=spec,
        x0=None if x0 is None else x0.reshape(B * K, d),
        precision=precision)
    return out.reshape(B, K, -1)


def windowed_signature_chen(path: jax.Array, windows, depth: int, *,
                            backward: str = "inverse",
                            backend: str = "jax", lengths=None) -> jax.Array:
    """Signatory-style alternative: S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r}.

    Equivalent to ``windowed_signature(..., route="chen")`` — kept as a
    public name with the same ``backend=``/``backward=``/``lengths=``
    surface as the other windowed entry points.
    """
    return windowed_signature(path, windows, depth, route="chen",
                              backward=backward, backend=backend,
                              lengths=lengths)


def expanding_windows(M: int, stride: int = 1) -> np.ndarray:
    """[0, stride], [0, 2·stride], ..., always ending with the full [0, M]
    window (the path tail is never silently dropped when stride ∤ M)."""
    if M < 1 or stride < 1:
        raise ValueError(f"need M >= 1 and stride >= 1, got M={M}, "
                         f"stride={stride}")
    r = np.arange(stride, M + 1, stride, dtype=np.int32)
    if r.size == 0 or r[-1] != M:
        r = np.concatenate([r, np.asarray([M], np.int32)])
    return np.stack([np.zeros_like(r), r], axis=1)


def sliding_windows(M: int, length: int, stride: int = 1) -> np.ndarray:
    if not 1 <= length <= M:
        raise ValueError(f"window length must satisfy 1 <= length <= M; got "
                         f"length={length}, M={M}")
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    l = np.arange(0, M - length + 1, stride, dtype=np.int32)
    return np.stack([l, l + length], axis=1)


def dyadic_windows(M: int, levels: int) -> np.ndarray:
    """Dyadic hierarchy of windows as in the generalised signature method."""
    out = []
    for lev in range(levels):
        k = 2 ** lev
        bounds = np.linspace(0, M, k + 1).astype(np.int32)
        for i in range(k):
            if bounds[i + 1] > bounds[i]:
                out.append((bounds[i], bounds[i + 1]))
    return np.asarray(out, dtype=np.int32)
