"""Windowed signature computation (paper §5).

Given index pairs (l_i, r_i), pathsig returns all S_{t_{l_i}, t_{r_i}}(X) in a
single evaluation.  We materialise per-window increment slices (zero-padded to
the longest window — zero increments are identity Chen updates, so padding is
exact) and fold the window axis into the batch axis: windows become an extra
axis of parallelism, exactly the paper's saturation argument.

The Chen alternative S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r} is provided as
``windowed_signature_chen`` (the paper notes it is cheaper only for heavily
overlapping windows and can be numerically unstable; benchmarked in
benchmarks/fig3_windows.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import tensor_ops as tops
from .projection import projected_signature_from_increments
from .signature import signature_from_increments, signature_inverse, \
    signature_combine
from .words import WordPlan, sig_dim


def _check_windows(windows, M: int) -> np.ndarray:
    """Validate (K, 2) index pairs against a path of M increments."""
    windows_np = np.asarray(windows, dtype=np.int32).reshape(-1, 2)
    if windows_np.shape[0]:
        if (windows_np[:, 0] < 0).any() or (windows_np[:, 1] > M).any():
            raise ValueError(
                f"window indices must lie in [0, {M}] (M = number of path "
                f"increments); got {windows_np.tolist()}")
        if (windows_np[:, 0] > windows_np[:, 1]).any():
            raise ValueError(f"windows must satisfy l <= r; got "
                             f"{windows_np.tolist()}")
    return windows_np


def _window_increments(path: jax.Array, windows_np: np.ndarray) -> jax.Array:
    """(B, M+1, d) x validated (K, 2) -> (B, K, L_max, d) zero-padded slices.

    ``windows_np`` must come from :func:`_check_windows` (host-side: shapes
    are static).
    """
    L_max = int((windows_np[:, 1] - windows_np[:, 0]).max())
    windows = jnp.asarray(windows_np)
    K = windows.shape[0]
    incs = tops.path_increments(path)                      # (B, M, d)
    M = incs.shape[1]
    lengths = windows[:, 1] - windows[:, 0]                # (K,)
    # gather indices: l_i + t, clamped; mask t >= length
    t = jnp.arange(L_max)[None, :]                         # (1, L)
    idx = jnp.clip(windows[:, :1] + t, 0, M - 1)           # (K, L)
    mask = (t < lengths[:, None]).astype(incs.dtype)       # (K, L)
    g = jnp.take(incs, idx.reshape(-1), axis=1)            # (B, K*L, d)
    g = g.reshape(incs.shape[0], K, L_max, incs.shape[2])
    return g * mask[None, :, :, None]


def windowed_signature(path: jax.Array, windows, depth: int, *,
                       backward: str = "inverse",
                       backend: str = "jax") -> jax.Array:
    """(B, M+1, d) x (K, 2) -> (B, K, D_sig) in one batched evaluation.

    Folded windows ride the engine dispatch (:mod:`repro.kernels.ops`), so
    every backend's kernel forward + O(1)-in-length backward applies per
    window.  An empty window set yields an empty (B, 0, D_sig) result.
    """
    if path.ndim == 2:
        return windowed_signature(path[None], windows, depth,
                                  backward=backward, backend=backend)[0]
    B, d = path.shape[0], path.shape[-1]
    windows = _check_windows(windows, path.shape[1] - 1)
    if windows.shape[0] == 0:
        return jnp.zeros((B, 0, sig_dim(d, depth)), path.dtype)
    g = _window_increments(path, windows)                  # (B, K, L, d)
    K, L, d = g.shape[1:]
    flat = signature_from_increments(g.reshape(B * K, L, d), depth,
                                     backward=backward, backend=backend)
    return flat.reshape(B, K, -1)


def windowed_projection(path: jax.Array, windows, plan: WordPlan, *,
                        backward: str = "inverse",
                        backend: str = "jax") -> jax.Array:
    """Windowed + word-projected signatures in one call (B, K, |I|)."""
    if path.ndim == 2:
        return windowed_projection(path[None], windows, plan,
                                   backward=backward, backend=backend)[0]
    B = path.shape[0]
    windows = _check_windows(windows, path.shape[1] - 1)
    if windows.shape[0] == 0:
        return jnp.zeros((B, 0, len(plan.words)), path.dtype)
    g = _window_increments(path, windows)
    K, L, d = g.shape[1:]
    out = projected_signature_from_increments(g.reshape(B * K, L, d), plan,
                                              backward=backward,
                                              backend=backend)
    return out.reshape(B, K, -1)


def windowed_signature_chen(path: jax.Array, windows, depth: int) -> jax.Array:
    """Signatory-style alternative: S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r}."""
    if path.ndim == 2:
        return windowed_signature_chen(path[None], windows, depth)[0]
    d = path.shape[-1]
    windows = jnp.asarray(_check_windows(windows, path.shape[1] - 1))
    if windows.shape[0] == 0:
        return jnp.zeros((path.shape[0], 0, sig_dim(d, depth)), path.dtype)
    stream = signature_from_increments(tops.path_increments(path), depth,
                                       stream=True)        # (B, M, D)
    # prepend the identity signature for l = 0
    ident = jnp.zeros_like(stream[:, :1])
    stream = jnp.concatenate([ident, stream], axis=1)       # (B, M+1, D)
    s_l = jnp.take(stream, windows[:, 0], axis=1)           # (B, K, D)
    s_r = jnp.take(stream, windows[:, 1], axis=1)
    inv = signature_inverse(s_l.reshape(-1, s_l.shape[-1]), d, depth)
    out = signature_combine(inv, s_r.reshape(-1, s_r.shape[-1]), d, depth)
    return out.reshape(s_l.shape)


def expanding_windows(M: int, stride: int = 1) -> np.ndarray:
    """[0, stride], [0, 2·stride], ..., always ending with the full [0, M]
    window (the path tail is never silently dropped when stride ∤ M)."""
    if M < 1 or stride < 1:
        raise ValueError(f"need M >= 1 and stride >= 1, got M={M}, "
                         f"stride={stride}")
    r = np.arange(stride, M + 1, stride, dtype=np.int32)
    if r.size == 0 or r[-1] != M:
        r = np.concatenate([r, np.asarray([M], np.int32)])
    return np.stack([np.zeros_like(r), r], axis=1)


def sliding_windows(M: int, length: int, stride: int = 1) -> np.ndarray:
    if not 1 <= length <= M:
        raise ValueError(f"window length must satisfy 1 <= length <= M; got "
                         f"length={length}, M={M}")
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    l = np.arange(0, M - length + 1, stride, dtype=np.int32)
    return np.stack([l, l + length], axis=1)


def dyadic_windows(M: int, levels: int) -> np.ndarray:
    """Dyadic hierarchy of windows as in the generalised signature method."""
    out = []
    for lev in range(levels):
        k = 2 ** lev
        bounds = np.linspace(0, M, k + 1).astype(np.int32)
        for i in range(k):
            if bounds[i + 1] > bounds[i]:
                out.append((bounds[i], bounds[i + 1]))
    return np.asarray(out, dtype=np.int32)
