"""Word algebra over the alphabet {0, ..., d-1} (paper §2.3, Appendix A).

Words index the canonical basis of the tensor algebra T(R^d).  We follow the
paper's integer encoding (Def. A.1): a word w = (i_1, ..., i_n) of length n is
stored as the base-d integer  phi_n(w) = sum_j i_j d^{n-j},  which is bijective
per level and preserves lexicographic order (Prop. A.2).  A word is therefore
represented as the pair ``(level, code)``; the pair is flattened into a single
global index by the cumulative level offset.

Everything in this module runs on the host at trace/plan time and produces
plain numpy index tables that are fed to the JAX/Pallas engines.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

import numpy as np

Word = tuple[int, ...]  # letters over 0-based alphabet


# ---------------------------------------------------------------------------
# encoding / decoding (Appendix A)
# ---------------------------------------------------------------------------

def encode(word: Word, d: int) -> int:
    """phi_n(word): base-d integer encoding (Def. A.1)."""
    code = 0
    for letter in word:
        if not 0 <= letter < d:
            raise ValueError(f"letter {letter} outside alphabet of size {d}")
        code = code * d + letter
    return code


def decode(code: int, level: int, d: int) -> Word:
    """Inverse of :func:`encode` at a fixed level."""
    letters = []
    for _ in range(level):
        letters.append(code % d)
        code //= d
    return tuple(reversed(letters))


def concat_codes(code_u: int, code_v: int, len_v: int, d: int) -> int:
    """Encoding of u∘v from encodings of u, v (Prop. A.3)."""
    return code_u * d**len_v + code_v


def prefix_code(code: int, level: int, k: int, d: int) -> int:
    """Encoding of the length-k prefix of a level-`level` word (Cor. A.4)."""
    return code // d ** (level - k)


def suffix_code(code: int, k: int, d: int) -> int:
    """Encoding of the length-k suffix (Cor. A.5)."""
    return code % d**k


def level_offsets(d: int, depth: int) -> np.ndarray:
    """offsets[n] = flat index of the first level-n word, for n = 0..depth.

    Level 0 (the empty word) is *not* stored in signature buffers, so
    offsets[1] == 0 and offsets[depth+1] == D_sig.
    """
    sizes = [d**n for n in range(1, depth + 1)]
    return np.concatenate([[0, 0], np.cumsum(sizes)]).astype(np.int64)


def sig_dim(d: int, depth: int) -> int:
    """D_sig = sum_{n=1..N} d^n (level 0 excluded, as in the paper §6.2)."""
    return sum(d**n for n in range(1, depth + 1))


def flat_index(word: Word, d: int) -> int:
    """Global index of a non-empty word in the level-concatenated layout."""
    n = len(word)
    if n == 0:
        raise ValueError("empty word has no flat index (level 0 is implicit)")
    return int(level_offsets(d, n)[n] + encode(word, d))


# ---------------------------------------------------------------------------
# word-set constructors (paper §7)
# ---------------------------------------------------------------------------

def all_words(d: int, depth: int) -> list[Word]:
    """W_{<=N} \\ {eps}: every word of length 1..depth, level-major lex order."""
    out: list[Word] = []
    for n in range(1, depth + 1):
        out.extend(itertools.product(range(d), repeat=n))
    return out


def anisotropic_words(gamma: Sequence[float], r: float) -> list[Word]:
    """W^γ_{<=r}: words with weighted degree |w|_γ <= r (paper Def. 7.1).

    γ_i > 0 for all i, so the set is finite; built by DFS.  The result is
    prefix-closed by construction (weighted degree is monotone in prefixes).
    """
    gamma = list(map(float, gamma))
    if any(g <= 0 for g in gamma):
        raise ValueError("anisotropic weights must be strictly positive")
    d = len(gamma)
    out: list[Word] = []

    def dfs(word: Word, weight: float) -> None:
        for i in range(d):
            w2 = weight + gamma[i]
            if w2 <= r + 1e-12:
                nxt = word + (i,)
                out.append(nxt)
                dfs(nxt, w2)

    dfs((), 0.0)
    out.sort(key=lambda w: (len(w), w))
    return out


def dag_words(edges: Iterable[tuple[int, int]], d: int, depth: int,
              roots: Iterable[int] | None = None) -> list[Word]:
    """W_{<=N}(G): words whose consecutive letters follow edges of G (§7.1)."""
    adj: dict[int, list[int]] = {i: [] for i in range(d)}
    for i, j in edges:
        adj[i].append(j)
    out: list[Word] = []
    start = list(roots) if roots is not None else list(range(d))

    def dfs(word: Word) -> None:
        if len(word) >= depth:
            return
        for j in adj[word[-1]]:
            nxt = word + (j,)
            out.append(nxt)
            dfs(nxt)

    for i in start:
        out.append((i,))
        dfs((i,))
    out.sort(key=lambda w: (len(w), w))
    return out


def generated_words(generators: Iterable[Word], depth: int) -> list[Word]:
    """Words formed by concatenating generator blocks, up to `depth` (§8).

    Mirrors the paper's sparse lead-lag set  W^sparse_{<=N} = {u_1∘…∘u_p :
    u_j ∈ G, |w| <= N}.  The empty word is excluded from the result.
    """
    gens = [tuple(g) for g in generators if len(g) > 0]
    seen: set[Word] = set()
    frontier: list[Word] = [()]
    while frontier:
        new: list[Word] = []
        for base in frontier:
            for g in gens:
                w = base + g
                if len(w) <= depth and w not in seen:
                    seen.add(w)
                    new.append(w)
        frontier = new
    out = sorted(seen, key=lambda w: (len(w), w))
    return out


def lyndon_words(d: int, depth: int) -> list[Word]:
    """All Lyndon words over {0..d-1} of length 1..depth (Duval's algorithm)."""
    out: list[Word] = []
    w = [-1]
    while w:
        w[-1] += 1
        m = len(w)
        if m <= depth:
            out.append(tuple(w))
        # extend periodically to length `depth`
        while len(w) < depth:
            w.append(w[len(w) - m])
        # strip trailing maximal letters
        while w and w[-1] == d - 1:
            w.pop()
    out.sort(key=lambda t: (len(t), t))
    return out


def lyndon_dim(d: int, depth: int) -> int:
    """dim of the free Lie algebra truncation = #Lyndon words (necklace sum)."""
    return len(lyndon_words(d, depth))


def shuffle_product(u: Word, v: Word) -> dict[Word, int]:
    """The shuffle product u ⧢ v as a multiset {word: multiplicity}.

    Signatures are grouplike, so ⟨S, u⟩·⟨S, v⟩ = Σ_w c_w ⟨S, w⟩ with c_w the
    shuffle multiplicities — the identity that makes the weighted Gram of
    :mod:`repro.sigkernel` a genuine kernel on path space (and the property
    the algebra test-suite checks every engine against).
    """
    u, v = tuple(u), tuple(v)
    if not u:
        return {v: 1}
    if not v:
        return {u: 1}
    out: dict[Word, int] = {}
    for w, c in shuffle_product(u[1:], v).items():
        k = (u[0],) + w
        out[k] = out.get(k, 0) + c
    for w, c in shuffle_product(u, v[1:]).items():
        k = (v[0],) + w
        out[k] = out.get(k, 0) + c
    return out


def deconcatenations(w: Word) -> list[tuple[Word, Word]]:
    """All splits w = u∘v including the empty-factor ones — the coproduct
    side of Chen's identity ⟨S(x·y), w⟩ = Σ ⟨S(x), u⟩⟨S(y), v⟩."""
    w = tuple(w)
    return [(w[:k], w[k:]) for k in range(len(w) + 1)]


# ---------------------------------------------------------------------------
# prefix closure + computation plan (paper §3.1-3.2 adapted to tiles)
# ---------------------------------------------------------------------------

def prefix_closure(words: Iterable[Word]) -> list[Word]:
    """Smallest prefix-closed superset (excluding eps), level-major sorted."""
    closed: set[Word] = set()
    for w in words:
        w = tuple(w)
        for k in range(1, len(w) + 1):
            closed.add(w[:k])
    return sorted(closed, key=lambda w: (len(w), w))


@dataclasses.dataclass(frozen=True, eq=False)  # eq=False: identity hash (arrays)
class WordPlan:
    """Index tables driving the word-table signature engines.

    The closure rows are augmented with a virtual row 0 holding the constant
    coefficient S[eps] = 1, so prefix indices are always well-defined.  For a
    closure of size W and max level N:

    - ``letters[r, j]``: j-th letter (0-based position) of word r; 0-padded.
    - ``prefix_idx[r, j]``: row index (into the augmented buffer, so 0 = eps)
      of the length-j prefix of word r, for j = 0..N-1 (j < len(r)).
    - ``inv[r, j]``: Horner divisor 1/(n_r - j) for step j (paper Alg. 1);
      0 where j >= len(r) (masks padded steps).
    - ``emit[r, j]``: 1.0 exactly at j = len(r) - 1 (the step whose
      accumulator equals the word's Chen increment h).
    - ``out_rows``: rows (augmented indexing) of the originally requested
      words, in their original order — the projection π_I output.
    """
    d: int
    depth: int
    words: tuple[Word, ...]          # requested set, original order
    closure: tuple[Word, ...]        # prefix closure, level-major order
    letters: np.ndarray              # (W, N) int32
    prefix_idx: np.ndarray           # (W, N) int32, augmented row indices
    inv: np.ndarray                  # (W, N) float32
    emit: np.ndarray                 # (W, N) float32
    lengths: np.ndarray              # (W,) int32
    out_rows: np.ndarray             # (len(words),) int32

    @property
    def closure_size(self) -> int:
        return len(self.closure)

    @property
    def max_level(self) -> int:
        return self.depth


def make_plan(words: Sequence[Word], d: int) -> WordPlan:
    """Build the index tables for an arbitrary non-empty word set."""
    words = [tuple(w) for w in words]
    if not words:
        raise ValueError("word set must be non-empty")
    for w in words:
        if len(w) == 0:
            raise ValueError("the empty word is implicit; remove it from the set")
        if any(not 0 <= i < d for i in w):
            raise ValueError(f"word {w} outside alphabet of size {d}")
    closure = prefix_closure(words)
    depth = max(len(w) for w in closure)
    row_of: dict[Word, int] = {w: r + 1 for r, w in enumerate(closure)}  # +1: eps row
    W = len(closure)
    letters = np.zeros((W, depth), dtype=np.int32)
    prefix_idx = np.zeros((W, depth), dtype=np.int32)
    inv = np.zeros((W, depth), dtype=np.float32)
    emit = np.zeros((W, depth), dtype=np.float32)
    lengths = np.zeros((W,), dtype=np.int32)
    for r, w in enumerate(closure):
        n = len(w)
        lengths[r] = n
        for j in range(n):
            letters[r, j] = w[j]
            prefix_idx[r, j] = 0 if j == 0 else row_of[w[:j]]
            inv[r, j] = 1.0 / (n - j)
        emit[r, n - 1] = 1.0
    out_rows = np.array([row_of[w] for w in words], dtype=np.int32)
    return WordPlan(d=d, depth=depth, words=tuple(words), closure=tuple(closure),
                    letters=letters, prefix_idx=prefix_idx, inv=inv, emit=emit,
                    lengths=lengths, out_rows=out_rows)


def truncation_plan(d: int, depth: int) -> WordPlan:
    """Plan for the full truncation W_{<=N} (useful as an oracle path)."""
    return make_plan(all_words(d, depth), d)


# ---------------------------------------------------------------------------
# tiling for the Pallas word-set kernel (§2.1 of DESIGN.md)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class TiledPlan:
    """A word plan partitioned into prefix-closed tiles of bounded size.

    Each tile is itself a WordPlan (its closure includes the shared prefix
    path redundantly — the paper's locality/redundancy trade).  ``gather``
    maps each requested word to (tile, out_row-within-tile).
    """
    d: int
    tiles: tuple[WordPlan, ...]
    # for each requested word in original order: (tile_index, row_in_tile_out)
    gather: tuple[tuple[int, int], ...]
    words: tuple[Word, ...]


def make_tiled_plan(words: Sequence[Word], d: int, max_rows: int = 256) -> TiledPlan:
    """Split a word set into prefix-closed tiles with closure size <= max_rows.

    Strategy: recursively partition by first letter (level-1 prefix, then
    level-2, ...) until each group's closure fits.  Each group keeps its own
    copy of the shared ancestor path, mirroring the paper's per-thread P_w
    redundancy at tile granularity.
    """
    words = [tuple(w) for w in words]

    def split(group: list[Word], level: int) -> list[list[Word]]:
        closure_size = len(prefix_closure(group))
        if closure_size <= max_rows or all(len(w) <= level for w in group):
            return [group]
        buckets: dict[Word, list[Word]] = {}
        shorts: list[Word] = []
        for w in group:
            if len(w) <= level:
                shorts.append(w)
            else:
                buckets.setdefault(w[: level + 1], []).append(w)
        out: list[list[Word]] = []
        if shorts:
            out.append(shorts)
        for _, sub in sorted(buckets.items()):
            out.extend(split(sub, level + 1))
        return out

    groups = split(words, 0)
    tiles = tuple(make_plan(g, d) for g in groups)
    where: dict[Word, tuple[int, int]] = {}
    for t, plan in enumerate(tiles):
        for k, w in enumerate(plan.words):
            where[w] = (t, k)
    gather = tuple(where[w] for w in words)
    return TiledPlan(d=d, tiles=tiles, gather=gather, words=tuple(words))
