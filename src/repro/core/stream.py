"""Online signature state: the pooled ``StreamCarry`` core and the
``SignatureStream`` view.

The streamed kernels answer "all prefix signatures of a path I already have";
this module answers the *online* question: path steps arrive chunk by chunk
(serving, sensors, tick data) and per-window signature features must stay
current without ever recomputing from scratch.

Two layers:

1. **Pooled functional core** — :class:`StreamCarry` is a struct-of-arrays
   carry for N independent streams sharing one device-resident pool:

   - ``sig``    — (N, D_sig) flat signature of every increment in each row's
                  current window, updated by Chen's identity S' = S ⊗ S(chunk);
   - ``ring``   — (N, R, d) ring buffers holding exactly each window's
                  increments, so the *left* end can move too: dropping the
                  oldest increment is the exact group operation
                  S' = exp(-ΔX_oldest) ⊗ S (Lemma 4.5 / Prop. 4.6 applied from
                  the left — exact only because ΔX_oldest IS the leftmost
                  increment of ``sig``, the ring invariant);
   - ``length`` / ``end`` — per-row occupancy and ring write head, *traced*
     int32 lanes (rows advance independently);
   - ``valid``  — per-row liveness mask: dead lanes pass through every
     operation bit-identically, which is what lets a serving pool keep free
     slots resident on device instead of reallocating.

   :func:`stream_extend` / :func:`stream_rolling_drop` take per-row
   ``counts`` so one compiled call advances any subset of rows by any
   (bounded) number of ticks — the primitive `repro.serve.SessionStore`
   builds continuous-batching ingest on.  Because these lanes are traced,
   occupancy violations cannot raise here; pool owners keep host mirrors and
   raise *before* dispatch (``SessionStore`` does).

2. **``SignatureStream``** — the original per-object carry, kept as a thin
   view over the same shared update math with *static* host-int
   length/end: chunk sizes and drop counts fix them at trace time, so
   occupancy violations raise immediately instead of silently corrupting the
   window.  Every existing call site is untouched.

All array operations are functional (a new carry is returned), jit- and
grad-compatible: both carries are registered pytrees.  ``extend(...,
return_stream=True)`` additionally emits the per-step features
S_{window_start, t} for every new step — the carried prefix Chen-combined
with the *streamed* chunk signature from the engine dispatch, so the hot
loop stays on the configured backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import tensor_ops as tops
from .signature import signature_from_increments
from .words import sig_dim


# ---------------------------------------------------------------------------
# shared update math (the pure functional core both carries ride)
# ---------------------------------------------------------------------------

def _combine_flat(prefix_flat: jax.Array, chunk_flat: jax.Array, d: int,
                  depth: int) -> jax.Array:
    """Chen combine with broadcasting: prefix (B, D) ⊗ chunk (B, T, D)."""
    a = [jnp.broadcast_to(lv[:, None], (*chunk_flat.shape[:2], lv.shape[-1]))
         for lv in tops.flat_to_levels(prefix_flat, d, depth)]
    b = tops.flat_to_levels(chunk_flat, d, depth)
    return tops.levels_to_flat(tops.chen_mul(a, b))


def extend_sig(sig: jax.Array, increments: jax.Array, d: int, depth: int, *,
               backend: str = "jax", backward: str = "inverse",
               return_stream: bool = False, stream_stride: int = 1):
    """S ← S ⊗ S(chunk) for a (B, m, d) chunk against a (B, D_sig) carry.

    One dispatch call on the configured backend; returns ``(new_sig, feats)``
    where feats is the (B, m_out, D_sig) per-step features when
    ``return_stream`` (None otherwise).  A zero increment is the identity
    Chen update, so rows whose chunk is all-zero come back unchanged (up to
    exact +0.0 adds) — the algebraic fact pooled ingest relies on.
    """
    if return_stream:
        chunk = signature_from_increments(
            increments, depth, stream=True, stream_stride=stream_stride,
            backward=backward, backend=backend)        # (B, m_out, D)
        feats = _combine_flat(sig, chunk, d, depth)
        return feats[:, -1], feats
    chunk = signature_from_increments(increments, depth, backward=backward,
                                      backend=backend)
    return _combine_flat(sig, chunk[:, None], d, depth)[:, 0], None


def drop_sig(sig: jax.Array, dropped: jax.Array, d: int,
             depth: int) -> jax.Array:
    """S ← exp(-ΔX_k) ⊗ ... ⊗ exp(-ΔX_1) ⊗ S for (B, n, d) oldest-first
    dropped increments (the exact left-inverse window update).  All-zero
    rows of ``dropped`` are exact identity steps."""
    def step(levels, dx):
        e = tops.tensor_exp(-dx, depth)
        return tops.chen_mul(e, levels), None

    levels = tops.flat_to_levels(sig, d, depth)
    levels, _ = jax.lax.scan(step, levels, jnp.moveaxis(dropped, 1, 0))
    return tops.levels_to_flat(levels)


# ---------------------------------------------------------------------------
# pooled struct-of-arrays carry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamCarry:
    """Struct-of-arrays carry for N pooled streams (see module docstring).

    Build with :func:`stream_init`; update with :func:`stream_extend` /
    :func:`stream_rolling_drop`; move rows with :func:`stream_take` /
    :func:`stream_scatter`.  ``length``/``end``/``valid`` are *data* lanes —
    rows advance independently inside one compiled call.
    """
    sig: jax.Array      # (N, D_sig) per-row window signature
    ring: jax.Array     # (N, R, d) per-row window increments (R may be 0)
    length: jax.Array   # (N,) int32 increments covered by ``sig``
    end: jax.Array      # (N,) int32 ring write position
    valid: jax.Array    # (N,) bool live-lane mask
    d: int              # static: path dimension
    depth: int          # static: truncation depth

    @property
    def capacity(self) -> int:
        return self.ring.shape[1]

    @property
    def size(self) -> int:
        """Pool row count N."""
        return self.sig.shape[0]


jax.tree_util.register_dataclass(
    StreamCarry, data_fields=("sig", "ring", "length", "end", "valid"),
    meta_fields=("d", "depth"))


def stream_init(n: int, d: int, depth: int, *, capacity: int = 0,
                dtype=jnp.float32, valid: bool = False) -> StreamCarry:
    """Fresh pool of ``n`` rows: identity signatures, empty rings.

    ``capacity`` is the per-row ring size R: with a ring, a row may never
    hold more than R increments (pool owners enforce this on the host — see
    module docstring), and up to ``length`` oldest increments can be dropped
    at any time.  ``capacity=0`` disables rings: expanding-window only.
    ``valid=True`` starts every lane live (the engines' fixed-slot case).
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    if capacity < 0:
        raise ValueError("capacity must be >= 0")
    return StreamCarry(
        sig=jnp.zeros((n, sig_dim(d, depth)), dtype),
        ring=jnp.zeros((n, capacity, d), dtype),
        length=jnp.zeros((n,), jnp.int32),
        end=jnp.zeros((n,), jnp.int32),
        valid=jnp.full((n,), bool(valid)),
        d=d, depth=depth)


def stream_take(carry: StreamCarry, slots) -> StreamCarry:
    """Gather pool rows into a (len(slots), ...) sub-carry.  Out-of-range
    slots clamp (jnp.take's jit behaviour) — pair them with ``counts == 0``
    so the clamped row passes through unchanged and its write-back is
    dropped by :func:`stream_scatter`."""
    slots = jnp.asarray(slots, jnp.int32)
    return dataclasses.replace(
        carry,
        sig=jnp.take(carry.sig, slots, axis=0),
        ring=jnp.take(carry.ring, slots, axis=0),
        length=jnp.take(carry.length, slots, axis=0),
        end=jnp.take(carry.end, slots, axis=0),
        valid=jnp.take(carry.valid, slots, axis=0))


def stream_scatter(carry: StreamCarry, slots, sub: StreamCarry) -> StreamCarry:
    """Write a sub-carry's rows back into the pool.  Out-of-range slots are
    dropped (``mode="drop"``), so padding rows can point past the pool."""
    slots = jnp.asarray(slots, jnp.int32)
    return dataclasses.replace(
        carry,
        sig=carry.sig.at[slots].set(sub.sig, mode="drop"),
        ring=carry.ring.at[slots].set(sub.ring, mode="drop"),
        length=carry.length.at[slots].set(sub.length, mode="drop"),
        end=carry.end.at[slots].set(sub.end, mode="drop"),
        valid=carry.valid.at[slots].set(sub.valid, mode="drop"))


def stream_extend(carry: StreamCarry, increments: jax.Array, *,
                  counts=None, backend: str = "jax",
                  backward: str = "inverse", return_stream: bool = False,
                  stream_stride: int = 1):
    """Append up to m new increments (N, m, d) to every row of the pool.

    ``counts`` is a per-row (N,) int32 tick count <= m: row i consumes its
    first ``counts[i]`` increments (the rest are masked to zero = identity),
    advancing ``length``/``end``/ring by exactly ``counts[i]``.  ``None``
    means the full m for every valid row.  Rows with count 0 (and invalid
    lanes) come back bit-identical — that is what makes zero-padded
    continuous-batching rungs exact.

    Occupancy (``length + counts <= capacity`` when a ring exists, and
    ``counts <= m``) is the CALLER's contract — these are traced lanes, so
    violations cannot raise here (see module docstring).

    ``return_stream=True`` additionally returns the (N, m_out, D_sig)
    per-step features; it requires uniform full-chunk counts
    (``counts=None``) because emitted steps past a row's true count would
    repeat the prefix.
    """
    N, m, d = increments.shape
    if d != carry.d:
        raise ValueError(f"increment dim {d} != pool dim {carry.d}")
    if N != carry.size:
        raise ValueError(f"batch {N} != pool size {carry.size}")
    if counts is not None and return_stream:
        raise ValueError("return_stream=True needs uniform chunks "
                         "(counts=None)")
    increments = increments.astype(carry.sig.dtype)
    if counts is None:
        counts = jnp.where(carry.valid, m, 0).astype(jnp.int32)
    else:
        counts = jnp.asarray(counts, jnp.int32) * carry.valid
    mask = jnp.arange(m)[None, :] < counts[:, None]            # (N, m)
    inc = jnp.where(mask[..., None], increments, 0.0)
    new_sig, feats = extend_sig(carry.sig, inc, carry.d, carry.depth,
                                backend=backend, backward=backward,
                                return_stream=return_stream,
                                stream_stride=stream_stride)
    active = counts > 0
    sig = jnp.where(active[:, None], new_sig, carry.sig)
    R = carry.capacity
    if R:
        rows = jnp.arange(N)[:, None]
        # Masked positions scatter to index R (out of range, mode="drop")
        # instead of writing back the stale current value: when m > R the
        # wrapped indices collide, and a stale write-back for a masked
        # position would clobber a freshly written increment.
        idx = jnp.where(mask, (carry.end[:, None] + jnp.arange(m)) % R, R)
        ring = carry.ring.at[rows, idx].set(inc, mode="drop")
        end = (carry.end + counts) % R
    else:
        ring, end = carry.ring, carry.end
    new = dataclasses.replace(carry, sig=sig, ring=ring,
                              length=carry.length + counts, end=end)
    return (new, feats) if return_stream else new


def stream_rolling_drop(carry: StreamCarry, counts, *,
                        max_drop: int | None = None) -> StreamCarry:
    """Drop each row's ``counts[i]`` oldest increments: for each, the exact
    left-inverse update S ← exp(-ΔX_oldest) ⊗ S.

    ``max_drop`` is the static scan bound (>= max(counts)); it defaults to
    ``counts`` itself when that is a host int.  Rows with count 0 pass
    through bit-identically; a row dropped to length 0 resets to the exact
    identity (no accumulated float error).  ``counts <= length`` is the
    caller's contract (traced lanes — see module docstring).
    """
    if carry.capacity == 0:
        raise ValueError("rolling_drop needs ring buffers: init the pool "
                         "with capacity > 0")
    if max_drop is None:
        try:
            max_drop = int(counts)      # host ints / np scalars / 0-d arrays
        except TypeError:               # per-row or traced counts
            raise ValueError("stream_rolling_drop with per-row counts needs "
                             "a static max_drop= bound") from None
    max_drop = int(max_drop)
    if max_drop == 0:
        return carry
    N, R = carry.size, carry.capacity
    counts = (jnp.broadcast_to(jnp.asarray(counts, jnp.int32), (N,))
              * carry.valid)
    start = (carry.end - carry.length) % R                  # oldest slot
    rows = jnp.arange(N)[:, None]
    idx = (start[:, None] + jnp.arange(max_drop)) % R        # (N, max_drop)
    dropped = carry.ring[rows, idx]                          # oldest-first
    dropped = jnp.where(
        (jnp.arange(max_drop)[None, :] < counts[:, None])[..., None],
        dropped, 0.0)                                        # identity steps
    new_sig = drop_sig(carry.sig, dropped, carry.d, carry.depth)
    new_len = carry.length - counts
    # a fully-drained window is exactly the identity — no float drift
    new_sig = jnp.where((new_len == 0)[:, None], 0.0, new_sig)
    sig = jnp.where((counts > 0)[:, None], new_sig, carry.sig)
    return dataclasses.replace(carry, sig=sig, length=new_len)


# ---------------------------------------------------------------------------
# SignatureStream: the per-object static-occupancy view
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SignatureStream:
    """Carry for online signature updates (see module docstring).

    Construct with :func:`signature_stream_init`; update with
    :meth:`extend` / :meth:`rolling_drop` (both return new carries).
    """
    sig: jax.Array      # (B, D_sig) signature of the current window
    ring: jax.Array     # (B, capacity, d) the window's increments (R may be 0)
    length: int         # static: increments covered by ``sig``
    end: int            # static: ring write position
    d: int              # static: path dimension
    depth: int          # static: truncation depth

    @property
    def capacity(self) -> int:
        return self.ring.shape[1]

    @property
    def batch(self) -> int:
        return self.sig.shape[0]

    def extend(self, increments: jax.Array, **kw):
        return signature_stream_extend(self, increments, **kw)

    def rolling_drop(self, n: int):
        return signature_stream_rolling_drop(self, n)


jax.tree_util.register_dataclass(
    SignatureStream, data_fields=("sig", "ring"),
    meta_fields=("length", "end", "d", "depth"))


def signature_stream_init(batch: int, d: int, depth: int, *,
                          capacity: int = 0,
                          dtype=jnp.float32) -> SignatureStream:
    """Fresh carry: identity signature, empty ring.

    ``capacity`` is the ring size R: with a ring, the window may never hold
    more than R increments (extend past that raises — drop first), and up to
    ``length`` oldest increments can be dropped at any time.  ``capacity=0``
    disables the ring: expanding-window only, unbounded length.
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    if capacity < 0:
        raise ValueError("capacity must be >= 0")
    return SignatureStream(
        sig=jnp.zeros((batch, sig_dim(d, depth)), dtype),
        ring=jnp.zeros((batch, capacity, d), dtype),
        length=0, end=0, d=d, depth=depth)


def signature_stream_extend(state: SignatureStream, increments: jax.Array, *,
                            backend: str = "jax", backward: str = "inverse",
                            return_stream: bool = False,
                            stream_stride: int = 1):
    """Append a chunk of m new increments (B, m, d) to the window.

    Returns the new carry, or ``(carry, features)`` when
    ``return_stream=True`` — features (B, m_out, D_sig) are the per-step
    signatures S_{window_start, t} for every emitted step of the chunk
    (terminal step always included), fully differentiable.

    With a ring, ``length + m`` must stay within capacity (call
    :func:`signature_stream_rolling_drop` first to make room) — that is the
    invariant that keeps later drops exact.  Static occupancy means this
    check raises at trace time; the pooled spelling of the same update is
    :func:`stream_extend`.
    """
    B, m, d = increments.shape
    if d != state.d:
        raise ValueError(f"increment dim {d} != stream dim {state.d}")
    if B != state.batch:
        raise ValueError(f"batch {B} != stream batch {state.batch}")
    R = state.capacity
    if R and state.length + m > R:
        raise ValueError(
            f"extending by {m} would hold {state.length + m} increments in a "
            f"ring of capacity {R}; rolling_drop at least "
            f"{state.length + m - R} first")
    increments = increments.astype(state.sig.dtype)
    new_sig, feats = extend_sig(state.sig, increments, state.d, state.depth,
                                backend=backend, backward=backward,
                                return_stream=return_stream,
                                stream_stride=stream_stride)
    if R == 0:
        new = dataclasses.replace(state, sig=new_sig,
                                  length=state.length + m)
    else:
        idx = (state.end + jnp.arange(m)) % R
        new = dataclasses.replace(
            state, sig=new_sig, ring=state.ring.at[:, idx].set(increments),
            length=state.length + m, end=(state.end + m) % R)
    return (new, feats) if return_stream else new


def signature_stream_rolling_drop(state: SignatureStream,
                                  n: int) -> SignatureStream:
    """Drop the n oldest increments from the window: for each, the exact
    left-inverse update S ← exp(-ΔX_oldest) ⊗ S."""
    if state.capacity == 0:
        raise ValueError("rolling_drop needs a ring buffer: init the stream "
                         "with capacity > 0")
    if not 0 <= n <= state.length:
        raise ValueError(f"cannot drop {n} increments from a window of "
                         f"length {state.length}")
    if n == 0:
        return state
    if n == state.length:
        # dropping the whole window: the exact result is the identity —
        # skip the n-step inverse scan (and its accumulated float error)
        return dataclasses.replace(state, sig=jnp.zeros_like(state.sig),
                                   length=0)
    R = state.capacity
    start = (state.end - state.length) % R          # oldest retained slot
    idx = (start + jnp.arange(n)) % R
    dropped = jnp.take(state.ring, idx, axis=1)     # (B, n, d) oldest-first
    return dataclasses.replace(
        state, sig=drop_sig(state.sig, dropped, state.d, state.depth),
        length=state.length - n)
