"""Online signature state: the ``SignatureStream`` carry.

The streamed kernels answer "all prefix signatures of a path I already have";
this module answers the *online* question: path steps arrive chunk by chunk
(serving, sensors, tick data) and per-window signature features must stay
current without ever recomputing from scratch.  The carry is

- ``sig``    — (B, D_sig) flat signature of every increment in the current
               window, updated by Chen's identity  S' = S ⊗ S(chunk)  (one
               dispatch call per chunk, any backend);
- ``ring``   — (B, R, d) ring buffer holding exactly the window's increments,
               so the *left* end of the window can move too: dropping the
               oldest increment is the exact group operation
               S' = exp(-ΔX_oldest) ⊗ S  (Lemma 4.5 / Prop. 4.6 applied from
               the left — exact only because ΔX_oldest IS the leftmost
               increment of ``sig``, which the ring invariant guarantees);
- ``length`` / ``end`` — window length and ring write head.  These are
  *static* host ints: chunk sizes and drop counts fix them at trace time, so
  occupancy violations raise immediately instead of silently corrupting the
  window (a ring overwrite of an increment still covered by ``sig`` would
  make every later drop inexact).

All array operations are functional (a new ``SignatureStream`` is returned),
jit- and grad-compatible: the carry is a registered pytree with static
(d, depth, length, end) metadata.  ``extend(..., return_stream=True)``
additionally emits the per-step features S_{window_start, t} for every new
step — the carried prefix Chen-combined with the *streamed* chunk signature
from the engine dispatch, so the hot loop stays on the configured backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import tensor_ops as tops
from .signature import signature_from_increments
from .words import sig_dim


@dataclasses.dataclass(frozen=True)
class SignatureStream:
    """Carry for online signature updates (see module docstring).

    Construct with :func:`signature_stream_init`; update with
    :meth:`extend` / :meth:`rolling_drop` (both return new carries).
    """
    sig: jax.Array      # (B, D_sig) signature of the current window
    ring: jax.Array     # (B, capacity, d) the window's increments (R may be 0)
    length: int         # static: increments covered by ``sig``
    end: int            # static: ring write position
    d: int              # static: path dimension
    depth: int          # static: truncation depth

    @property
    def capacity(self) -> int:
        return self.ring.shape[1]

    @property
    def batch(self) -> int:
        return self.sig.shape[0]

    def extend(self, increments: jax.Array, **kw):
        return signature_stream_extend(self, increments, **kw)

    def rolling_drop(self, n: int):
        return signature_stream_rolling_drop(self, n)


jax.tree_util.register_dataclass(
    SignatureStream, data_fields=("sig", "ring"),
    meta_fields=("length", "end", "d", "depth"))


def signature_stream_init(batch: int, d: int, depth: int, *,
                          capacity: int = 0,
                          dtype=jnp.float32) -> SignatureStream:
    """Fresh carry: identity signature, empty ring.

    ``capacity`` is the ring size R: with a ring, the window may never hold
    more than R increments (extend past that raises — drop first), and up to
    ``length`` oldest increments can be dropped at any time.  ``capacity=0``
    disables the ring: expanding-window only, unbounded length.
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    if capacity < 0:
        raise ValueError("capacity must be >= 0")
    return SignatureStream(
        sig=jnp.zeros((batch, sig_dim(d, depth)), dtype),
        ring=jnp.zeros((batch, capacity, d), dtype),
        length=0, end=0, d=d, depth=depth)


def _combine_flat(prefix_flat: jax.Array, chunk_flat: jax.Array, d: int,
                  depth: int) -> jax.Array:
    """Chen combine with broadcasting: prefix (B, D) ⊗ chunk (B, T, D)."""
    a = [jnp.broadcast_to(lv[:, None], (*chunk_flat.shape[:2], lv.shape[-1]))
         for lv in tops.flat_to_levels(prefix_flat, d, depth)]
    b = tops.flat_to_levels(chunk_flat, d, depth)
    return tops.levels_to_flat(tops.chen_mul(a, b))


def signature_stream_extend(state: SignatureStream, increments: jax.Array, *,
                            backend: str = "jax", backward: str = "inverse",
                            return_stream: bool = False,
                            stream_stride: int = 1):
    """Append a chunk of m new increments (B, m, d) to the window.

    Returns the new carry, or ``(carry, features)`` when
    ``return_stream=True`` — features (B, m_out, D_sig) are the per-step
    signatures S_{window_start, t} for every emitted step of the chunk
    (terminal step always included), fully differentiable.

    With a ring, ``length + m`` must stay within capacity (call
    :func:`signature_stream_rolling_drop` first to make room) — that is the
    invariant that keeps later drops exact.
    """
    B, m, d = increments.shape
    if d != state.d:
        raise ValueError(f"increment dim {d} != stream dim {state.d}")
    if B != state.batch:
        raise ValueError(f"batch {B} != stream batch {state.batch}")
    R = state.capacity
    if R and state.length + m > R:
        raise ValueError(
            f"extending by {m} would hold {state.length + m} increments in a "
            f"ring of capacity {R}; rolling_drop at least "
            f"{state.length + m - R} first")
    increments = increments.astype(state.sig.dtype)
    if return_stream:
        chunk = signature_from_increments(
            increments, state.depth, stream=True, stream_stride=stream_stride,
            backward=backward, backend=backend)        # (B, m_out, D)
        feats = _combine_flat(state.sig, chunk, state.d, state.depth)
        new_sig = feats[:, -1]
    else:
        chunk = signature_from_increments(increments, state.depth,
                                          backward=backward, backend=backend)
        new_sig = _combine_flat(state.sig, chunk[:, None], state.d,
                                state.depth)[:, 0]
    if R == 0:
        new = dataclasses.replace(state, sig=new_sig,
                                  length=state.length + m)
    else:
        idx = (state.end + jnp.arange(m)) % R
        new = dataclasses.replace(
            state, sig=new_sig, ring=state.ring.at[:, idx].set(increments),
            length=state.length + m, end=(state.end + m) % R)
    return (new, feats) if return_stream else new


def signature_stream_rolling_drop(state: SignatureStream,
                                  n: int) -> SignatureStream:
    """Drop the n oldest increments from the window: for each, the exact
    left-inverse update S ← exp(-ΔX_oldest) ⊗ S."""
    if state.capacity == 0:
        raise ValueError("rolling_drop needs a ring buffer: init the stream "
                         "with capacity > 0")
    if not 0 <= n <= state.length:
        raise ValueError(f"cannot drop {n} increments from a window of "
                         f"length {state.length}")
    if n == 0:
        return state
    if n == state.length:
        # dropping the whole window: the exact result is the identity —
        # skip the n-step inverse scan (and its accumulated float error)
        return dataclasses.replace(state, sig=jnp.zeros_like(state.sig),
                                   length=0)
    R = state.capacity
    start = (state.end - state.length) % R          # oldest retained slot
    idx = (start + jnp.arange(n)) % R
    dropped = jnp.take(state.ring, idx, axis=1)     # (B, n, d) oldest-first

    def step(levels, dx):
        e = tops.tensor_exp(-dx, state.depth)
        return tops.chen_mul(e, levels), None

    levels = tops.flat_to_levels(state.sig, state.d, state.depth)
    levels, _ = jax.lax.scan(step, levels, jnp.moveaxis(dropped, 1, 0))
    return dataclasses.replace(state, sig=tops.levels_to_flat(levels),
                               length=state.length - n)
