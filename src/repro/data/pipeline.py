"""Data pipeline: synthetic LM token streams (deterministic, seekable —
checkpointable), fBM path generation for the paper's §8 experiment, and a
host-sharded loader abstraction for multi-process launches.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# synthetic LM stream (seekable => data state lives in the checkpoint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM stream with a Zipfian unigram + a short
    Markov dependency so the loss has learnable structure.

    ``state`` is just the step counter — restoring it resumes the exact
    stream (fault-tolerant input pipeline).
    """
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, self.vocab_size, size=8)

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        base = rng.choice(self.vocab_size, size=(self.batch, self.seq + 1),
                          p=self._p)
        # inject short-range structure: x[t] sometimes determined by x[t-1]
        det = (base[:, :-1] + self._shift[self.step % 8]) % self.vocab_size
        mask = rng.random((self.batch, self.seq)) < 0.5
        nxt = np.where(mask, det, base[:, 1:])
        tokens = np.concatenate([base[:, :1], nxt], axis=1)
        self.step += 1
        return {"tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
                "labels": jnp.asarray(tokens[:, 1:], jnp.int32)}


def synthetic_lm_batches(vocab_size: int, batch: int, seq: int,
                         seed: int = 0) -> Iterator[dict]:
    return iter(TokenStream(vocab_size, batch, seq, seed))


# ---------------------------------------------------------------------------
# fractional Brownian motion (paper §8)
# ---------------------------------------------------------------------------

def fbm_paths(rng: np.random.Generator, n_paths: int, n_steps: int,
              hurst: np.ndarray | float, d: int = 1,
              T: float = 1.0) -> np.ndarray:
    """Exact fBM via Cholesky of the fBM covariance (per Hurst exponent).

    hurst: scalar or (n_paths,) array (H ~ U(0.25, 0.75) in the paper).
    Returns (n_paths, n_steps+1, d), X_0 = 0, components independent.
    """
    H = np.broadcast_to(np.asarray(hurst, np.float64), (n_paths,))
    t = np.linspace(T / n_steps, T, n_steps)
    out = np.zeros((n_paths, n_steps + 1, d), np.float32)
    # group paths by identical H to reuse the Cholesky factor
    uniq, inv = np.unique(np.round(H, 6), return_inverse=True)
    for u_i, h in enumerate(uniq):
        idx = np.nonzero(inv == u_i)[0]
        tt = t[:, None]
        ss = t[None, :]
        cov = 0.5 * (tt ** (2 * h) + ss ** (2 * h) - np.abs(tt - ss) ** (2 * h))
        L = np.linalg.cholesky(cov + 1e-12 * np.eye(n_steps))
        z = rng.standard_normal((len(idx), n_steps, d))
        out[idx, 1:, :] = np.einsum("ts,psd->ptd", L, z).astype(np.float32)
    return out


def hurst_dataset(seed: int, n_paths: int, n_steps: int, d: int,
                  h_range=(0.25, 0.75)) -> tuple[np.ndarray, np.ndarray]:
    """(paths (N, M+1, d), H (N,)) — the paper's §8 Hurst-estimation data."""
    rng = np.random.default_rng(seed)
    H = rng.uniform(*h_range, size=n_paths)
    X = fbm_paths(rng, n_paths, n_steps, H, d)
    return X, H.astype(np.float32)


# ---------------------------------------------------------------------------
# ragged (variable-length) generators — trainer and benchmarks draw their
# mixed-length workloads from the SAME deterministic, seekable pipeline
# ---------------------------------------------------------------------------

def geometric_lengths(seed: int, n: int, max_steps: int, min_steps: int = 2,
                      mean_frac: float = 0.25) -> np.ndarray:
    """Deterministic geometric-ish per-request lengths in
    [min_steps, max_steps].

    ``mean_frac`` sets the pre-clip mean to ``mean_frac · max_steps``; the
    resulting clipped distribution has max/median >= ~4 for the default —
    the serving-traffic shape the ragged benchmarks assume.  Same (seed, n,
    max_steps) -> same lengths, always.
    """
    if not 1 <= min_steps <= max_steps:
        raise ValueError(f"need 1 <= min_steps <= max_steps, got "
                         f"{min_steps}, {max_steps}")
    rng = np.random.default_rng((7919, seed))  # domain-separated from paths
    p = min(1.0, 1.0 / max(mean_frac * max_steps, 1.0))
    return np.clip(rng.geometric(p, size=n), min_steps,
                   max_steps).astype(np.int64)


def ragged_fbm_dataset(seed: int, n_paths: int, max_steps: int, d: int,
                       h_range=(0.25, 0.75), min_steps: int = 2):
    """Variable-length fBM batch: (values (N, max_steps+1, d) frozen-tail
    padded, lengths (N,), H (N,)) — the ragged spelling of
    :func:`hurst_dataset` (each path is a true L_i-step fBM; the tail holds
    its endpoint, so padded increments are zero)."""
    rng = np.random.default_rng(seed)
    H = rng.uniform(*h_range, size=n_paths)
    lengths = geometric_lengths(seed, n_paths, max_steps,
                                min_steps=min_steps)
    X = fbm_paths(rng, n_paths, max_steps, H, d)
    k = np.arange(max_steps + 1)[None, :]
    idx = np.minimum(k, lengths[:, None])                # frozen tail
    X = np.take_along_axis(X, idx[..., None], axis=1)
    return X, lengths.astype(np.int32), H.astype(np.float32)


@dataclasses.dataclass
class RaggedPathStream:
    """Deterministic, seekable stream of variable-length path batches.

    Each batch is ``{"paths": (B, max_steps+1, d) frozen-tail padded,
    "path_lengths": (B,) int32}`` — exactly the keys
    ``TrainLoopConfig(loss="sig_mmd")`` consumes as ragged reference
    sample, and the workload generator the ragged serving benchmarks reuse.
    ``kind="walk"`` draws scaled Gaussian random walks; ``"fbm"`` draws
    per-example-Hurst fBM (slower: one Cholesky per distinct H).
    Restoring ``state()`` resumes the exact stream (the per-batch RNG is
    keyed by (seed, step)).
    """
    batch: int
    max_steps: int
    d: int
    seed: int = 0
    min_steps: int = 2
    kind: str = "walk"          # "walk" | "fbm"
    step: int = 0

    def __post_init__(self):
        if self.kind not in ("walk", "fbm"):
            raise ValueError(f"unknown kind {self.kind!r}")

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        lengths = geometric_lengths(self.seed * 1_000_003 + self.step,
                                    self.batch, self.max_steps,
                                    min_steps=self.min_steps)
        if self.kind == "fbm":
            H = rng.uniform(0.25, 0.75, size=self.batch)
            X = fbm_paths(rng, self.batch, self.max_steps, H, self.d)
        else:
            steps = rng.standard_normal(
                (self.batch, self.max_steps, self.d)).astype(np.float32)
            steps /= np.sqrt(np.maximum(lengths, 1))[:, None, None]
            X = np.concatenate(
                [np.zeros((self.batch, 1, self.d), np.float32),
                 np.cumsum(steps, axis=1)], axis=1)
        k = np.arange(self.max_steps + 1)[None, :]
        idx = np.minimum(k, lengths[:, None])            # frozen tail
        X = np.take_along_axis(X, idx[..., None], axis=1)
        self.step += 1
        return {"paths": jnp.asarray(X),
                "path_lengths": jnp.asarray(lengths, jnp.int32)}


def ragged_token_batches(vocab_size: int, batch: int, seq: int,
                         seed: int = 0) -> Iterator[dict]:
    """Variable-length LM stream: :class:`TokenStream` batches plus a
    right-padded ``"mask"`` (tokens past each example's deterministic
    length are zeroed) — the ragged spelling the sig-head/trainer ``mask``
    pass-through consumes."""
    stream = TokenStream(vocab_size, batch, seq, seed)
    for item in stream:
        lengths = geometric_lengths(seed * 1_000_003 + stream.step,
                                    batch, seq, min_steps=2)
        mask = (np.arange(seq)[None, :] < lengths[:, None])
        tokens = np.asarray(item["tokens"]) * mask
        labels = np.where(mask, np.asarray(item["labels"]), -1)
        yield {"tokens": jnp.asarray(tokens, jnp.int32),
               "labels": jnp.asarray(labels, jnp.int32),
               "mask": jnp.asarray(mask, jnp.int32)}


# ---------------------------------------------------------------------------
# multi-tenant session traffic (the SessionStore ingest workload)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionTickStream:
    """Deterministic bursty multi-tenant tick traffic for a session pool.

    Models the serving workload :class:`repro.serve.SessionStore` is built
    for: a population of sessions with **heavy-tailed per-session tick
    rates** (a few whales stream constantly, a long tail ticks rarely —
    Pareto-distributed rates), plus **arrival/churn** (new sessions appear
    at ``arrival_rate`` per round, live ones leave with probability
    ``churn_prob``).

    Each ``next()`` is one ingest round, shaped for
    ``SessionStore.ingest_many``::

        {"sids":       [k active session ids that tick this round],
         "counts":     (k,) int64 per-sid tick counts (>= 1),
         "ticks":      (sum(counts), d) float32 increments, sids order,
         "departures": [sids churning out after this round]}

    Deterministic and seekable: every draw is keyed by (seed, step) and the
    per-session rate by (seed, sid index), so the same seed replays the
    same traffic and ``state()``/``restore()`` resume it exactly — traffic
    replay across a checkpoint/restart is what makes the resume tests
    meaningful.
    """
    n_sessions: int             # initial population
    d: int
    seed: int = 0
    mean_ticks: float = 3.0     # mean per-tick burst length of a rate-1 user
    max_ticks: int = 64         # burst cap per session per round
    tick_prob: float = 0.3      # base per-round tick probability
    arrival_rate: float = 0.0   # Poisson new sessions per round
    churn_prob: float = 0.0     # per-session departure probability per round
    scale: float = 0.1          # increment std
    step: int = 0

    def __post_init__(self):
        if self.n_sessions < 1 or self.d < 1:
            raise ValueError("need n_sessions >= 1 and d >= 1")
        self._active: list[int] = list(range(self.n_sessions))
        self._next_id = self.n_sessions
        self._rates: dict[int, float] = {}

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed,
                "active": list(self._active), "next_id": self._next_id}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self._active = [int(s) for s in state["active"]]
        self._next_id = int(state["next_id"])

    def _rate(self, idx: int) -> float:
        """Heavy-tailed per-session activity multiplier (Pareto α=1.2),
        fixed for the session's lifetime and keyed only by (seed, idx) — a
        pure function, so the memo survives ``restore`` unchanged."""
        r = self._rates.get(idx)
        if r is None:
            g = np.random.default_rng((self.seed, 104729, idx))
            r = self._rates[idx] = float(1.0 + g.pareto(1.2))
        return r

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        # arrivals join before the round so a fresh session can tick at once
        n_new = int(rng.poisson(self.arrival_rate)) if self.arrival_rate \
            else 0
        self._active.extend(range(self._next_id, self._next_id + n_new))
        self._next_id += n_new
        active = np.asarray(self._active, np.int64)
        rates = np.asarray([self._rate(i) for i in active])
        ticking = rng.random(len(active)) < np.minimum(
            1.0, self.tick_prob * rates)
        sids = active[ticking]
        # burst length ~ geometric with a rate-scaled mean, capped
        mean = np.minimum(self.mean_ticks * rates[ticking], self.max_ticks)
        counts = np.clip(rng.geometric(1.0 / np.maximum(mean, 1.0)),
                         1, self.max_ticks).astype(np.int64)
        ticks = (rng.standard_normal((int(counts.sum()), self.d)) *
                 self.scale).astype(np.float32)
        leave = rng.random(len(active)) < self.churn_prob
        departures = active[leave].tolist()
        self._active = active[~leave].tolist()
        self.step += 1
        return {"sids": [f"u{i}" for i in sids],
                "counts": counts,
                "ticks": ticks,
                "departures": [f"u{i}" for i in departures]}


def session_tick_stream(n_sessions: int, d: int, seed: int = 0,
                        **kw) -> SessionTickStream:
    """Bursty multi-tenant ingest traffic (see :class:`SessionTickStream`)."""
    return SessionTickStream(n_sessions, d, seed, **kw)


# ---------------------------------------------------------------------------
# host-sharded loader
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedLoader:
    """Wraps a stream so each host reads only its shard of the global batch.

    In a multi-process launch, process i of n loads rows [i·B/n, (i+1)·B/n);
    with jax.make_array_from_process_local_data the global batch is assembled
    without cross-host traffic.  On a single process this is an identity.
    """
    stream: TokenStream
    process_index: int = 0
    process_count: int = 1

    def state(self):
        return self.stream.state()

    def restore(self, st):
        self.stream.restore(st)

    def __iter__(self):
        return self

    def __next__(self):
        batch = next(self.stream)
        if self.process_count == 1:
            return batch
        def shard(x):
            B = x.shape[0]
            per = B // self.process_count
            return x[self.process_index * per:(self.process_index + 1) * per]
        return jax.tree.map(shard, batch)
