"""Data pipeline: synthetic LM token streams (deterministic, seekable —
checkpointable), fBM path generation for the paper's §8 experiment, and a
host-sharded loader abstraction for multi-process launches.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# synthetic LM stream (seekable => data state lives in the checkpoint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM stream with a Zipfian unigram + a short
    Markov dependency so the loss has learnable structure.

    ``state`` is just the step counter — restoring it resumes the exact
    stream (fault-tolerant input pipeline).
    """
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1)
        self._p = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._shift = rng.integers(1, self.vocab_size, size=8)

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        base = rng.choice(self.vocab_size, size=(self.batch, self.seq + 1),
                          p=self._p)
        # inject short-range structure: x[t] sometimes determined by x[t-1]
        det = (base[:, :-1] + self._shift[self.step % 8]) % self.vocab_size
        mask = rng.random((self.batch, self.seq)) < 0.5
        nxt = np.where(mask, det, base[:, 1:])
        tokens = np.concatenate([base[:, :1], nxt], axis=1)
        self.step += 1
        return {"tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
                "labels": jnp.asarray(tokens[:, 1:], jnp.int32)}


def synthetic_lm_batches(vocab_size: int, batch: int, seq: int,
                         seed: int = 0) -> Iterator[dict]:
    return iter(TokenStream(vocab_size, batch, seq, seed))


# ---------------------------------------------------------------------------
# fractional Brownian motion (paper §8)
# ---------------------------------------------------------------------------

def fbm_paths(rng: np.random.Generator, n_paths: int, n_steps: int,
              hurst: np.ndarray | float, d: int = 1,
              T: float = 1.0) -> np.ndarray:
    """Exact fBM via Cholesky of the fBM covariance (per Hurst exponent).

    hurst: scalar or (n_paths,) array (H ~ U(0.25, 0.75) in the paper).
    Returns (n_paths, n_steps+1, d), X_0 = 0, components independent.
    """
    H = np.broadcast_to(np.asarray(hurst, np.float64), (n_paths,))
    t = np.linspace(T / n_steps, T, n_steps)
    out = np.zeros((n_paths, n_steps + 1, d), np.float32)
    # group paths by identical H to reuse the Cholesky factor
    uniq, inv = np.unique(np.round(H, 6), return_inverse=True)
    for u_i, h in enumerate(uniq):
        idx = np.nonzero(inv == u_i)[0]
        tt = t[:, None]
        ss = t[None, :]
        cov = 0.5 * (tt ** (2 * h) + ss ** (2 * h) - np.abs(tt - ss) ** (2 * h))
        L = np.linalg.cholesky(cov + 1e-12 * np.eye(n_steps))
        z = rng.standard_normal((len(idx), n_steps, d))
        out[idx, 1:, :] = np.einsum("ts,psd->ptd", L, z).astype(np.float32)
    return out


def hurst_dataset(seed: int, n_paths: int, n_steps: int, d: int,
                  h_range=(0.25, 0.75)) -> tuple[np.ndarray, np.ndarray]:
    """(paths (N, M+1, d), H (N,)) — the paper's §8 Hurst-estimation data."""
    rng = np.random.default_rng(seed)
    H = rng.uniform(*h_range, size=n_paths)
    X = fbm_paths(rng, n_paths, n_steps, H, d)
    return X, H.astype(np.float32)


# ---------------------------------------------------------------------------
# host-sharded loader
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedLoader:
    """Wraps a stream so each host reads only its shard of the global batch.

    In a multi-process launch, process i of n loads rows [i·B/n, (i+1)·B/n);
    with jax.make_array_from_process_local_data the global batch is assembled
    without cross-host traffic.  On a single process this is an identity.
    """
    stream: TokenStream
    process_index: int = 0
    process_count: int = 1

    def state(self):
        return self.stream.state()

    def restore(self, st):
        self.stream.restore(st)

    def __iter__(self):
        return self

    def __next__(self):
        batch = next(self.stream)
        if self.process_count == 1:
            return batch
        def shard(x):
            B = x.shape[0]
            per = B // self.process_count
            return x[self.process_index * per:(self.process_index + 1) * per]
        return jax.tree.map(shard, batch)
