from .pipeline import (TokenStream, fbm_paths, synthetic_lm_batches,
                       hurst_dataset, ShardedLoader)
