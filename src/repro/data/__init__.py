from .pipeline import (RaggedPathStream, ShardedLoader, TokenStream,
                       fbm_paths, geometric_lengths, hurst_dataset,
                       ragged_fbm_dataset, ragged_token_batches,
                       synthetic_lm_batches)
