from .pipeline import (RaggedPathStream, SessionTickStream, ShardedLoader,
                       TokenStream, fbm_paths, geometric_lengths,
                       hurst_dataset, ragged_fbm_dataset,
                       ragged_token_batches, session_tick_stream,
                       synthetic_lm_batches)
