"""Pallas TPU kernels for the paper's compute hot-spot (signature updates),
with jit'd wrappers (ops) and pure-jnp oracles (ref)."""
from . import ops, ref
from .sig_trunc import sig_trunc, choose_split, cone_rows
from .sig_words import sig_words

__all__ = ["ops", "ref", "sig_trunc", "sig_words", "choose_split", "cone_rows"]
