"""Pallas TPU kernels for the paper's compute hot-spot (signature updates),
with jit'd wrappers (ops) and pure-jnp oracles (ref)."""
from . import ops, ref
from .ops import (BoundedCache, clear_plan_caches, plan_cache_info,
                  set_plan_cache_maxsize)
from .sig_gram import sig_gram_tiles
from .sig_trunc import sig_trunc, choose_split, cone_rows
from .sig_words import sig_words

__all__ = ["ops", "ref", "sig_trunc", "sig_words", "sig_gram_tiles",
           "choose_split", "cone_rows", "BoundedCache", "clear_plan_caches",
           "plan_cache_info", "set_plan_cache_maxsize"]
