"""Pallas TPU kernel: weighted signature Gram tiles.

The truncated signature kernel is a *weighted inner product over word
coordinates*:  k_ω(x, y) = Σ_w ω_w ⟨S(x), w⟩⟨S(y), w⟩ = (S_x diag(ω) S_yᵀ).
This kernel computes the (B_x, B_y) Gram matrix **blocked over the word
axis**: grid cell (i, j, k) loads the (bx_tile, k_tile) / (by_tile, k_tile)
signature slabs of word block k, fuses the weighting ω into the left operand
on the VPU, and accumulates the partial product into the (bx_tile, by_tile)
output block on the MXU.  The (B_x, B_y, D_sig) elementwise intermediate of
the textbook formula is never materialised — live state per cell is the
output tile plus two signature slabs, O(B_x·B_y + B·D_tile).

The word axis is the innermost grid dimension, so the output block is
revisited across k and the accumulation is the standard Pallas reduction
pattern (init at k == 0, += after).  Zero-padding the *weights* (not just
the signatures) makes padded word columns exact no-ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _kernel(sx_ref, sy_ref, w_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sxw = sx_ref[...] * w_ref[...]          # (bx, kt) * (1, kt): fused ω
    out_ref[...] += jax.lax.dot_general(    # contract the word block on MXU
        sxw, sy_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bx_tile", "by_tile", "k_tile",
                                             "interpret"))
def sig_gram_tiles(Sx: jax.Array, Sy: jax.Array, weights: jax.Array, *,
                   bx_tile: int = 128, by_tile: int = 128, k_tile: int = 512,
                   interpret: bool = True) -> jax.Array:
    """Weighted Gram of signature coordinate matrices.

    Sx: (B_x, D), Sy: (B_y, D), weights: (D,)  ->  (B_x, B_y) float32 with
    G[i, j] = Σ_k Sx[i, k] · weights[k] · Sy[j, k].
    """
    from repro import obs
    obs.count_trace("sig_gram_tiles", Sx, Sy, bx_tile=bx_tile,
                    by_tile=by_tile, k_tile=k_tile)
    Bx, D = Sx.shape
    By, D2 = Sy.shape
    if D2 != D or weights.shape != (D,):
        raise ValueError(f"shape mismatch: Sx {Sx.shape}, Sy {Sy.shape}, "
                         f"weights {weights.shape}")
    bx = min(bx_tile, _round_up(Bx, 8))
    by = min(by_tile, _round_up(By, 8))
    kt = min(k_tile, _round_up(D, 128))
    Bx_p, By_p, D_p = _round_up(Bx, bx), _round_up(By, by), _round_up(D, kt)
    x = jnp.pad(Sx, ((0, Bx_p - Bx), (0, D_p - D))).astype(jnp.float32)
    y = jnp.pad(Sy, ((0, By_p - By), (0, D_p - D))).astype(jnp.float32)
    w = jnp.pad(weights.astype(jnp.float32), (0, D_p - D))[None, :]

    out = pl.pallas_call(
        _kernel,
        grid=(Bx_p // bx, By_p // by, D_p // kt),   # word blocks innermost
        in_specs=[
            pl.BlockSpec((bx, kt), lambda i, j, k: (i, k)),
            pl.BlockSpec((by, kt), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, kt), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((bx, by), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bx_p, By_p), jnp.float32),
        interpret=interpret,
    )(x, y, w)
    return out[:Bx, :By]
