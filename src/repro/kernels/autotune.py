"""Persistent per-cell autotuner for the Pallas dispatch layer.

The kernels' tile shapes (``batch_tile``, the level ``split`` of the cone
kernel, the Gram block shapes) are workload-dependent: interpret mode pays
~4x wasted compute when B=32 is padded to the default ``batch_tile=128``,
while a compiled TPU run wants the full 128-lane tile.  This module keeps a
small JSON cache of measured winners keyed by dispatch *cell* — (kind, d,
depth, pow2-bucketed M and B, engine, precision) — which
:mod:`repro.kernels.ops` consults whenever the caller does not pass an
explicit tile.

Environment control (read per call, so tests can monkeypatch):

``PATHSIG_AUTOTUNE``
    ``off``   — never consult or write the cache: library defaults.
    ``load``  — (default) consult the cache, never measure.
    ``sweep`` — consult the cache; on a miss, measure the candidate grid for
    that cell once, persist the winner, and use it from then on.

``PATHSIG_AUTOTUNE_CACHE``
    Cache file path (default ``.pathsig_autotune.json`` in the CWD).

Safety rails:

* the library default configuration is ALWAYS a sweep candidate, and a
  non-default winner is only recorded when it beats the default by >= 10%
  (hysteresis) — so an autotuned cell can never lose to the default by more
  than timing noise;
* a corrupt / unreadable / wrong-version cache file degrades to the empty
  cache with a one-time warning — never an exception on the hot path;
* lookups with non-concrete (traced) cell values return the defaults.

CLI: ``python -m repro.kernels.autotune --quick`` sweeps a small paper-grid
set of cells and writes the cache (used by the CI benchmark job).
"""
from __future__ import annotations

import argparse
import json
import os
import time
import warnings
from pathlib import Path

from repro import obs

__all__ = ["lookup", "cell_key", "load_cache", "save_cache", "sweep_cell",
           "clear", "cache_path", "mode", "main"]

_VERSION = 1
_DEFAULT_CACHE = ".pathsig_autotune.json"

# in-memory cache: {path: cells-dict}; invalidated via clear()
_caches: dict[str, dict] = {}
_warned: set[str] = set()
_sweeping = False  # reentrancy guard: sweeps call back into the dispatch


def mode() -> str:
    m = os.environ.get("PATHSIG_AUTOTUNE", "load").strip().lower()
    if m not in ("off", "load", "sweep"):
        _warn_once(f"PATHSIG_AUTOTUNE={m!r} is not off|load|sweep; "
                   "treating as 'off'")
        return "off"
    return m


def cache_path() -> Path:
    return Path(os.environ.get("PATHSIG_AUTOTUNE_CACHE", _DEFAULT_CACHE))


def _warn_once(msg: str) -> None:
    if msg not in _warned:
        _warned.add(msg)
        warnings.warn(msg, stacklevel=3)


def clear() -> None:
    """Drop the in-memory cache + warning dedup (tests / env changes)."""
    _caches.clear()
    _warned.clear()


def _bucket(n: int) -> int:
    """Pow2 ceiling — cells generalise across nearby sizes."""
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


_BUCKETED = ("M", "B", "Bx", "By", "D")


def cell_key(kind: str, **cell) -> str:
    """Canonical cache key.  Size-like axes (M, B, Bx, By, D) are bucketed
    to the next power of two; structural axes (d, depth, engine, precision)
    are exact."""
    parts = [kind]
    for k in sorted(cell):
        v = cell[k]
        if k in _BUCKETED:
            v = _bucket(v)
        parts.append(f"{k}={v}")
    return "|".join(parts)


def load_cache(path: Path | None = None) -> dict:
    """-> the cells dict for ``path`` (never raises; corrupt -> {})."""
    path = cache_path() if path is None else Path(path)
    key = str(path)
    if key in _caches:
        return _caches[key]
    cells: dict = {}
    if path.exists():
        try:
            raw = json.loads(path.read_text())
            if not isinstance(raw, dict) or raw.get("version") != _VERSION \
                    or not isinstance(raw.get("cells"), dict):
                raise ValueError(f"bad schema (want version={_VERSION} with "
                                 "a 'cells' dict)")
            cells = {k: v for k, v in raw["cells"].items()
                     if isinstance(v, dict)}
        except Exception as e:  # corrupt cache must never break dispatch
            _warn_once(f"ignoring corrupt autotune cache {path}: {e}")
            cells = {}
    _caches[key] = cells
    return cells


def save_cache(cells: dict, path: Path | None = None) -> None:
    path = cache_path() if path is None else Path(path)
    try:
        path.write_text(json.dumps({"version": _VERSION, "cells": cells},
                                   indent=1, sort_keys=True) + "\n")
        _caches[str(path)] = cells
    except OSError as e:
        _warn_once(f"cannot write autotune cache {path}: {e}")


def _concrete(cell: dict) -> dict | None:
    """Cell with int-able sizes, or None if anything is traced/abstract."""
    out = {}
    for k, v in cell.items():
        if isinstance(v, str):
            out[k] = v
            continue
        try:
            out[k] = int(v)
        except TypeError:
            return None
    return out


def _count_lookup(kind: str, outcome: str) -> None:
    if not obs.enabled():
        return
    obs.counter("pathsig_autotune_lookups_total",
                "autotune cache consultations by outcome "
                "(hit/miss/sweep/off/traced/jax_engine)",
                ("kind", "outcome")).inc(kind=kind, outcome=outcome)


def lookup(kind: str, **cell) -> dict:
    """The cached record for a dispatch cell ({} on miss / off / traced).

    In ``sweep`` mode a miss triggers a one-off candidate sweep for the cell
    (measured with synthetic data of the cell's shape), whose winner is
    persisted and returned.  Every consultation ticks
    ``pathsig_autotune_lookups_total{kind=,outcome=}`` when metrics are on."""
    m = mode()
    if m == "off" or _sweeping:
        _count_lookup(kind, "off")
        return {}
    cell = _concrete(cell)
    if cell is None:
        _count_lookup(kind, "traced")
        return {}
    if cell.get("engine") == "jax":
        _count_lookup(kind, "jax_engine")
        return {}  # tile shapes are a Pallas concern
    key = cell_key(kind, **cell)
    cells = load_cache()
    hit = cells.get(key)
    if hit is not None:
        _count_lookup(kind, "hit")
        return hit
    if m != "sweep":
        _count_lookup(kind, "miss")
        return {}
    _count_lookup(kind, "sweep")
    rec = sweep_cell(kind, cell)
    if rec:
        cells[key] = rec
        save_cache(cells)
    return rec


# ---------------------------------------------------------------------------
# sweeps
# ---------------------------------------------------------------------------

def _median_time(fn, repeats: int = 3) -> float:
    fn()  # compile + warm caches
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _backend_name(engine: str) -> str:
    return "pallas_interpret" if engine == "pallas" else engine


def _pick(timed: list[tuple[float, dict]], default: dict,
          hysteresis: float = 0.9) -> dict:
    """Winner with default-bias: the default config is always present, and a
    non-default candidate must beat it by >= (1 - hysteresis) to be chosen."""
    t_default = next(t for t, rec in timed if rec == default)
    t_best, best = min(timed, key=lambda p: p[0])
    if best != default and t_best < hysteresis * t_default:
        return best
    return default


def _sig_candidates(depth: int, B: int) -> list[dict]:
    tiles = sorted({bt for bt in (8, 32, 128) if bt >= 8} |
                   {min(128, max(8, _bucket(B)))})
    splits = sorted({s for s in (None, 2, depth - 1) if s is None
                     or 1 <= s < depth}, key=lambda s: -1 if s is None else s)
    return [{"batch_tile": bt, "split": sp} for bt in tiles for sp in splits]


def sweep_cell(kind: str, cell: dict, repeats: int = 3) -> dict:
    """Measure the candidate grid for one dispatch cell on synthetic data of
    the cell's shape; -> the winning record ({} when the cell has nothing to
    tune).  Never raises: a failing candidate is skipped, a failing sweep
    returns {}."""
    global _sweeping
    import jax
    import numpy as np
    from repro.kernels import ops

    engine = cell.get("engine", "pallas")
    if engine == "jax":
        return {}
    backend = _backend_name(engine)
    precision = cell.get("precision", "fp32")
    rng = np.random.default_rng(0)
    _sweeping = True
    try:
        timed: list[tuple[float, dict]] = []
        if kind in ("sig_trunc", "sig_words"):
            B, M, d, depth = (cell["B"], cell["M"], cell["d"], cell["depth"])
            x = jax.numpy.asarray(
                rng.standard_normal((B, M, d), np.float32) * 0.1)
            if kind == "sig_trunc":
                cands = _sig_candidates(depth, B)
                default = {"batch_tile": 128, "split": None}

                def run(rec):
                    return ops.signature(
                        x, depth, backend=backend, precision=precision,
                        batch_tile=rec["batch_tile"], split=rec["split"])
            else:
                from repro.core.words import all_words
                words = tuple(all_words(d, depth))
                cands = [{"batch_tile": bt}
                         for bt in sorted({8, 32, 128} |
                                          {min(128, max(8, _bucket(B)))})]
                default = {"batch_tile": 128}

                def run(rec):
                    return ops.projected(
                        x, words, backend=backend, precision=precision,
                        batch_tile=rec["batch_tile"])
        elif kind == "gram":
            D, Bx, By = cell["D"], cell["Bx"], cell["By"]
            Sx = jax.numpy.asarray(
                rng.standard_normal((Bx, D), np.float32) * 0.1)
            Sy = jax.numpy.asarray(
                rng.standard_normal((By, D), np.float32) * 0.1)
            w = jax.numpy.asarray(rng.random(D, dtype=np.float32))
            cands = [{"block_words": bw, "bx_tile": bx, "by_tile": by}
                     for bw in (128, 512)
                     for bx in sorted({128, min(128, max(8, _bucket(Bx)))})
                     for by in sorted({128, min(128, max(8, _bucket(By)))})]
            default = {"block_words": 512, "bx_tile": 128, "by_tile": 128}

            def run(rec):
                return ops.gram(Sx, Sy, w, backend=backend,
                                precision=precision, **rec)
        elif kind == "gram_ring":
            # per-step tiles of the cross-device ppermute ring: only
            # sweepable under a live mesh whose "batch" axis matches the
            # cell's P (the lookup happens inside gram() under the caller's
            # sharding context, so that context is ambient here)
            from repro.distributed.ctx import current_mesh, logical_axis_size
            P = int(cell.get("P", 0))
            if current_mesh() is None or P < 2 \
                    or logical_axis_size("batch") != P:
                return {}
            D = cell["D"]
            Bx, By = cell["Bx"] * P, cell["By"] * P  # cell keys = per-shard
            Sx = jax.numpy.asarray(
                rng.standard_normal((Bx, D), np.float32) * 0.1)
            Sy = jax.numpy.asarray(
                rng.standard_normal((By, D), np.float32) * 0.1)
            w = jax.numpy.asarray(rng.random(D, dtype=np.float32))
            cands = [{"block_words": bw, "bx_tile": bx, "by_tile": by}
                     for bw in (128, 512)
                     for bx in sorted({128, min(128, max(8, _bucket(
                         cell["Bx"])))})
                     for by in sorted({128, min(128, max(8, _bucket(
                         cell["By"])))})]
            default = {"block_words": 512, "bx_tile": 128, "by_tile": 128}

            def run(rec):
                return ops.gram(Sx, Sy, w, backend=backend,
                                precision=precision, **rec)
        else:
            return {}
        if default not in cands:
            cands.append(default)
        for rec in cands:
            try:
                t = _median_time(
                    lambda: jax.block_until_ready(run(rec)), repeats)
            except Exception:
                continue  # infeasible candidate (e.g. invalid split)
            timed.append((t, rec))
        if not any(rec == default for _, rec in timed):
            return {}  # even the default failed: leave the cell untuned
        win = _pick(timed, default)
        win = dict(win)
        win["ms"] = round(min(t for t, r in timed if r == win) * 1e3, 4)
        win["default_ms"] = round(
            min(t for t, r in timed if r == default) * 1e3, 4)
        return win
    except Exception as e:
        _warn_once(f"autotune sweep failed for {kind} cell {cell}: {e}")
        return {}
    finally:
        _sweeping = False


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

_QUICK_GRID = [
    # (kind, cell) — paper-grid cells the benchmarks exercise
    ("sig_trunc", dict(engine="pallas", d=6, depth=2, M=100, B=32,
                       precision="fp32")),
    ("sig_trunc", dict(engine="pallas", d=3, depth=3, M=200, B=32,
                       precision="fp32")),
    ("sig_trunc", dict(engine="pallas", d=6, depth=2, M=100, B=32,
                       precision="bf16_fp32")),
    ("sig_words", dict(engine="pallas", d=2, depth=3, M=100, B=32,
                       precision="fp32")),
    ("gram", dict(engine="pallas", D=364, Bx=64, By=64, precision="fp32")),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="sweep the small built-in paper-grid cell set")
    ap.add_argument("--out", default=None,
                    help="cache file (default: PATHSIG_AUTOTUNE_CACHE or "
                         f"{_DEFAULT_CACHE})")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.out:
        os.environ["PATHSIG_AUTOTUNE_CACHE"] = args.out
        clear()
    grid = _QUICK_GRID  # --quick is the only shipped grid so far
    if not args.quick:
        print("note: only the --quick grid is defined; sweeping it")
    cells = load_cache()
    for kind, cell in grid:
        rec = sweep_cell(kind, cell, repeats=args.repeats)
        key = cell_key(kind, **cell)
        if rec:
            cells[key] = rec
            print(f"{key:70s} -> {rec}")
        else:
            print(f"{key:70s} -> (no winner; defaults)")
    save_cache(cells)
    print(f"wrote {cache_path()} ({len(cells)} cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
