"""The engine-dispatch layer: every public signature entry point routes here.

``repro.core.signature``, ``repro.core.projection``, ``repro.core.windows``,
``repro.core.logsignature`` and ``repro.models.sig_head`` all funnel their
``backend=`` / ``backward=`` arguments into :func:`signature` and
:func:`projected`, so kernel selection and differentiation policy live in
exactly one place.

``backend`` selection:

- ``"jax"``      — pure-JAX levelwise Horner scan (works everywhere).
- ``"pallas"``   — Pallas TPU kernels, compiled for the accelerator.
- ``"pallas_interpret"`` — same kernels executed in interpret mode (CPU
                   validation; the container's default).
- ``"auto"``     — pallas on TPU, jax elsewhere.
- ``"hybrid"``   — projected sets only: dense levelwise Horner for all of
                   W_{<=N-1} plus per-word chains for the requested level-N
                   words (``repro.core.hybrid``), with the §4.2 inverse VJP.
                   The §3.3 log-signature shape; wasteful for sets sparse at
                   low levels.

Backend × backward × stream support matrix
------------------------------------------

Every supported cell is differentiable via ``jax.grad``; cells marked (jax)
fall back to the pure-JAX engine because the Pallas forward cannot supply the
residuals that backward mode needs (no autodiff rule through ``pallas_call``;
no chunk-boundary output for the word kernel); cells marked ✗ raise
``NotImplementedError``:

=====================  ======  ============================  =====================  ==========
engine                 stream  backward="inverse"            "checkpoint"           "autodiff"
=====================  ======  ============================  =====================  ==========
jax, truncated         False   scan fwd + §4.2 reverse       √M boundaries + replay scan AD
jax, truncated         True    streamed scan fwd +           ✗                      scan AD
                               streamed §4.2 reverse
jax, projected         False   scan fwd + §4.2 reverse       √M boundaries + replay scan AD
jax, projected         True    streamed scan fwd +           ✗                      scan AD
                               streamed §4.2 reverse
pallas, truncated      False   kernel fwd + §4.2 reverse     kernel chunk fwd,      (jax)
                                                             Chen-combined, √M bwd
pallas, truncated      True    streamed kernel fwd +         ✗                      (jax)
                               streamed §4.2 reverse
pallas, projected      False   closure-kernel fwd +          (jax)                  (jax)
                               §4.2 reverse
pallas, projected      True    streamed closure-kernel fwd   ✗                      (jax)
                               + streamed §4.2 reverse
hybrid, projected      False   dense+top fwd + §4.2 reverse  (jax)                  top-level
                                                                                    scan AD
hybrid, projected      True    ✗                             ✗                      ✗
=====================  ======  ============================  =====================  ==========

``sig_gram`` row (:func:`gram`): the weighted Gram product
G = S_x diag(ω) S_yᵀ is one extra dispatch cell layered on the signature
engines above.  Backends: ``jax`` runs a word-blocked fori-loop (live state
O(B_x·B_y + B·block_words)); ``pallas``/``pallas_interpret`` run the tile
kernel in :mod:`repro.kernels.sig_gram` (same memory law, MXU contraction);
``hybrid`` falls back to jax.  Every backend is differentiable in all three
operands through one closed-form product VJP (dS_x = (g S_y)·ω,
dS_y = (gᵀ S_x)·ω, dω = Σ_ij g_ij S_x S_y) — the signature *legs* feeding it
carry whichever §4.2 inverse/checkpoint VJP the caller picked, so a full
kernel-method loss trains in O(B·D_sig) signature memory end to end.

The Pallas ``inverse`` rows are the paper's headline training path: the
kernel computes the forward, the backward reconstructs
S_{0,t_{j-1}} = S_{0,t_j} ⊗ exp(−ΔX_j) in O(B·D_sig) memory, independent of
sequence length (§4.2).  The ``checkpoint`` row for truncated signatures runs
the kernel over √M-length chunks folded into the batch axis, Chen-combines
the chunk signatures (storing the √M boundary states), and replays chunks on
the backward — drift-immune on very long paths.

``lengths`` column: EVERY cell above additionally accepts ``lengths=`` (B,)
for ragged batches — orthogonal to backend × backward × stream because it is
resolved before the engine runs: padded-tail increments are zero-masked (a
zero increment is the identity Chen update, so terminal outputs are exactly
the per-example unpadded signatures, to the bit), the outermost mask multiply
zeroes cotangents past each example's true end, and streamed outputs are
masked after the true-terminal slot (gather it back with
``repro.core.signature.ragged_terminal``).  ``gram`` has no time axis;
ragged batches enter it through the signature legs
(``repro.sigkernel.sig_gram(..., x_lengths=, y_lengths=)``).

``transform`` column: every cell above additionally accepts ``transform=``
(a :class:`repro.core.transforms.Transform` or spec string such as
``"time_augment+lead_lag"``) applied to the path before signing, with the
``(B, M', 2d+1)``-sized augmented increment tensor **never materialised** on
the fast rows.  How each cell gets there:

- ``pallas`` × ``inverse`` (truncated and projected, streamed or not): FUSED.
  The raw ``(B, M, d_raw)`` increments enter the kernel and each augmented
  increment ([t?, lag, lead] channels) is built in VMEM per time sub-step
  (``sig_trunc`` / ``sig_words`` ``transform=`` path).  The §4.2 backward
  reconstructs the augmented increments transiently level-by-step and
  :func:`repro.core.transforms.fused_adjoint` pulls the cotangent back to
  the raw channels — still O(B·D_sig) live memory.
- ``jax`` engines: the augment is fused into the scan step
  (``core.signature._fused_jax_signature``) — XLA fuses the per-step
  concat into the Horner update, no (M, 2d+1) intermediate in HBM.
- ``checkpoint`` / ``time_chunks>1`` / ``mesh`` / ``hybrid``: materialise
  fallback — ``augment_increments`` builds the augmented tensor once, then
  the plain cell runs (the augment is linear, ordinary AD transposes it).
- ``basepoint``: resolved at dispatch by prepending the ``x0=`` increment
  (lengths shift by one); a basepoint-only transform recurses into the
  plain cell, exactly.

``precision`` column: every cell above (and :func:`gram`) accepts
``precision="fp32"`` (default) or ``"bf16_fp32"``.  Mixed precision is
quantise-once-at-dispatch: increments are rounded to bf16 with a
straight-through-estimator cast *before* any engine runs, so every
backend × backward combination sees identical inputs and agrees
bit-for-bit; the Pallas kernels then *store* their increment blocks in
bf16 (halving VMEM state/block footprint — see
``sig_trunc.state_footprint(..., itemsize=2)``) while all Horner/Chen
accumulation stays fp32.  Gradients flow at fp32 through the STE.  The
per-level forward error against the fp32 oracle is bounded by
``level · 2^-8`` relative (bf16 has 8 mantissa bits; products of ``n``
rounded increments compound n rounding errors — ``tests/test_precision.py``
checks the measured bound at depth ≤ 6).

``mesh`` column: EVERY cell above (and :func:`gram`) is additionally
SPMD-capable — orthogonal to backend × backward × stream × lengths because
it is resolved OUTSIDE the engine.  Installing
``repro.distributed.ctx.sharding_ctx(mesh)`` whose rules map the "batch"
logical axis onto mesh axes (the default rules do, via 'data'/'pod';
``repro.launch.mesh.make_sig_mesh()`` builds the 1-axis case) wraps the
single-device cell in ``shard_map`` over that axis: each shard rebuilds the
same custom-VJP closure on its local batch, so gradients shard identically
to the primals; batches are zero-padded up to a multiple of the axis size
(zero increments are identity updates, padded rows are sliced off, their
cotangents are exactly zero); ``lengths`` ride along batch-sharded.
:func:`gram` instead runs the cross-device ring of :func:`_gram_ring`
(local X rows, Y tiles rotating by ``jax.lax.ppermute``, O(B·D_sig)
communication, no replicated Gram-sized intermediate).  Outside any context
every entry point is bit-identical to the single-device path.  The logical
axes "path_time" and "sig_words" exist in the default rules (unsharded) so
launchers can annotate time/word dims without touching the batch split.

``stream=True`` rows emit every ``stream_stride``-th prefix signature inside
the time loop — (B, M_out, D) with M_out = ceil(M / stride), terminal step
always included (``repro.core.signature.stream_emit_steps``).  Their
``inverse`` backward is the §4.2 reverse sweep generalised to cotangents
arriving at every emitted step: still ONE reverse scan with O(B·D_sig) live
memory, with only the terminal state kept as residual.  ``checkpoint`` is
pointless there (the output already materialises the boundary states) and
raises.

Also provides ``signature_time_parallel``: a beyond-paper optimisation that
splits the time axis into C chunks, computes chunk signatures independently
(folded into the batch axis — more parallel work, the paper's windowing
argument applied to *one* signature) and Chen-combines them in a log-depth
tree.  The paper explicitly does not parallelise over sequence length
(§6.1); on TPU this recovers utilisation for long paths at small batch.
"""
from __future__ import annotations

import functools
import weakref
from collections import OrderedDict, namedtuple
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro import obs
from repro.core import tensor_ops as tops
from repro.distributed.ctx import current_mesh, logical_axes
from repro.distributed.ctx import shard as shard_constraint
from repro.core.signature import (as_lengths, canon_precision,
                                  checkpoint_bwd_scan,
                                  dataclasses_replace_nobp, default_chunk,
                                  inverse_bwd_scan, mask_increments,
                                  quantise_increments,
                                  signature_from_increments,
                                  stream_emit_mask, stream_inverse_bwd_scan,
                                  unsupported_stream_backward)
from repro.core.transforms import (as_transform, augment_increments,
                                   fused_adjoint, fused_augment,
                                   transform_dim, transform_steps,
                                   transform_time_aux)
from repro.kernels import autotune
from repro.core.projection import (projected_inverse_bwd_scan,
                                   projected_signature_from_increments,
                                   projected_stream_inverse_bwd_scan)
from repro.core.words import (TiledPlan, WordPlan, flat_index, make_plan,
                              make_tiled_plan, sig_dim)
from .sig_gram import sig_gram_tiles
from .sig_trunc import sig_trunc
from .sig_words import sig_words

BACKENDS = ("jax", "pallas", "pallas_interpret", "auto", "hybrid")
BACKWARDS = ("inverse", "checkpoint", "autodiff")


# ---------------------------------------------------------------------------
# plan caches: one shared bounded policy.  Every interned plan / compiled
# closure / shard_map wrapper in this module is registered here, so serving
# traffic with an unbounded stream of word sets evicts old entries instead of
# growing without limit.  Eviction is always safe — entries are pure
# functions of their keys, so a rebuilt entry produces bit-identical results
# (jit recompiles, nothing else changes).
# ---------------------------------------------------------------------------

PLAN_CACHE_MAXSIZE = 256          # default per-cache bound

_PLAN_CACHE_FNS: dict = {}        # cache name -> undecorated fn


CacheInfo = namedtuple("CacheInfo",
                       ("hits", "misses", "maxsize", "currsize", "evictions"))


class _CountingLru:
    """``functools.lru_cache`` semantics plus an eviction counter
    (``lru_cache`` itself never reports how many entries it dropped, which
    is exactly the number serving traffic needs to see).  Same key rule as
    ``lru_cache``: positional args plus sorted kwargs, all hashable."""

    def __init__(self, fn, maxsize):
        self._fn = fn
        self._maxsize = maxsize
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        functools.update_wrapper(self, fn)

    def __call__(self, *args, **kwargs):
        key = (args, tuple(sorted(kwargs.items()))) if kwargs else args
        data = self._data
        if key in data:
            data.move_to_end(key)
            self.hits += 1
            return data[key]
        self.misses += 1
        val = self._fn(*args, **kwargs)
        data[key] = val
        if self._maxsize is not None:
            while len(data) > self._maxsize:
                data.popitem(last=False)
                self.evictions += 1
        return val

    def cache_info(self) -> CacheInfo:
        return CacheInfo(self.hits, self.misses, self._maxsize,
                         len(self._data), self.evictions)

    def cache_clear(self) -> None:
        self._data.clear()


def plan_cache(fn):
    """Register ``fn`` under the shared bounded-LRU plan-cache policy."""
    _PLAN_CACHE_FNS[fn.__name__] = fn
    return _CountingLru(fn, PLAN_CACHE_MAXSIZE)

# name -> WeakSet of live BoundedCache instances sharing that report line
_INSTANCE_CACHES: dict[str, weakref.WeakSet] = {}


class BoundedCache:
    """Per-instance LRU under the shared plan-cache policy.

    The serving layer keeps jitted per-shape computes on *instances*
    (``DynamicBatcher``, ``SessionStore``) rather than module functions, so
    ``lru_cache`` can't bound them.  A ``BoundedCache`` follows
    ``PLAN_CACHE_MAXSIZE`` dynamically (``set_plan_cache_maxsize`` re-trims,
    ``clear_plan_caches`` clears) and reports — aggregated per ``name``
    across live instances — through ``plan_cache_info()``.  Eviction is
    always safe: entries are jit wrappers, pure functions of their shape
    key, so a rebuilt entry recompiles to bit-identical results.
    """

    def __init__(self, name: str):
        self.name = name
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        _INSTANCE_CACHES.setdefault(name, weakref.WeakSet()).add(self)

    def get(self, key, make):
        """Cached value for ``key``, building (and possibly evicting) via
        ``make()`` on a miss."""
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        val = make()
        self._data[key] = val
        self.trim()
        return val

    def trim(self) -> None:
        if PLAN_CACHE_MAXSIZE is None:
            return
        while len(self._data) > PLAN_CACHE_MAXSIZE:
            self._data.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._data.clear()

    def info(self) -> CacheInfo:
        return CacheInfo(self.hits, self.misses, PLAN_CACHE_MAXSIZE,
                         len(self._data), self.evictions)


def set_plan_cache_maxsize(maxsize: int | None) -> None:
    """Rebuild every registered plan cache with a new bound (None =
    unbounded).  Existing entries are dropped — safe, see above.  Live
    instance caches (``BoundedCache``) are re-trimmed to the new bound."""
    global PLAN_CACHE_MAXSIZE
    PLAN_CACHE_MAXSIZE = maxsize
    g = globals()
    for name, fn in _PLAN_CACHE_FNS.items():
        g[name] = _CountingLru(fn, maxsize)
    for caches in _INSTANCE_CACHES.values():
        for c in caches:
            c.trim()


def clear_plan_caches() -> None:
    """Drop every cached plan / kernel closure / shard_map wrapper (the
    serving-side pressure valve; results are unaffected)."""
    g = globals()
    for name in _PLAN_CACHE_FNS:
        g[name].cache_clear()
    for caches in _INSTANCE_CACHES.values():
        for c in caches:
            c.clear()


def plan_cache_info() -> dict:
    """{cache name: CacheInfo} for every registered cache — the module-level
    ``@plan_cache`` functions plus each live ``BoundedCache`` family
    (hits/misses/currsize/evictions summed over instances)."""
    g = globals()
    out = {name: g[name].cache_info() for name in _PLAN_CACHE_FNS}
    for name, caches in _INSTANCE_CACHES.items():
        infos = [c.info() for c in caches]
        out[name] = CacheInfo(sum(i.hits for i in infos),
                              sum(i.misses for i in infos),
                              PLAN_CACHE_MAXSIZE,
                              sum(i.currsize for i in infos),
                              sum(i.evictions for i in infos))
    return out


def _plan_cache_collector(reg) -> None:
    """Pull collector: publish ``plan_cache_info()`` as
    ``pathsig_plan_cache{cache=,stat=}`` gauges at snapshot time — the hot
    path never mirrors increments into the registry."""
    g = reg.gauge("pathsig_plan_cache",
                  "plan cache accounting (hits/misses/currsize/evictions "
                  "per cache family)", ("cache", "stat"))
    for name, ci in plan_cache_info().items():
        g.set(ci.hits, cache=name, stat="hits")
        g.set(ci.misses, cache=name, stat="misses")
        g.set(ci.currsize, cache=name, stat="currsize")
        g.set(ci.evictions, cache=name, stat="evictions")


obs.register_collector(_plan_cache_collector)


# ---------------------------------------------------------------------------
# dispatch observability: per-entry call counters + tracer spans
# ---------------------------------------------------------------------------

def _dispatch_calls():
    return obs.counter(
        "pathsig_dispatch_calls_total",
        "public dispatch entry calls; ctx distinguishes eager host calls "
        "from trace-time calls inside an outer jit",
        ("op", "backend", "ctx"))


def _obs_entry(fn):
    """Wrap a public dispatch entry with call accounting and a tracer span.

    Costs two flag checks when observability is fully off.  Inside an outer
    ``jit`` the wrapper runs at trace time only (the body is staged out), so
    counts are labelled ``ctx="trace"`` there — one tick per compiled
    variant — versus ``ctx="eager"`` per host-level call.
    """
    site = fn.__name__

    @functools.wraps(fn)
    def wrapper(x, *args, **kwargs):
        metrics_on = obs.REGISTRY._enabled
        trace_on = obs.TRACER._active
        if not metrics_on and not trace_on:
            return fn(x, *args, **kwargs)
        backend = str(kwargs.get("backend", "auto"))
        ctx = "trace" if isinstance(x, jax.core.Tracer) else "eager"
        if metrics_on:
            _dispatch_calls().inc(op=site, backend=backend, ctx=ctx)
        if not trace_on:
            return fn(x, *args, **kwargs)
        with obs.span(f"kernels.{site}", backend=backend, ctx=ctx,
                      shapes=obs.shape_key(x)):
            return fn(x, *args, **kwargs)

    wrapper.__wrapped__ = fn
    return wrapper


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> tuple[str, bool]:
    """backend string -> (engine, interpret)."""
    if backend == "auto":
        return ("pallas", False) if _on_tpu() else ("jax", False)
    if backend == "pallas":
        return "pallas", not _on_tpu()
    if backend == "pallas_interpret":
        return "pallas", True
    if backend == "jax":
        return "jax", False
    if backend == "hybrid":
        return "hybrid", False
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")


_resolve = resolve_backend  # back-compat alias


def _check_backward(backward: str) -> None:
    if backward not in BACKWARDS:
        raise ValueError(
            f"unknown backward mode {backward!r}; expected one of {BACKWARDS}")


# ---------------------------------------------------------------------------
# truncated signatures: Pallas forwards, §4.2 custom VJPs
# ---------------------------------------------------------------------------

@plan_cache
def _pallas_sig_inverse(depth: int, batch_tile: int, split: int | None,
                        interpret: bool, precision: str = "fp32"):
    """Kernel forward + inverse-reconstruction backward (paper §4.2).

    ``precision`` only selects the kernel's storage dtype: the dispatch layer
    quantises the increments BEFORE they reach any custom VJP, so forward and
    backward sweeps see identical (already-rounded) values."""
    def kernel(increments):
        return sig_trunc(increments, depth, batch_tile=batch_tile,
                         split=split, interpret=interpret,
                         precision=precision)

    @jax.custom_vjp
    def sig(increments):
        return kernel(increments)

    def fwd(increments):
        out = kernel(increments)
        return out, (increments, out)

    def bwd(res, g_flat):
        increments, out_flat = res
        return (inverse_bwd_scan(increments, out_flat, g_flat, depth),)

    sig.defvjp(fwd, bwd)
    return sig


@plan_cache
def _pallas_sig_checkpoint(depth: int, chunk: int, batch_tile: int,
                           split: int | None, interpret: bool,
                           precision: str = "fp32"):
    """Kernel chunk forward + √M-checkpoint backward.

    Forward: fold √M-length time chunks into the batch axis, run the Pallas
    kernel once over all chunks, Chen-combine the chunk signatures in a scan
    whose carry traces out exactly the boundary states the backward needs.
    Backward: the shared chunk-replay sweep from ``repro.core.signature``.
    """
    def kernel(increments):
        return sig_trunc(increments, depth, batch_tile=batch_tile,
                         split=split, interpret=interpret,
                         precision=precision)

    @jax.custom_vjp
    def sig(increments):
        out, _ = _forward(increments)
        return out

    def _forward(increments):
        B, M, d = increments.shape
        n_chunks = -(-M // chunk)
        pad = n_chunks * chunk - M
        x = jnp.pad(increments, ((0, 0), (0, pad), (0, 0)))  # zero = identity
        folded = x.reshape(B, n_chunks, chunk, d).reshape(B * n_chunks,
                                                          chunk, d)
        chunk_flat = kernel(folded)                         # (B*C, D_sig)
        chunk_lv = tops.flat_to_levels(chunk_flat, d, depth)
        # -> time-major levels: each (n_chunks, B, d**n)
        chunk_lv = [jnp.moveaxis(a.reshape(B, n_chunks, -1), 1, 0)
                    for a in chunk_lv]

        def combine(levels, c_lv):
            new = tops.chen_mul(levels, c_lv)
            return new, [lv for lv in levels]  # boundary BEFORE the chunk

        init = tops.zero_levels((B,), d, depth, chunk_flat.dtype)
        final, boundaries = jax.lax.scan(combine, init, chunk_lv)
        return tops.levels_to_flat(final), boundaries

    def fwd(increments):
        out, boundaries = _forward(increments)
        return out, (increments, boundaries)

    def bwd(res, g_flat):
        increments, boundaries = res
        return (checkpoint_bwd_scan(increments, boundaries, g_flat, depth,
                                    chunk),)

    sig.defvjp(fwd, bwd)
    return sig


@plan_cache
def _pallas_sig_stream(depth: int, stride: int, batch_tile: int,
                       split: int | None, interpret: bool,
                       precision: str = "fp32"):
    """Streamed kernel forward + generalised §4.2 backward: cotangents arrive
    at every emitted step, one reverse scan, O(B·D_sig) live memory."""
    def kernel(increments):
        return sig_trunc(increments, depth, batch_tile=batch_tile,
                         split=split, interpret=interpret, stream=True,
                         stream_stride=stride, precision=precision)

    @jax.custom_vjp
    def sig(increments):
        return kernel(increments)

    def fwd(increments):
        out = kernel(increments)
        return out, (increments, out[:, -1])  # terminal step always emitted

    def bwd(res, g_steps):
        increments, terminal = res
        return (stream_inverse_bwd_scan(increments, terminal, g_steps, depth,
                                        stride),)

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# fused-transform cells: raw increments + time-aux in, augmented signature
# out.  The transform never materialises on the forward — the kernel builds
# each augmented increment in VMEM per Horner sub-step.  The backward
# transiently materialises the augmented increments ONCE (O(B·M_aug·d_aug),
# freed after the sweep), reuses the standard §4.2 reconstruction over them,
# then pulls the cotangent back through the transform's linear adjoint.
# ``taux`` ((B, 2) = [dt, n_valid_aug]) is data-independent: its cotangent
# is identically zero.
# ---------------------------------------------------------------------------

@plan_cache
def _pallas_sig_fused_inverse(depth: int, batch_tile: int, split: int | None,
                              interpret: bool, kspec, precision: str):
    """Fused-transform kernel forward + §4.2 backward through the transform
    adjoint.  ``kspec`` is a basepoint-free Transform (basepoint is handled
    as an increment prepend in the dispatch, outside the custom VJP, so x0
    gradients ride the concat's transpose)."""
    def kernel(increments, taux):
        return sig_trunc(increments, depth, batch_tile=batch_tile,
                         split=split, interpret=interpret, transform=kspec,
                         taux=taux, precision=precision)

    @jax.custom_vjp
    def sig(increments, taux):
        return kernel(increments, taux)

    def fwd(increments, taux):
        out = kernel(increments, taux)
        return out, (increments, taux, out)

    def bwd(res, g_flat):
        increments, taux, out_flat = res
        e = fused_augment(increments, taux, kspec)
        g_e = inverse_bwd_scan(e, out_flat, g_flat, depth)
        return (fused_adjoint(g_e, kspec, increments.shape[-1]),
                jnp.zeros_like(taux))

    sig.defvjp(fwd, bwd)
    return sig


@plan_cache
def _pallas_sig_fused_stream(depth: int, stride: int, batch_tile: int,
                             split: int | None, interpret: bool, kspec,
                             precision: str):
    """Fused-transform streamed forward + streamed §4.2 backward through the
    transform adjoint (strides and emissions are over the AUGMENTED step
    axis, matching the kernel's in-VMEM sub-steps)."""
    def kernel(increments, taux):
        return sig_trunc(increments, depth, batch_tile=batch_tile,
                         split=split, interpret=interpret, stream=True,
                         stream_stride=stride, transform=kspec, taux=taux,
                         precision=precision)

    @jax.custom_vjp
    def sig(increments, taux):
        return kernel(increments, taux)

    def fwd(increments, taux):
        out = kernel(increments, taux)
        return out, (increments, taux, out[:, -1])

    def bwd(res, g_steps):
        increments, taux, terminal = res
        e = fused_augment(increments, taux, kspec)
        g_e = stream_inverse_bwd_scan(e, terminal, g_steps, depth, stride)
        return (fused_adjoint(g_e, kspec, increments.shape[-1]),
                jnp.zeros_like(taux))

    sig.defvjp(fwd, bwd)
    return sig


# ---------------------------------------------------------------------------
# plan normalisation + caches — keyed by plan CONTENT (d, words), never by
# WordPlan/TiledPlan object identity, so rebuilding an identical plan hits
# the same compiled kernels instead of recompiling and growing the caches
# ---------------------------------------------------------------------------

@plan_cache
def _plan_for_words(words: tuple, d: int) -> WordPlan:
    """The interned WordPlan for a word set: one canonical object per
    (words, d) content, shared by every jit/lru cache downstream."""
    return make_plan(words, d)


@plan_cache
def _tiled_for_words(words: tuple, d: int, max_rows: int) -> TiledPlan:
    """The interned TiledPlan — content-keyed for the same reason (TiledPlan
    hashes by identity, and ``sig_words`` jit-caches on the plan object)."""
    return make_tiled_plan(words, d, max_rows=max_rows)


@plan_cache
def _closure_tiled_plan(words: tuple, d: int, max_rows: int) -> TiledPlan:
    """Tiled plan whose *requested* words are the prefix closure of the word
    set — the kernel computes the closure rows anyway, so asking for them adds
    output gather only, and the terminal closure state is what the §4.2
    backward reconstructs from."""
    wplan = _plan_for_words(words, d)
    return make_tiled_plan(wplan.closure, d, max_rows=max_rows)


def _normalise_plans(plan, d: int) -> tuple[WordPlan, TiledPlan | None]:
    """-> (interned WordPlan, TiledPlan-or-None) from any accepted plan
    spelling.  The WordPlan is always the canonical content-interned object,
    so two structurally equal plans resolve to the same kernel caches."""
    if isinstance(plan, TiledPlan):
        return _plan_for_words(plan.words, plan.d), plan
    if isinstance(plan, WordPlan):
        return _plan_for_words(plan.words, plan.d), None
    return _plan_for_words(tuple(tuple(w) for w in plan), d), None


# ---------------------------------------------------------------------------
# projected signatures: Pallas closure forward, §4.2 custom VJP
# ---------------------------------------------------------------------------

@plan_cache
def _pallas_proj_inverse(words: tuple, d: int, batch_tile: int, max_rows: int,
                         interpret: bool, precision: str = "fp32"):
    """Word-kernel forward over the prefix closure + §4.2 backward.
    Content-keyed: (words, d) identify the plan, not object identity."""
    wplan = _plan_for_words(words, d)
    closure_tplan = _closure_tiled_plan(words, d, max_rows)
    out_rows = np.asarray(wplan.out_rows)

    def closure_state(increments):
        cw = sig_words(increments, closure_tplan, batch_tile=batch_tile,
                       interpret=interpret,
                       precision=precision)               # (B, W), closure order
        ones = jnp.ones((cw.shape[0], 1), cw.dtype)
        return jnp.concatenate([ones, cw], axis=1)        # (B, 1 + W)

    @jax.custom_vjp
    def proj(increments):
        return jnp.take(closure_state(increments), out_rows, axis=1)

    def fwd(increments):
        S_T = closure_state(increments)
        return jnp.take(S_T, out_rows, axis=1), (increments, S_T)

    def bwd(res, g_out):
        increments, S_T = res
        return (projected_inverse_bwd_scan(increments, S_T, g_out, wplan),)

    proj.defvjp(fwd, bwd)
    return proj


@plan_cache
def _pallas_proj_stream(words: tuple, d: int, stride: int, batch_tile: int,
                        max_rows: int, interpret: bool,
                        precision: str = "fp32"):
    """Streamed word-kernel forward over the prefix closure + streamed §4.2
    backward (cotangents at every emitted step, one reverse scan)."""
    wplan = _plan_for_words(words, d)
    closure_tplan = _closure_tiled_plan(words, d, max_rows)
    out_rows = np.asarray(wplan.out_rows)

    def closure_stream(increments):
        cw = sig_words(increments, closure_tplan, batch_tile=batch_tile,
                       interpret=interpret, stream=True,
                       stream_stride=stride,
                       precision=precision)          # (B, M_out, W)
        ones = jnp.ones((*cw.shape[:2], 1), cw.dtype)
        return jnp.concatenate([ones, cw], axis=-1)  # (B, M_out, 1 + W)

    @jax.custom_vjp
    def proj(increments):
        return jnp.take(closure_stream(increments), out_rows, axis=-1)

    def fwd(increments):
        S = closure_stream(increments)
        # terminal closure state is the last emitted step — the only residual
        return jnp.take(S, out_rows, axis=-1), (increments, S[:, -1])

    def bwd(res, g_steps):
        increments, S_T = res
        return (projected_stream_inverse_bwd_scan(increments, S_T, g_steps,
                                                  wplan, stride),)

    proj.defvjp(fwd, bwd)
    return proj


@plan_cache
def _pallas_proj_fused_inverse(words: tuple, d: int, batch_tile: int,
                               max_rows: int, interpret: bool, kspec,
                               precision: str):
    """Fused-transform word-kernel forward + §4.2 backward through the
    transform adjoint.  ``words``/``d`` describe the plan over the AUGMENTED
    alphabet (d == transform_dim(kspec, d_raw)); the backward materialises
    the augmented increments once, runs the standard projected sweep, then
    applies the transform's linear adjoint."""
    wplan = _plan_for_words(words, d)
    closure_tplan = _closure_tiled_plan(words, d, max_rows)
    out_rows = np.asarray(wplan.out_rows)

    def closure_state(increments, taux):
        cw = sig_words(increments, closure_tplan, batch_tile=batch_tile,
                       interpret=interpret, transform=kspec, taux=taux,
                       precision=precision)
        ones = jnp.ones((cw.shape[0], 1), cw.dtype)
        return jnp.concatenate([ones, cw], axis=1)

    @jax.custom_vjp
    def proj(increments, taux):
        return jnp.take(closure_state(increments, taux), out_rows, axis=1)

    def fwd(increments, taux):
        S_T = closure_state(increments, taux)
        return jnp.take(S_T, out_rows, axis=1), (increments, taux, S_T)

    def bwd(res, g_out):
        increments, taux, S_T = res
        e = fused_augment(increments, taux, kspec)
        g_e = projected_inverse_bwd_scan(e, S_T, g_out, wplan)
        return (fused_adjoint(g_e, kspec, increments.shape[-1]),
                jnp.zeros_like(taux))

    proj.defvjp(fwd, bwd)
    return proj


@plan_cache
def _pallas_proj_fused_stream(words: tuple, d: int, stride: int,
                              batch_tile: int, max_rows: int, interpret: bool,
                              kspec, precision: str):
    """Fused-transform streamed word-kernel forward + streamed §4.2 backward
    through the transform adjoint (emissions stride the augmented axis)."""
    wplan = _plan_for_words(words, d)
    closure_tplan = _closure_tiled_plan(words, d, max_rows)
    out_rows = np.asarray(wplan.out_rows)

    def closure_stream(increments, taux):
        cw = sig_words(increments, closure_tplan, batch_tile=batch_tile,
                       interpret=interpret, stream=True, stream_stride=stride,
                       transform=kspec, taux=taux, precision=precision)
        ones = jnp.ones((*cw.shape[:2], 1), cw.dtype)
        return jnp.concatenate([ones, cw], axis=-1)

    @jax.custom_vjp
    def proj(increments, taux):
        return jnp.take(closure_stream(increments, taux), out_rows, axis=-1)

    def fwd(increments, taux):
        S = closure_stream(increments, taux)
        return jnp.take(S, out_rows, axis=-1), (increments, taux, S[:, -1])

    def bwd(res, g_steps):
        increments, taux, S_T = res
        e = fused_augment(increments, taux, kspec)
        g_e = projected_stream_inverse_bwd_scan(e, S_T, g_steps, wplan,
                                                stride)
        return (fused_adjoint(g_e, kspec, increments.shape[-1]),
                jnp.zeros_like(taux))

    proj.defvjp(fwd, bwd)
    return proj


# ---------------------------------------------------------------------------
# hybrid engine: dense W_{<=N-1} + per-word top chains (repro.core.hybrid)
# ---------------------------------------------------------------------------

@plan_cache
def _hybrid_gather(words: tuple, d: int):
    """-> (top_words, out_idx): the level-N words the hybrid engine must chain
    explicitly, and the gather from its [dense W_{<=N-1} ++ top] buffer back
    to the requested word order."""
    wplan = _plan_for_words(words, d)
    depth = wplan.depth
    top: list = []
    seen: set = set()
    for w in wplan.words:
        if len(w) == depth and w not in seen:
            seen.add(w)
            top.append(w)
    top_pos = {w: i for i, w in enumerate(top)}
    lown = sig_dim(d, depth - 1)
    idx = [lown + top_pos[w] if len(w) == depth else flat_index(w, d)
           for w in wplan.words]
    return tuple(top), np.asarray(idx, dtype=np.int32)


def _hybrid_projected(increments: jax.Array, wplan: WordPlan,
                      backward: str) -> jax.Array:
    """Projected signature through the hybrid dense+word-table engine: the
    dense levelwise-Horner scan covers every level below the set's max level
    (gather/scatter-free), per-word chains cover only the top-level words,
    and the requested coordinates are gathered from the combined buffer.
    Worth it exactly when the set is dense at low levels (the §3.3 shape)."""
    if wplan.depth < 2:
        # no dense block below level 1: the word-table engine IS the limit
        return projected_signature_from_increments(
            increments, wplan, backward=backward, backend="jax")
    from repro.core.hybrid import hybrid_low_plus_top
    top, idx = _hybrid_gather(wplan.words, wplan.d)
    buf = hybrid_low_plus_top(increments, top, wplan.depth, backward=backward)
    return jnp.take(buf, jnp.asarray(idx), axis=1)


# ---------------------------------------------------------------------------
# mesh-aware SPMD path: an installed sharding_ctx(mesh) whose rules map the
# "batch" logical axis onto >= 2 devices turns EVERY dispatch cell into a
# shard_map over that axis — the same engines run per shard with per-shard
# custom-VJP closures (signatures are batch-elementwise, so gradients shard
# identically), the batch is zero-padded up to a multiple of the axis size
# (zero increments are identity updates; padded rows are sliced off, so their
# cotangents are exactly zero), and outside any context every entry point is
# bit-identical to the single-device path (the mesh branch is never taken).
# ---------------------------------------------------------------------------


def _mesh_batch():
    """-> (mesh, batch axis names, axis size) when the current sharding
    context shards the "batch" logical axis over >= 2 devices, else None."""
    mesh = current_mesh()
    if mesh is None:
        return None
    names = logical_axes("batch")
    size = 1
    for a in names:
        size *= mesh.shape[a]
    if size <= 1:
        return None
    return mesh, names, size


def _axis_arg(names: tuple):
    """Axis-name argument for PartitionSpec / collectives: a bare name for
    1 axis, the tuple for several (treated as one flattened axis)."""
    return names if len(names) > 1 else names[0]


def _pad_rows(x: jax.Array, size: int) -> jax.Array:
    """Zero-pad dim 0 up to a multiple of ``size``."""
    pad = -x.shape[0] % size
    if not pad:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def _apply_sharded(fn, size: int, increments: jax.Array, lengths):
    """Pad the batch to a multiple of the axis size, run the shard_map'd
    ``fn``, slice the padding back off (its transpose zero-fills, so padded
    rows contribute exactly zero cotangent)."""
    B = increments.shape[0]
    incs = _pad_rows(increments, size)
    if lengths is None:
        out = fn(incs)
    else:
        out = fn(incs, _pad_rows(lengths, size))
    return out[:B] if incs.shape[0] != B else out


def _shard_wrap(mesh, names: tuple, with_lengths: bool, local_fn, *,
                site: str):
    """Wrap ``local_fn(increments, lengths_or_None)`` in shard_map with every
    argument batch-sharded on dim 0.  The body is the single-device dispatch,
    so the custom-VJP closure is rebuilt per shard and gradients shard
    identically to the primals.  ``check_rep=False``: pallas_call has no
    replication rule.

    The wrapper is jitted (with retrace accounting under ``site``): the
    plan cache pins one wrapper per (mesh, cell) and jit's cache pins one
    trace per argument shape, so repeated mesh calls with the same
    (op, cell, shape) re-dispatch a compiled executable instead of
    re-tracing the per-shard custom-VJP closures every call."""
    spec = PartitionSpec(_axis_arg(names))
    if with_lengths:
        def body(incs, lens):
            return local_fn(incs, lens)
        in_specs = (spec, spec)
    else:
        def body(incs):
            return local_fn(incs, None)
        in_specs = (spec,)
    return obs.instrument_jit(
        shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=spec,
                  check_rep=False), site=site)


@plan_cache
def _sharded_sig(mesh, names: tuple, with_lengths: bool, depth: int,
                 engine: str, interpret: bool, backward: str, batch_tile: int,
                 split: int | None, time_chunks: int, stream: bool,
                 stream_stride: int, precision: str = "fp32"):
    """shard_map wrapper around the truncated-signature cell.  Transforms
    are materialised BEFORE the mesh branch (support matrix), so the shard
    body only needs the precision knob."""
    return _shard_wrap(mesh, names, with_lengths, partial(
        _signature_local, depth=depth, engine=engine, interpret=interpret,
        backward=backward, batch_tile=batch_tile, split=split,
        time_chunks=time_chunks, stream=stream,
        stream_stride=stream_stride, precision=precision),
        site="sharded_sig")


@plan_cache
def _sharded_proj(mesh, names: tuple, with_lengths: bool, words: tuple,
                  d: int, engine: str, interpret: bool, backward: str,
                  batch_tile: int, max_rows: int, stream: bool,
                  stream_stride: int, precision: str = "fp32"):
    """shard_map wrapper around the projected-signature cell (incl. the
    hybrid engine).  Transforms are materialised before the mesh branch."""
    return _shard_wrap(mesh, names, with_lengths, partial(
        _projected_local, words=words, d=d, engine=engine,
        interpret=interpret, backward=backward, batch_tile=batch_tile,
        max_rows=max_rows, stream=stream, stream_stride=stream_stride,
        precision=precision), site="sharded_proj")


@plan_cache
def _sharded_proj_fwd(mesh, names: tuple, with_lengths: bool, words: tuple,
                      d: int, engine: str, interpret: bool, batch_tile: int,
                      max_rows: int, precision: str = "fp32"):
    """shard_map wrapper around :func:`projected_forward_only`'s body."""
    return _shard_wrap(mesh, names, with_lengths, partial(
        _projected_fwd_local, words=words, d=d, engine=engine,
        interpret=interpret, batch_tile=batch_tile, max_rows=max_rows,
        precision=precision), site="sharded_proj_fwd")


# ---------------------------------------------------------------------------
# weighted Gram product: word-blocked routes + closed-form product VJP
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block",))
def _gram_blocked_jax(Sx: jax.Array, Sy: jax.Array, w: jax.Array,
                      block: int) -> jax.Array:
    """G = S_x diag(w) S_yᵀ via a fori-loop over word blocks: live state is
    the (B_x, B_y) accumulator plus one (B, block) slab per operand — the
    (B_x, B_y, D) elementwise intermediate is never formed."""
    Bx, D = Sx.shape
    By = Sy.shape[0]
    blk = min(block, D)
    n = -(-D // blk)
    dt = jnp.promote_types(Sx.dtype, jnp.float32)
    if n == 1:
        # single-block fast path: one dot, no fori_loop — keeps the lowered
        # HLO loop-free so the gram ring's tile dots stay visible to the
        # scheduler (and to ring_overlap's permute/compute analysis)
        return (Sx.astype(dt) * w.astype(dt)[None, :]) @ Sy.astype(dt).T
    pad = n * blk - D
    if pad:  # zero-padded weights make the padded columns exact no-ops
        Sx = jnp.pad(Sx, ((0, 0), (0, pad)))
        Sy = jnp.pad(Sy, ((0, 0), (0, pad)))
        w = jnp.pad(w, (0, pad))

    def body(i, acc):
        sx = jax.lax.dynamic_slice(Sx, (0, i * blk), (Bx, blk)).astype(dt)
        sy = jax.lax.dynamic_slice(Sy, (0, i * blk), (By, blk)).astype(dt)
        wb = jax.lax.dynamic_slice(w, (i * blk,), (blk,)).astype(dt)
        return acc + (sx * wb[None, :]) @ sy.T

    return jax.lax.fori_loop(0, n, body, jnp.zeros((Bx, By), dt))


@plan_cache
def _gram_vjp(engine: str, interpret: bool, block_words: int, bx_tile: int,
              by_tile: int):
    def forward(Sx, Sy, w):
        if engine == "pallas":
            return sig_gram_tiles(Sx, Sy, w, bx_tile=bx_tile, by_tile=by_tile,
                                  k_tile=block_words, interpret=interpret)
        return _gram_blocked_jax(Sx, Sy, w, block_words)

    @jax.custom_vjp
    def gram_fn(Sx, Sy, w):
        return forward(Sx, Sy, w)

    def fwd(Sx, Sy, w):
        return forward(Sx, Sy, w), (Sx, Sy, w)

    def bwd(res, g):
        # G_ij = Σ_k Sx_ik w_k Sy_jk: products of (B, D) mats only — the
        # backward obeys the same no-(B_x, B_y, D)-intermediate law.
        Sx, Sy, w = res
        g = g.astype(jnp.promote_types(Sx.dtype, jnp.float32))
        dSx = (g @ (Sy * w[None, :])).astype(Sx.dtype)
        dSy = (g.T @ (Sx * w[None, :])).astype(Sy.dtype)
        dw = ((g.T @ Sx) * Sy).sum(axis=0).astype(w.dtype)
        return dSx, dSy, dw

    gram_fn.defvjp(fwd, bwd)
    return gram_fn


@plan_cache
def _gram_ring(mesh, names: tuple, size: int, engine: str, interpret: bool,
               block_words: int, bx_tile: int, by_tile: int):
    """Cross-device Gram: X rows stay local, Y signature tiles rotate around
    the mesh axis in a ``jax.lax.ppermute`` ring.

    Both operands are batch-sharded; device p computes the (B_x/P, B_y/P)
    tile against whichever Y shard it currently holds, writes it into its
    output row block at the shard's *origin* columns, and passes the shard to
    its left neighbour — P steps visit every tile.  Per-device communication
    is (P-1)/P · B_y · D bytes (O(B·D_sig) in total), live memory is one Y
    shard + one in-flight shard + the local (B_x/P, B_y) row block, and no
    collective ever carries a replicated Gram-sized or (B_x, B_y, D_sig)
    intermediate — asserted via
    :func:`repro.distributed.hlo.collective_stats` in the shard tests.

    The ring is double-buffered: the loop is statically unrolled (size <= 8
    in practice) and each step issues the ppermute for the NEXT shard into
    a second buffer *before* consuming the current one, so the permute has
    no data dependence on the tile dot and the scheduler can hide step
    k+1's wire time under step k's matmul.  Only size-1 permutes are issued
    (the last held shard is consumed, not forwarded).  The carry buffers
    alias in place across steps (XLA reuses the consumed shard's buffer for
    the incoming one — the loop-free form is what makes that legal), and
    the overlap structure is asserted on the lowered HLO via
    :func:`repro.distributed.hlo.ring_overlap` in the shard tests.
    Differentiable: each tile rides the closed-form product VJP and the
    ring transposes to the reversed ring.
    """
    local = _gram_vjp(engine, interpret, block_words, bx_tile, by_tile)
    ax = _axis_arg(names)
    spec = PartitionSpec(ax)
    perm = [(i, (i - 1) % size) for i in range(size)]

    def body(sx, sy, w):
        p = jax.lax.axis_index(ax)
        by = sy.shape[0]
        dt = jnp.promote_types(sx.dtype, jnp.float32)
        G = jnp.zeros((sx.shape[0], by * size), dt)
        sy_cur = sy
        for s in range(size):
            sy_next = None
            if s + 1 < size:  # prefetch before the dot: no data dependence
                sy_next = jax.lax.ppermute(sy_cur, ax, perm)
            tile = local(sx, sy_cur, w).astype(dt)
            # (p + s) % size: origin device of the currently held shard
            G = jax.lax.dynamic_update_slice(G, tile,
                                             (0, ((p + s) % size) * by))
            if sy_next is not None:
                sy_cur = sy_next
        return G

    return obs.instrument_jit(
        shard_map(body, mesh=mesh, in_specs=(spec, spec, PartitionSpec()),
                  out_specs=spec, check_rep=False), site="gram_ring")


@_obs_entry
def gram(Sx: jax.Array, Sy: jax.Array, weights: jax.Array, *,
         backend: str = "auto", block_words: int | None = None,
         bx_tile: int | None = None, by_tile: int | None = None,
         precision: str = "fp32") -> jax.Array:
    """Weighted signature Gram product (B_x, D), (B_y, D), (D,) -> (B_x, B_y).

    The tiled route of the signature kernel k_ω(x, y) = S_x diag(ω) S_yᵀ:
    blocked over the word axis (``block_words`` coordinates at a time) so the
    (B_x, B_y, D) elementwise intermediate is never materialised, on every
    backend (see the support-matrix note in the module docstring).
    Differentiable in all three operands via the closed-form product VJP —
    gradients flow into learned signatures AND learned weights.

    Under an installed ``sharding_ctx(mesh)`` that shards the "batch"
    logical axis, the product runs as the cross-device ring of
    :func:`_gram_ring`: (B_x/P, B_y/P) tiles, O(B·D_sig) communication,
    never a replicated (B_x, B_y) or (B_x, B_y, D_sig) intermediate.  Both
    operands are padded up to a multiple of the axis size with zero rows
    (exact: zero rows / columns are sliced back off).
    """
    engine, interpret = resolve_backend(backend)
    precision = canon_precision(precision)
    if engine == "hybrid":  # the gram product has no dense/word split
        engine, interpret = "jax", False
    if Sx.ndim != 2 or Sy.ndim != 2 or Sy.shape[1] != Sx.shape[1] \
            or weights.shape != (Sx.shape[1],):
        raise ValueError(
            f"gram needs Sx (B_x, D), Sy (B_y, D), weights (D,); got "
            f"{Sx.shape}, {Sy.shape}, {weights.shape}")
    mb = _mesh_batch()
    if block_words is None or bx_tile is None or by_tile is None:
        if mb is not None:
            # under the mesh the tiles the local product actually sees are
            # the per-shard ones — key the autotune cell on those (and on P:
            # the ring's step count changes the profitable block size)
            P = mb[2]
            hit = autotune.lookup("gram_ring", engine=engine, D=Sx.shape[1],
                                  Bx=-(-Sx.shape[0] // P),
                                  By=-(-Sy.shape[0] // P), P=P,
                                  precision=precision)
        else:
            hit = autotune.lookup("gram", engine=engine, D=Sx.shape[1],
                                  Bx=Sx.shape[0], By=Sy.shape[0],
                                  precision=precision)
        block_words = hit.get("block_words", 512) if block_words is None \
            else block_words
        bx_tile = hit.get("bx_tile", 128) if bx_tile is None else bx_tile
        by_tile = hit.get("by_tile", 128) if by_tile is None else by_tile
    if block_words < 1:
        raise ValueError(f"block_words must be >= 1, got {block_words}")
    # precision: rounding the signature operands IS the semantics (both
    # engines then accumulate the same values in fp32); the STE quantiser
    # keeps exact fp32 cotangents for the rounded forward.
    Sx = quantise_increments(Sx, precision)
    Sy = quantise_increments(Sy, precision)
    if mb is not None:
        mesh, names, size = mb
        ring = _gram_ring(mesh, names, size, engine, interpret, block_words,
                          bx_tile, by_tile)
        Bx, By = Sx.shape[0], Sy.shape[0]
        if obs.REGISTRY._enabled:
            # analytic ring accounting: size-1 unrolled permute steps (the
            # last held shard is consumed, not forwarded), each one ppermute
            # of the local (By/size, D) Y shard — published at dispatch so
            # the ring-vs-oracle anomaly is a counter, not a benchmark-only
            # artefact (HLO-derived numbers ride obs.record_collectives
            # where a lowered module is at hand).
            By_pad = -(-By // size) * size
            shard_bytes = (By_pad // size) * Sy.shape[1] * Sy.dtype.itemsize
            obs.counter("pathsig_ring_ppermute_total",
                        "ppermute steps issued by the gram ring",
                        ("ctx",)).inc(
                size - 1, ctx="trace" if isinstance(Sx, jax.core.Tracer)
                else "eager")
            obs.counter("pathsig_ring_wire_bytes_total",
                        "analytic wire bytes moved by gram-ring ppermutes "
                        "(per device)", ("ctx",)).inc(
                (size - 1) * shard_bytes,
                ctx="trace" if isinstance(Sx, jax.core.Tracer) else "eager")
        with obs.span("kernels.gram_ring", devices=size,
                      shapes=obs.shape_key(Sx, Sy)):
            G = ring(_pad_rows(Sx, size), _pad_rows(Sy, size), weights)
        if G.shape != (Bx, By):
            G = G[:Bx, :By]
        return shard_constraint(G, "batch", None)
    return _gram_vjp(engine, interpret, block_words, bx_tile,
                     by_tile)(Sx, Sy, weights)


# ---------------------------------------------------------------------------
# public dispatch
# ---------------------------------------------------------------------------

def _mask_stream_out(out: jax.Array, M: int, stride: int,
                     lengths) -> jax.Array:
    """Zero a streamed output (B, M_out, D) after each example's true-
    terminal slot.  No-op without lengths (or with no emissions)."""
    if lengths is None or out.shape[1] == 0:
        return out
    return out * stream_emit_mask(M, stride, lengths)[..., None].astype(
        out.dtype)


def _signature_local(increments: jax.Array, lengths, *, depth: int,
                     engine: str, interpret: bool, backward: str,
                     batch_tile: int, split: int | None, time_chunks: int,
                     stream: bool, stream_stride: int, transform=None,
                     x0=None, precision: str = "fp32") -> jax.Array:
    """Single-device truncated-signature dispatch — the body of
    :func:`signature` after validation and mesh routing.  Under a mesh this
    runs per shard inside :func:`_sharded_sig` (never consults the context
    again, so shard_map bodies cannot recurse into the mesh branch).

    Precision discipline: the increments are quantised HERE, once, before any
    engine or custom VJP sees them — rounding is the semantics (every engine
    agrees bit-for-bit on what it accumulates), while the kernels' storage
    dtype handles the bandwidth side.  The STE-style quantiser keeps fp32
    cotangents, so gradients are exact for the rounded forward.
    """
    spec = transform
    if spec is None:
        if lengths is not None:
            lengths = as_lengths(lengths, increments.shape[0])
            increments = mask_increments(increments, lengths)
        increments = quantise_increments(increments, precision)
        if stream:
            if engine == "jax" or backward == "autodiff" \
                    or increments.shape[1] == 0:  # M=0: no emissions
                out = signature_from_increments(
                    increments, depth, stream=True,
                    stream_stride=stream_stride, backward=backward,
                    backend="jax")
            else:
                out = _pallas_sig_stream(depth, stream_stride, batch_tile,
                                         split, interpret,
                                         precision)(increments)
            # bf16_fp32 stores the streamed emission buffer in bf16 (the
            # pallas cells emit bf16 from fp32 accumulators); the STE round
            # here makes every engine agree on the emitted values and is
            # idempotent over the kernels' hard rounding
            out = quantise_increments(out, precision)
            return _mask_stream_out(out, increments.shape[1], stream_stride,
                                    lengths)
        if engine == "jax" or backward == "autodiff":
            # autodiff has no Pallas rule: route to the jax engine entirely so
            # the forward actually produces the residuals the scan AD consumes.
            return signature_from_increments(increments, depth,
                                             backward=backward, backend="jax")
        if time_chunks > 1:
            return _time_parallel_combine(
                lambda x: _signature_local(x, None, depth=depth, engine=engine,
                                           interpret=interpret,
                                           backward=backward,
                                           batch_tile=batch_tile, split=split,
                                           time_chunks=1, stream=False,
                                           stream_stride=1,
                                           precision=precision),
                increments, depth, time_chunks)
        if backward == "checkpoint":
            chunk = default_chunk(increments.shape[1])
            return _pallas_sig_checkpoint(depth, chunk, batch_tile, split,
                                          interpret, precision)(increments)
        return _pallas_sig_inverse(depth, batch_tile, split, interpret,
                                   precision)(increments)
    # ---- fused-transform cell -------------------------------------------
    if engine == "jax" or backward == "autodiff" or increments.shape[1] == 0:
        # the pure-JAX fused scan owns masking/basepoint/taux bookkeeping
        out = signature_from_increments(
            increments, depth, stream=stream, stream_stride=stream_stride,
            backward=backward, backend="jax", lengths=lengths, transform=spec,
            x0=x0, precision=precision)
        return quantise_increments(out, precision) if stream else out
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
        increments = mask_increments(increments, lengths)
    if spec.basepoint:
        if x0 is None:
            raise ValueError("transform with basepoint needs x0= (the path "
                             "start point, shape (B, d)); repro.core."
                             "signature.signature passes it automatically")
        x0 = jnp.asarray(x0).astype(increments.dtype)
        increments = jnp.concatenate([x0[:, None, :], increments], axis=1)
        lengths = None if lengths is None else lengths + 1
    increments = quantise_increments(increments, precision)
    kspec = dataclasses_replace_nobp(spec)
    if not kspec:
        # basepoint-only: just one prepended increment — plain engines
        # (lengths already shifted; re-masking the prepended batch is exact)
        return _signature_local(increments, lengths, depth=depth,
                                engine=engine, interpret=interpret,
                                backward=backward, batch_tile=batch_tile,
                                split=split, time_chunks=time_chunks,
                                stream=stream, stream_stride=stream_stride,
                                precision=precision)
    B, M_bp, _ = increments.shape
    taux = transform_time_aux(kspec, B, M_bp, lengths)
    M_aug = M_bp * kspec.sub_steps
    aug_lengths = None if lengths is None else lengths * kspec.sub_steps
    if stream:
        out = _pallas_sig_fused_stream(depth, stream_stride, batch_tile,
                                       split, interpret, kspec,
                                       precision)(increments, taux)
        out = quantise_increments(out, precision)
        return _mask_stream_out(out, M_aug, stream_stride, aug_lengths)
    if time_chunks > 1 or backward == "checkpoint":
        # materialise-then-sweep fallback (support matrix): the augment is
        # linear jnp, so autodiff through it IS the transform adjoint and the
        # chunked cells run unchanged over the augmented increments.
        e = fused_augment(increments, taux, kspec)
        return _signature_local(e, None, depth=depth, engine=engine,
                                interpret=interpret, backward=backward,
                                batch_tile=batch_tile, split=split,
                                time_chunks=time_chunks, stream=False,
                                stream_stride=1, precision=precision)
    return _pallas_sig_fused_inverse(depth, batch_tile, split, interpret,
                                     kspec, precision)(increments, taux)


@_obs_entry
def signature(increments: jax.Array, depth: int, *, backend: str = "auto",
              backward: str = "inverse", batch_tile: int | None = None,
              split: int | None = None, time_chunks: int = 1,
              stream: bool = False, stream_stride: int = 1,
              lengths=None, transform=None, x0=None,
              precision: str = "fp32") -> jax.Array:
    """Truncated signature (B, M, d) -> (B, D_sig), differentiable on every
    backend (see the support matrix in the module docstring).

    ``stream=True`` -> (B, M_out, D_sig) prefix signatures at every
    ``stream_stride``-th step (terminal always included).

    ``lengths`` (B,) makes the batch ragged (every backend × backward ×
    stream cell): padded-tail increments are zero-masked BEFORE the engine
    runs (a zero increment is the identity update, so terminal outputs are
    exactly the per-example unpadded signatures, and the outermost mask
    multiply zeroes cotangents past each true end); streamed outputs are
    additionally masked after each example's true-terminal slot
    (:func:`repro.core.signature.stream_emit_slots` gathers it).

    ``transform`` (``"time_augment"``, ``"lead_lag"``, ``"basepoint"``, or a
    ``+``-joined composition — :func:`repro.core.transforms.as_transform`)
    applies the path transform FUSED into the engine sweep: the augmented
    increment is built in VMEM/registers per Horner sub-step and the
    (B, M_aug, d_aug) intermediate never materialises (forward, streamed,
    and both custom-VJP backwards; streamed strides/lengths count AUGMENTED
    steps).  ``x0`` (B, d) is the path start, required iff the transform
    includes basepoint.  ``precision="bf16_fp32"`` rounds increments to bf16
    (storage + traffic) while accumulating in fp32 — see the module
    docstring for the error model.

    ``batch_tile=None`` (the default) consults the persistent autotuner
    (:mod:`repro.kernels.autotune`) for this dispatch cell and falls back to
    128; an explicit value always wins.  A cached autotune entry may also
    supply ``split`` when it is not passed.

    Under an installed ``sharding_ctx(mesh)`` whose rules shard the "batch"
    logical axis, the call is SPMD: the batch is split over the mesh with
    ``shard_map`` and each shard runs this same cell (see the mesh note in
    the module docstring).  Outside any context the result is bit-identical
    to the single-device path.
    """
    engine, interpret = resolve_backend(backend)
    _check_backward(backward)
    precision = canon_precision(precision)
    spec = as_transform(transform)
    if engine == "hybrid":
        raise ValueError(
            "backend='hybrid' only applies to projected word sets (the "
            "truncated signature IS the dense engine); use backend='jax'")
    if stream:
        if stream_stride < 1:
            raise ValueError(
                f"stream_stride must be >= 1, got {stream_stride}")
        if backward == "checkpoint":
            raise unsupported_stream_backward(backward)
        if time_chunks > 1:
            raise NotImplementedError(
                "stream=True is incompatible with time_chunks > 1: chunked "
                "signatures only reconstruct the terminal state")
    d_eff = transform_dim(spec, increments.shape[-1]) if spec \
        else increments.shape[-1]
    M_eff = transform_steps(spec, increments.shape[1]) if spec \
        else increments.shape[1]
    if batch_tile is None:
        hit = autotune.lookup("sig_trunc", engine=engine, d=d_eff,
                              depth=depth, M=M_eff, B=increments.shape[0],
                              precision=precision)
        batch_tile = hit.get("batch_tile", 128)
        if split is None:
            split = hit.get("split")
    if obs.REGISTRY._enabled and engine == "pallas" and depth >= 1:
        from .sig_trunc import choose_split, state_footprint
        itemsize = 2 if precision == "bf16_fp32" else 4
        s = split if split is not None else choose_split(
            d_eff, depth, batch_tile, itemsize=itemsize)
        obs.gauge(
            "pathsig_vmem_state_bytes",
            "per-cell VMEM footprint of the cone-kernel state at the "
            "resolved (batch_tile, split)", ("op",)).set(
            state_footprint(d_eff, depth, s, batch_tile, itemsize),
            op="signature")
    kw = dict(depth=depth, engine=engine, interpret=interpret,
              backward=backward, batch_tile=batch_tile, split=split,
              time_chunks=time_chunks, stream=stream,
              stream_stride=stream_stride)
    mb = _mesh_batch()
    if mb is None:
        return _signature_local(increments, lengths, **kw, transform=spec,
                                x0=x0, precision=precision)
    mesh, names, size = mb
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
    if spec:
        # mesh × transform: increment-level materialise (support matrix) —
        # the augment is linear jnp, so its adjoint rides ordinary AD and the
        # per-shard custom VJPs run unchanged over augmented increments.
        if lengths is not None:
            increments, lengths = augment_increments(increments, spec, x0=x0,
                                                     lengths=lengths)
        else:
            increments = augment_increments(increments, spec, x0=x0)
    fn = _sharded_sig(mesh, names, lengths is not None, depth, engine,
                      interpret, backward, batch_tile, split, time_chunks,
                      stream, stream_stride, precision)
    out = _apply_sharded(fn, size, increments, lengths)
    if stream:
        return shard_constraint(out, "batch", "path_time", "sig_words")
    return shard_constraint(out, "batch", "sig_words")


def _projected_local(increments: jax.Array, lengths, *, words: tuple, d: int,
                     engine: str, interpret: bool, backward: str,
                     batch_tile: int, max_rows: int, stream: bool,
                     stream_stride: int, transform=None, x0=None,
                     precision: str = "fp32") -> jax.Array:
    """Single-device projected-signature dispatch — the body of
    :func:`projected` after validation and mesh routing (``max_rows`` is
    already resolved from any caller-supplied TiledPlan).  Same precision
    discipline as :func:`_signature_local`: quantise once at dispatch, every
    engine sees the rounded values."""
    wplan = _plan_for_words(words, d)
    spec = transform
    if spec is not None:
        # fused-transform cell: the word kernel fuses lead_lag/time; every
        # other engine × backward cell runs the documented materialise-then-
        # sweep fallback over augmented increments.
        if lengths is not None:
            lengths = as_lengths(lengths, increments.shape[0])
            increments = mask_increments(increments, lengths)
        if spec.basepoint:
            if x0 is None:
                raise ValueError("transform with basepoint needs x0= (the "
                                 "path start point, shape (B, d))")
            x0 = jnp.asarray(x0).astype(increments.dtype)
            increments = jnp.concatenate([x0[:, None, :], increments], axis=1)
            lengths = None if lengths is None else lengths + 1
        increments = quantise_increments(increments, precision)
        kspec = dataclasses_replace_nobp(spec)
        kw = dict(words=words, d=d, engine=engine, interpret=interpret,
                  backward=backward, batch_tile=batch_tile, max_rows=max_rows,
                  stream=stream, stream_stride=stream_stride,
                  precision=precision)
        if not kspec:  # basepoint-only: one prepended increment
            return _projected_local(increments, lengths, **kw)
        B, M_bp, _ = increments.shape
        taux = transform_time_aux(kspec, B, M_bp, lengths)
        M_aug = M_bp * kspec.sub_steps
        aug_lengths = None if lengths is None else lengths * kspec.sub_steps
        if engine in ("jax", "hybrid") or backward == "autodiff" \
                or M_aug == 0 or (not stream and backward != "inverse"):
            e = fused_augment(increments, taux, kspec)
            return _projected_local(e, aug_lengths, **kw)
        if stream:
            out = _pallas_proj_fused_stream(
                wplan.words, wplan.d, stream_stride, batch_tile, max_rows,
                interpret, kspec, precision)(increments, taux)
            out = quantise_increments(out, precision)
            return _mask_stream_out(out, M_aug, stream_stride, aug_lengths)
        return _pallas_proj_fused_inverse(
            wplan.words, wplan.d, batch_tile, max_rows, interpret, kspec,
            precision)(increments, taux)
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
        increments = mask_increments(increments, lengths)
    increments = quantise_increments(increments, precision)
    if engine == "hybrid":
        if backward == "checkpoint":
            # no chunk-boundary buffer in the hybrid engine: run on jax
            return projected_signature_from_increments(
                increments, wplan, backward=backward, backend="jax")
        return _hybrid_projected(increments, wplan, backward)
    if stream:
        if engine == "jax" or backward == "autodiff" \
                or increments.shape[1] == 0:  # M=0: no emissions, any engine
            out = projected_signature_from_increments(
                increments, wplan, stream=True, stream_stride=stream_stride,
                backward=backward, backend="jax")
        else:
            out = _pallas_proj_stream(wplan.words, wplan.d, stream_stride,
                                      batch_tile, max_rows, interpret,
                                      precision)(increments)
        # same streamed-emission rounding discipline as _signature_local
        out = quantise_increments(out, precision)
        return _mask_stream_out(out, increments.shape[1], stream_stride,
                                lengths)
    if engine == "jax" or backward != "inverse":
        # checkpoint needs chunk-boundary closure states the word kernel
        # cannot emit; autodiff needs scan residuals — both run on jax.
        return projected_signature_from_increments(
            increments, wplan, backward=backward, backend="jax")
    return _pallas_proj_inverse(wplan.words, wplan.d, batch_tile, max_rows,
                                interpret, precision)(increments)


@_obs_entry
def projected(increments: jax.Array, plan, *, backend: str = "auto",
              backward: str = "inverse", batch_tile: int | None = None,
              max_rows: int = 256, stream: bool = False,
              stream_stride: int = 1, lengths=None, transform=None,
              x0=None, precision: str = "fp32") -> jax.Array:
    """Projected signature over a word set / plan (B, M, d) -> (B, |I|),
    differentiable on every backend.  ``plan`` may be a WordPlan, a
    TiledPlan, or an iterable of letter tuples.

    ``stream=True`` -> (B, M_out, |I|) per-step projections.  ``lengths``
    (B,) makes the batch ragged, with the same zero-masked-increment
    exactness guarantees as :func:`signature`.  An installed
    ``sharding_ctx(mesh)`` sharding the "batch" logical axis makes the call
    SPMD exactly like :func:`signature`.

    ``transform`` / ``x0`` / ``precision`` mirror :func:`signature`: the
    word set is over the AUGMENTED alphabet (letters index the transformed
    channels, ``wplan.d == transform_dim(transform, d_raw)``), the pallas
    inverse/stream cells fuse the transform into the kernel time loop, and
    every other cell materialises augmented increments once then sweeps
    (support matrix).  ``batch_tile=None`` consults the autotuner.
    """
    engine, interpret = resolve_backend(backend)
    _check_backward(backward)
    precision = canon_precision(precision)
    spec = as_transform(transform)
    d_in = increments.shape[-1]
    d_eff = transform_dim(spec, d_in) if spec else d_in
    wplan, tplan = _normalise_plans(plan, d_eff)
    if spec and wplan.d != d_eff:
        raise ValueError(
            f"projected word plan is over d={wplan.d} letters, but transform "
            f"{spec} maps d={d_in} input channels to {d_eff} augmented "
            f"channels — build the plan over the augmented alphabet")
    if engine == "hybrid" and stream:
        raise NotImplementedError(
            "backend='hybrid' has no streamed forward; use "
            "backend='jax' or a pallas backend for stream=True")
    if stream:
        if stream_stride < 1:
            raise ValueError(
                f"stream_stride must be >= 1, got {stream_stride}")
        if backward == "checkpoint":
            raise unsupported_stream_backward(backward)
    if tplan is not None:  # keep the caller's tile granularity
        max_rows = max(p.closure_size for p in tplan.tiles)
    if batch_tile is None:
        hit = autotune.lookup(
            "sig_words", engine=engine, d=wplan.d, depth=wplan.depth,
            M=transform_steps(spec, increments.shape[1]) if spec
            else increments.shape[1],
            B=increments.shape[0], precision=precision)
        batch_tile = hit.get("batch_tile", 128)
    kw = dict(words=wplan.words, d=wplan.d, engine=engine,
              interpret=interpret, backward=backward, batch_tile=batch_tile,
              max_rows=max_rows, stream=stream, stream_stride=stream_stride)
    mb = _mesh_batch()
    if mb is None:
        return _projected_local(increments, lengths, **kw, transform=spec,
                                x0=x0, precision=precision)
    mesh, names, size = mb
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
    if spec:
        # mesh × transform: increment-level materialise (support matrix)
        if lengths is not None:
            increments, lengths = augment_increments(increments, spec, x0=x0,
                                                     lengths=lengths)
        else:
            increments = augment_increments(increments, spec, x0=x0)
    fn = _sharded_proj(mesh, names, lengths is not None, wplan.words,
                       wplan.d, engine, interpret, backward, batch_tile,
                       max_rows, stream, stream_stride, precision)
    out = _apply_sharded(fn, size, increments, lengths)
    if stream:
        return shard_constraint(out, "batch", "path_time", "sig_words")
    return shard_constraint(out, "batch", "sig_words")


def _projected_fwd_local(increments: jax.Array, lengths, *, words: tuple,
                         d: int, engine: str, interpret: bool,
                         batch_tile: int, max_rows: int, transform=None,
                         x0=None, precision: str = "fp32") -> jax.Array:
    """Single-device body of :func:`projected_forward_only`."""
    wplan = _plan_for_words(words, d)
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
        increments = mask_increments(increments, lengths)
    spec = transform
    taux = None
    kspec = None
    if spec is not None:
        if spec.basepoint:
            if x0 is None:
                raise ValueError("transform with basepoint needs x0= (the "
                                 "path start point, shape (B, d))")
            increments = jnp.concatenate(
                [jnp.asarray(x0).astype(increments.dtype)[:, None, :],
                 increments], axis=1)
            lengths = None if lengths is None else lengths + 1
        kspec = dataclasses_replace_nobp(spec) or None
    increments = quantise_increments(increments, precision)
    if kspec is not None:
        taux = transform_time_aux(kspec, increments.shape[0],
                                  increments.shape[1], lengths)
        if engine in ("jax", "hybrid"):
            # materialise-then-sweep fallback (support matrix)
            increments = fused_augment(increments, taux, kspec)
            kspec = taux = None
    if engine == "hybrid":
        return _hybrid_projected(increments, wplan, "inverse")
    if engine == "jax":
        return projected_signature_from_increments(increments, wplan,
                                                   backend="jax")
    tplan = _tiled_for_words(wplan.words, wplan.d, max_rows)
    return sig_words(increments, tplan, batch_tile=batch_tile,
                     interpret=interpret, transform=kspec, taux=taux,
                     precision=precision)


@_obs_entry
def projected_forward_only(increments: jax.Array, plan, *,
                           backend: str = "auto", batch_tile: int | None = None,
                           max_rows: int = 256, lengths=None, transform=None,
                           x0=None, precision: str = "fp32") -> jax.Array:
    """Inference-only projected signature: skips the closure readout (the
    kernel gathers just the requested rows).  Not differentiable on the
    pallas engines — use :func:`projected` for training.  Mesh-aware like
    :func:`projected` (per-shard kernels under a batch-sharding context);
    ``transform`` / ``x0`` / ``precision`` / autotuned ``batch_tile`` mirror
    :func:`projected`."""
    engine, interpret = resolve_backend(backend)
    precision = canon_precision(precision)
    spec = as_transform(transform)
    d_in = increments.shape[-1]
    d_eff = transform_dim(spec, d_in) if spec else d_in
    wplan, tplan = _normalise_plans(plan, d_eff)
    if spec and wplan.d != d_eff:
        raise ValueError(
            f"projected word plan is over d={wplan.d} letters, but transform "
            f"{spec} maps d={d_in} input channels to {d_eff} augmented "
            f"channels — build the plan over the augmented alphabet")
    if tplan is not None:  # keep the caller's tile granularity
        max_rows = max(p.closure_size for p in tplan.tiles)
    if batch_tile is None:
        hit = autotune.lookup(
            "sig_words", engine=engine, d=wplan.d, depth=wplan.depth,
            M=transform_steps(spec, increments.shape[1]) if spec
            else increments.shape[1],
            B=increments.shape[0], precision=precision)
        batch_tile = hit.get("batch_tile", 128)
    kw = dict(words=wplan.words, d=wplan.d, engine=engine,
              interpret=interpret, batch_tile=batch_tile, max_rows=max_rows)
    mb = _mesh_batch()
    if mb is None:
        return _projected_fwd_local(increments, lengths, **kw, transform=spec,
                                    x0=x0, precision=precision)
    mesh, names, size = mb
    if lengths is not None:
        lengths = as_lengths(lengths, increments.shape[0])
    if spec:
        # mesh × transform: increment-level materialise (support matrix)
        if lengths is not None:
            increments, lengths = augment_increments(increments, spec, x0=x0,
                                                     lengths=lengths)
        else:
            increments = augment_increments(increments, spec, x0=x0)
    fn = _sharded_proj_fwd(mesh, names, lengths is not None, wplan.words,
                           wplan.d, engine, interpret, batch_tile, max_rows,
                           precision)
    out = _apply_sharded(fn, size, increments, lengths)
    return shard_constraint(out, "batch", "sig_words")


def _time_parallel_combine(sig_flat_fn, increments: jax.Array, depth: int,
                           time_chunks: int) -> jax.Array:
    """Fold time chunks into the batch axis, compute chunk signatures with
    ``sig_flat_fn`` ((B·C, Mc, d) -> (B·C, D_sig)), Chen-combine in a
    log-depth tree.  Shared by :func:`signature_time_parallel` (public,
    routed through the dispatch) and the mesh path's per-shard body."""
    B, M, d = increments.shape
    C = max(1, min(time_chunks, M))
    Mc = -(-M // C)
    pad = C * Mc - M
    x = jnp.pad(increments, ((0, 0), (0, pad), (0, 0)))  # zero incs = identity
    x = x.reshape(B, C, Mc, d).reshape(B * C, Mc, d)
    flat = sig_flat_fn(x)
    parts = flat.reshape(B, C, -1)
    # log-depth Chen combination tree
    while parts.shape[1] > 1:
        n = parts.shape[1]
        even, odd = parts[:, 0:n - n % 2:2], parts[:, 1:n:2]
        a = tops.flat_to_levels(even.reshape(-1, even.shape[-1]), d, depth)
        b = tops.flat_to_levels(odd.reshape(-1, odd.shape[-1]), d, depth)
        merged = tops.levels_to_flat(tops.chen_mul(a, b))
        merged = merged.reshape(even.shape)
        if n % 2:
            merged = jnp.concatenate([merged, parts[:, -1:]], axis=1)
        parts = merged
    return parts[:, 0]


def signature_time_parallel(increments: jax.Array, depth: int,
                            time_chunks: int, *, backend: str = "auto",
                            backward: str = "inverse",
                            batch_tile: int | None = None,
                            split: int | None = None,
                            precision: str = "fp32") -> jax.Array:
    """Chunked-time signature: fold chunks into batch, tree-Chen-combine.

    Differentiable end to end: the per-chunk signatures carry the dispatch
    layer's custom VJPs and the combination tree is plain jnp algebra.
    """
    return _time_parallel_combine(
        lambda x: signature(x, depth, backend=backend, backward=backward,
                            batch_tile=batch_tile, split=split,
                            time_chunks=1, precision=precision),
        increments, depth, time_chunks)
