"""jit'd dispatch layer over the signature engines.

``backend`` selection:

- ``"jax"``      — pure-JAX levelwise Horner scan (works everywhere, used for
                   gradients: the Pallas forwards are wrapped in the same
                   inverse-reconstruction custom VJP).
- ``"pallas"``   — Pallas TPU kernels, compiled for the accelerator.
- ``"pallas_interpret"`` — same kernels executed in interpret mode (CPU
                   validation; the container's default).
- ``"auto"``     — pallas on TPU, jax elsewhere.

Also provides ``signature_time_parallel``: a beyond-paper optimisation that
splits the time axis into C chunks, computes chunk signatures independently
(folded into the batch axis — more parallel work, the paper's windowing
argument applied to *one* signature) and Chen-combines them in a log-depth
tree.  The paper explicitly does not parallelise over sequence length
(§6.1); on TPU this recovers utilisation for long paths at small batch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import tensor_ops as tops
from repro.core.signature import signature_from_increments
from repro.core.projection import projected_signature_from_increments
from repro.core.words import TiledPlan, WordPlan, make_plan, make_tiled_plan
from .sig_trunc import sig_trunc
from .sig_words import sig_words


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> tuple[str, bool]:
    """-> (engine, interpret)"""
    if backend == "auto":
        return ("pallas", False) if _on_tpu() else ("jax", False)
    if backend == "pallas":
        return "pallas", not _on_tpu()
    if backend == "pallas_interpret":
        return "pallas", True
    if backend == "jax":
        return "jax", False
    raise ValueError(f"unknown backend {backend!r}")


def signature(increments: jax.Array, depth: int, *, backend: str = "auto",
              batch_tile: int = 128, split: int | None = None,
              time_chunks: int = 1) -> jax.Array:
    """Truncated signature (B, M, d) -> (B, D_sig)."""
    engine, interpret = _resolve(backend)
    if engine == "jax":
        return signature_from_increments(increments, depth)
    if time_chunks > 1:
        return signature_time_parallel(increments, depth, time_chunks,
                                       backend=backend, batch_tile=batch_tile,
                                       split=split)
    return sig_trunc(increments, depth, batch_tile=batch_tile, split=split,
                     interpret=interpret)


def projected(increments: jax.Array, plan, *, backend: str = "auto",
              batch_tile: int = 128, max_rows: int = 256) -> jax.Array:
    """Projected signature over a word set / plan (B, M, d) -> (B, |I|)."""
    engine, interpret = _resolve(backend)
    if isinstance(plan, TiledPlan):
        tplan, wplan = plan, None
    elif isinstance(plan, WordPlan):
        tplan, wplan = None, plan
    else:  # iterable of words
        wplan = make_plan(tuple(tuple(w) for w in plan), increments.shape[-1])
        tplan = None
    if engine == "jax":
        if wplan is None:
            wplan = make_plan(tplan.words, tplan.d)
        return projected_signature_from_increments(increments, wplan)
    if tplan is None:
        tplan = make_tiled_plan(wplan.words, wplan.d, max_rows=max_rows)
    return sig_words(increments, tplan, batch_tile=batch_tile,
                     interpret=interpret)


def signature_time_parallel(increments: jax.Array, depth: int,
                            time_chunks: int, *, backend: str = "auto",
                            batch_tile: int = 128,
                            split: int | None = None) -> jax.Array:
    """Chunked-time signature: fold chunks into batch, tree-Chen-combine."""
    B, M, d = increments.shape
    C = max(1, min(time_chunks, M))
    Mc = -(-M // C)
    pad = C * Mc - M
    x = jnp.pad(increments, ((0, 0), (0, pad), (0, 0)))  # zero incs = identity
    x = x.reshape(B, C, Mc, d).reshape(B * C, Mc, d)
    flat = signature(x, depth, backend=backend, batch_tile=batch_tile,
                     split=split, time_chunks=1)          # (B*C, D)
    parts = flat.reshape(B, C, -1)
    # log-depth Chen combination tree
    while parts.shape[1] > 1:
        n = parts.shape[1]
        even, odd = parts[:, 0:n - n % 2:2], parts[:, 1:n:2]
        a = tops.flat_to_levels(even.reshape(-1, even.shape[-1]), d, depth)
        b = tops.flat_to_levels(odd.reshape(-1, odd.shape[-1]), d, depth)
        merged = tops.levels_to_flat(tops.chen_mul(a, b))
        merged = merged.reshape(even.shape)
        if n % 2:
            merged = jnp.concatenate([merged, parts[:, -1:]], axis=1)
        parts = merged
    return parts[:, 0]
