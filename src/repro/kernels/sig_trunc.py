"""Pallas TPU kernel: truncated signature via prefix-cone decomposition.

TPU adaptation of the paper's §3.1-3.2 CUDA design (see DESIGN.md §2).  The
word basis W_{<=N} is partitioned into *prefix cones*: grid cell ``c`` owns
the level-``s`` prefix word ``u = digits_d(c)`` together with every
descendant ``u∘v`` up to depth N, plus (redundantly) u's ancestor path —
a prefix-closed set, the tile-granularity analogue of the paper's
thread-per-``P_w`` assignment.  Each cell scans the whole time axis with its
coefficients resident in VMEM.

Layout: batch on the 128-wide lane axis, words on sublanes, so the per-word
Horner rule (paper Alg. 1) vectorises across the batch and the level-raising
outer product is a sublane reshape-broadcast — no gathers anywhere.

Per-cell state block (rows × B_TILE), rows =
  [ path: levels 1..s-1 along u ] ++ [ cone levels s..N: d^0, d^1, ..., d^{N-s} rows ]

Streaming (``stream=True``): the running state lives in a VMEM scratch block
and every ``stream_stride``-th step (plus the terminal step) is copied into an
(M_out, rows, B_TILE) output block *inside* the time loop — the kernel emits
all prefix signatures S_{0,t_j} in one pass.  ``stream_stride`` bounds the
output block so VMEM/HBM stays proportional to M_out = ceil(M / stride), not
M; the emitted step indices are ``repro.core.signature.stream_emit_steps``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core.words import sig_dim


def cone_base_level(s: int) -> int:
    """Lowest global level stored in the cone (eps is never stored)."""
    return max(s, 1)


def cone_offsets(d: int, depth: int, s: int) -> np.ndarray:
    """Row offsets of cone global levels n = base..depth inside the block."""
    base = cone_base_level(s)
    sizes = [d ** (n - s) for n in range(base, depth + 1)]
    return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)


def cone_rows(d: int, depth: int, s: int) -> int:
    return int(cone_offsets(d, depth, s)[-1])


def state_footprint(d: int, depth: int, s: int, batch_tile: int,
                    itemsize: int = 4) -> int:
    """Per-cell VMEM bytes at split ``s``: the resident state block plus the
    chain temporaries (which roughly double the top cone level).  ``itemsize``
    is the element byte width of the state dtype — 4 for fp32, 2 for bf16 —
    so VMEM budgeting stays correct under mixed precision."""
    rows = max(0, s - 1) + cone_rows(d, depth, s)
    return (rows + d ** (depth - s)) * batch_tile * itemsize


def choose_split(d: int, depth: int, batch_tile: int,
                 vmem_budget: int = 6 * 2**20, itemsize: int = 4) -> int:
    """Smallest split level s whose per-cell state fits the VMEM budget."""
    for s in range(0, depth):
        if state_footprint(d, depth, s, batch_tile, itemsize) <= vmem_budget:
            return s
    return depth - 1


def _kernel(incs_ref, *refs, d: int, depth: int, s: int, M: int,
            stream_stride: int = 0, fuse_ll: bool = False,
            fuse_time: bool = False):
    """Cone update loop.  Non-streamed: ``out_ref`` IS the running state.
    Streamed (``stream_stride >= 1``): the state lives in the trailing VMEM
    scratch ref and strided snapshots are stored into ``out_ref``.

    Fused transforms (``fuse_ll`` / ``fuse_time``): the input block holds RAW
    increments (M, d_raw, B) and each augmented increment — channel layout
    [t?, lag, lead] matching ``core.transforms`` — is built in VMEM right
    here, ``sub = 2 if fuse_ll else 1`` Horner sub-steps per raw step.  The
    (M_aug, d_aug, B) block never exists; ``d`` is the AUGMENTED channel
    count and streamed emission is strided over the augmented step axis.
    ``fuse_time`` reads a (2, B) aux ref ``[dt; n_valid_aug]`` (zero time
    increments past each example's true augmented end)."""
    refs = list(refs)
    taux_ref = refs.pop(0) if fuse_time else None
    out_ref = refs.pop(0)
    scratch = refs
    stream = bool(scratch)
    state_ref = scratch[0] if stream else out_ref
    n_path = max(0, s - 1)
    base = cone_base_level(s)
    co = cone_offsets(d, depth, s)
    sub = 2 if fuse_ll else 1
    M_aug = M * sub

    def cone_slice(n):  # rows of global level n (n >= base)
        k = n - base
        return slice(n_path + int(co[k]), n_path + int(co[k + 1]))

    c = pl.program_id(1)
    # letters of the cell's prefix word u (traced scalars, most significant first)
    letters = [(c // d ** (s - 1 - k)) % d for k in range(s)]

    state_ref[...] = jnp.zeros(state_ref.shape, state_ref.dtype)

    def update(dx):
        """One augmented-increment Horner update.  dx: (d, B) in state dtype."""
        B = dx.shape[-1]
        # per-path-step increment components ΔX^{(u_k)}  -> (1, B)
        dxl = [jax.lax.dynamic_slice(dx, (letters[k], 0), (1, B))
               for k in range(s)]

        def path_val(lev):  # old value of ancestor u_{1:lev}, lev in 1..s-1
            return state_ref[lev - 1:lev, :]

        def chain(n):
            """Horner accumulator for target level n (paper Alg. 1):
            acc_j = (S[w_{1:j-1}] + acc_{j-1}) · ΔX^{(i_j)} / (n-j+1)."""
            acc = None
            for jj in range(1, n + 1):
                inv = 1.0 / (n - jj + 1)
                if jj == 1:          # innermost: S[eps] = 1
                    acc = (dxl[0] if s >= 1 else dx) * inv
                elif jj <= s:        # on-path step, width 1
                    acc = (path_val(jj - 1) + acc) * dxl[jj - 1] * inv
                else:                # cone expansion: width d^{jj-1-s} -> d^{jj-s}
                    prev = state_ref[cone_slice(jj - 1), :]
                    t = prev + acc
                    w = t.shape[0]
                    acc = (t[:, None, :] * dx[None, :, :]).reshape(w * d, B) * inv
            return acc

        # top-down over global target levels: reads touch strictly lower levels
        for n in range(depth, base - 1, -1):
            acc = chain(n)
            sl = cone_slice(n)
            state_ref[sl, :] = state_ref[sl, :] + acc
        # ancestor path levels n = s-1 .. 1 (width-1 chains)
        for n in range(min(s - 1, depth), 0, -1):
            acc = dxl[0] * (1.0 / n)
            for jj in range(2, n + 1):
                acc = (path_val(jj - 1) + acc) * dxl[jj - 1] * (1.0 / (n - jj + 1))
            state_ref[n - 1:n, :] = state_ref[n - 1:n, :] + acc

    def body(j, _):
        g = incs_ref[pl.ds(j, 1), :, :][0].astype(state_ref.dtype)  # (d_raw, B)
        for p in range(sub):
            ja = sub * j + p  # augmented step index
            if fuse_ll or fuse_time:
                parts = ([jnp.zeros_like(g), g] if p == 0 else
                         [g, jnp.zeros_like(g)]) if fuse_ll else [g]
                if fuse_time:
                    trow = taux_ref[0:1, :] * (
                        ja < taux_ref[1:2, :]).astype(state_ref.dtype)
                    parts = [trow] + parts
                e = jnp.concatenate(parts, axis=0)  # (d_aug, B) in VMEM
            else:
                e = g
            update(e)
            if stream:
                # strided per-step emission over the augmented axis: slot q
                # holds S_{0,t_{ja+1}}; the terminal step is always emitted
                q = ja // stream_stride

                @pl.when((((ja + 1) % stream_stride) == 0) | (ja == M_aug - 1))
                def _emit():
                    # the emission buffer may be bf16 (precision="bf16_fp32"):
                    # round on store, the fp32 accumulator state is untouched
                    pl.store(out_ref, (pl.ds(q, 1), slice(None), slice(None)),
                             state_ref[...].astype(out_ref.dtype)[None])
        return 0

    jax.lax.fori_loop(0, M, body, 0)


def _reassemble(out, d, depth, s, B):
    """(n_cells, n_path+cone, B_pad) -> flat (B, D_sig)."""
    n_cells = d**s
    n_path = max(0, s - 1)
    base = cone_base_level(s)
    co = cone_offsets(d, depth, s)
    levels = []
    for lev in range(1, s):  # ancestor levels, gathered from owning cells
        idx = np.arange(d**lev) * d ** (s - lev)
        levels.append(out[idx, lev - 1, :])  # (d^lev, B_pad)
    for n in range(base, depth + 1):  # cone global levels
        k = n - base
        blk = out[:, n_path + int(co[k]):n_path + int(co[k + 1]), :]
        levels.append(blk.reshape(n_cells * d ** (n - s), -1))
    flat = jnp.concatenate(levels, axis=0)  # (D_sig, B_pad)
    return flat[:, :B].T


def _reassemble_stream(out, d, depth, s, B):
    """(M_out, n_cells, n_path+cone, B_pad) -> (B, M_out, D_sig)."""
    T = out.shape[0]
    n_cells = d**s
    n_path = max(0, s - 1)
    base = cone_base_level(s)
    co = cone_offsets(d, depth, s)
    levels = []
    for lev in range(1, s):  # ancestor levels, gathered from owning cells
        idx = np.arange(d**lev) * d ** (s - lev)
        levels.append(out[:, idx, lev - 1, :])  # (T, d^lev, B_pad)
    for n in range(base, depth + 1):  # cone global levels
        k = n - base
        blk = out[:, :, n_path + int(co[k]):n_path + int(co[k + 1]), :]
        levels.append(blk.reshape(T, n_cells * d ** (n - s), -1))
    flat = jnp.concatenate(levels, axis=1)  # (T, D_sig, B_pad)
    return jnp.moveaxis(flat[:, :, :B], -1, 0)  # (B, T, D_sig)


def _fuse_flags(transform):
    """Validate a kernel-level transform spec -> (fuse_ll, fuse_time)."""
    if transform is None:
        return False, False
    if transform.basepoint:
        raise ValueError("kernel-level transform must not include basepoint "
                         "(dispatch prepends the x0 increment first)")
    return transform.lead_lag, transform.time


def _storage_dtype(precision: str):
    """Increments-block storage dtype: bf16 halves the VMEM/HBM traffic of
    the input block while accumulation stays fp32 in the state block."""
    if precision == "bf16_fp32":
        return jnp.bfloat16
    if precision == "fp32":
        return jnp.float32
    raise ValueError(f"unknown precision {precision!r}")


@functools.partial(jax.jit, static_argnames=("depth", "batch_tile", "split",
                                             "interpret", "vmem_budget",
                                             "stream", "stream_stride",
                                             "transform", "precision"))
def sig_trunc(increments: jax.Array, depth: int, *, batch_tile: int = 128,
              split: int | None = None, interpret: bool = True,
              vmem_budget: int = 6 * 2**20, stream: bool = False,
              stream_stride: int = 1, transform=None, taux=None,
              precision: str = "fp32") -> jax.Array:
    """Truncated signature via the Pallas cone kernel.  (B, M, d) -> (B, D_sig).

    ``stream=True`` emits every ``stream_stride``-th prefix signature (the
    terminal step always included): (B, M, d) -> (B, M_out, D_sig) with
    M_out = ceil(M_aug / stream_stride).

    ``transform`` (a :class:`repro.core.transforms.Transform` WITHOUT
    basepoint — dispatch prepends the x0 increment) fuses lead_lag /
    time_augment into the time loop: ``increments`` stay raw (B, M, d_raw)
    and the augmented increment is built in VMEM per sub-step.  ``taux`` is
    the (B, 2) ``transform_time_aux`` array, required iff the transform has
    a time channel.  ``precision="bf16_fp32"`` stores the increments block
    in bf16 (halved VMEM/HBM traffic) with fp32 accumulators.
    """
    obs.count_trace("sig_trunc", increments, depth=depth,
                    batch_tile=batch_tile, split=split, stream=stream,
                    precision=precision)
    B, M, d_raw = increments.shape
    if depth < 1:
        raise ValueError("depth must be >= 1")
    if stream_stride < 1:
        raise ValueError(f"stream_stride must be >= 1, got {stream_stride}")
    fuse_ll, fuse_time = _fuse_flags(transform)
    if fuse_time and taux is None:
        raise ValueError("transform with a time channel needs taux= "
                         "(see repro.core.transforms.transform_time_aux)")
    sub = 2 if fuse_ll else 1
    d = (2 * d_raw if fuse_ll else d_raw) + (1 if fuse_time else 0)
    M_aug = M * sub
    s = choose_split(d, depth, batch_tile, vmem_budget) if split is None else split
    if not 0 <= s < depth:
        raise ValueError(f"split {s} outside [0, {depth})")
    n_cells = d**s
    n_path = max(0, s - 1)
    rows = n_path + cone_rows(d, depth, s)

    B_pad = -(-B // batch_tile) * batch_tile
    x = jnp.moveaxis(increments, 0, -1)  # (M, d_raw, B)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, B_pad - B))).astype(
        _storage_dtype(precision))
    kern = functools.partial(_kernel, d=d, depth=depth, s=s, M=M,
                             fuse_ll=fuse_ll, fuse_time=fuse_time)
    inputs = [x]
    in_specs = [pl.BlockSpec((M, d_raw, batch_tile),
                             lambda bi, c: (0, 0, bi))]
    if fuse_time:
        ta = jnp.pad(jnp.asarray(taux, jnp.float32).T,
                     ((0, 0), (0, B_pad - B)))  # (2, B_pad)
        inputs.append(ta)
        in_specs.append(pl.BlockSpec((2, batch_tile), lambda bi, c: (0, bi)))

    if not stream:
        out = pl.pallas_call(
            kern,
            grid=(B_pad // batch_tile, n_cells),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((rows, batch_tile), lambda bi, c: (c, bi)),
            out_shape=jax.ShapeDtypeStruct((n_cells * rows, B_pad),
                                           jnp.float32),
            interpret=interpret,
        )(*inputs)
        out = out.reshape(n_cells, rows, B_pad)
        return _reassemble(out, d, depth, s, B).astype(increments.dtype)

    M_out = -(-M_aug // stream_stride)
    out = pl.pallas_call(
        functools.partial(kern, stream_stride=stream_stride),
        grid=(B_pad // batch_tile, n_cells),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((M_out, rows, batch_tile),
                               lambda bi, c: (0, c, bi)),
        # bf16_fp32: the (M_out, ·, ·) emission buffer is stored at the
        # precision's storage dtype (halving its VMEM/HBM footprint) while
        # the running state scratch stays a full fp32 accumulator
        out_shape=jax.ShapeDtypeStruct((M_out, n_cells * rows, B_pad),
                                       _storage_dtype(precision)),
        scratch_shapes=[pltpu.VMEM((rows, batch_tile), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    out = out.reshape(M_out, n_cells, rows, B_pad)
    return _reassemble_stream(out, d, depth, s, B).astype(increments.dtype)
