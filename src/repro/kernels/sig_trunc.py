"""Pallas TPU kernel: truncated signature via prefix-cone decomposition.

TPU adaptation of the paper's §3.1-3.2 CUDA design (see DESIGN.md §2).  The
word basis W_{<=N} is partitioned into *prefix cones*: grid cell ``c`` owns
the level-``s`` prefix word ``u = digits_d(c)`` together with every
descendant ``u∘v`` up to depth N, plus (redundantly) u's ancestor path —
a prefix-closed set, the tile-granularity analogue of the paper's
thread-per-``P_w`` assignment.  Each cell scans the whole time axis with its
coefficients resident in VMEM.

Layout: batch on the 128-wide lane axis, words on sublanes, so the per-word
Horner rule (paper Alg. 1) vectorises across the batch and the level-raising
outer product is a sublane reshape-broadcast — no gathers anywhere.

Per-cell state block (rows × B_TILE), rows =
  [ path: levels 1..s-1 along u ] ++ [ cone levels s..N: d^0, d^1, ..., d^{N-s} rows ]
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.words import sig_dim


def cone_base_level(s: int) -> int:
    """Lowest global level stored in the cone (eps is never stored)."""
    return max(s, 1)


def cone_offsets(d: int, depth: int, s: int) -> np.ndarray:
    """Row offsets of cone global levels n = base..depth inside the block."""
    base = cone_base_level(s)
    sizes = [d ** (n - s) for n in range(base, depth + 1)]
    return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)


def cone_rows(d: int, depth: int, s: int) -> int:
    return int(cone_offsets(d, depth, s)[-1])


def choose_split(d: int, depth: int, batch_tile: int,
                 vmem_budget: int = 6 * 2**20) -> int:
    """Smallest split level s whose per-cell state fits the VMEM budget."""
    for s in range(0, depth):
        state = (max(0, s - 1) + cone_rows(d, depth, s)) * batch_tile * 4
        # chain temporaries roughly double the top cone level
        state += d ** (depth - s) * batch_tile * 4
        if state <= vmem_budget:
            return s
    return depth - 1


def _kernel(incs_ref, out_ref, *, d: int, depth: int, s: int, M: int):
    n_path = max(0, s - 1)
    base = cone_base_level(s)
    co = cone_offsets(d, depth, s)

    def cone_slice(n):  # rows of global level n (n >= base)
        k = n - base
        return slice(n_path + int(co[k]), n_path + int(co[k + 1]))

    c = pl.program_id(1)
    # letters of the cell's prefix word u (traced scalars, most significant first)
    letters = [(c // d ** (s - 1 - k)) % d for k in range(s)]

    out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    def body(j, _):
        dx = incs_ref[pl.ds(j, 1), :, :][0]  # (d, B)
        B = dx.shape[-1]
        # per-path-step increment components ΔX^{(u_k)}  -> (1, B)
        dxl = [jax.lax.dynamic_slice(dx, (letters[k], 0), (1, B))
               for k in range(s)]

        def path_val(lev):  # old value of ancestor u_{1:lev}, lev in 1..s-1
            return out_ref[lev - 1:lev, :]

        def chain(n):
            """Horner accumulator for target level n (paper Alg. 1):
            acc_j = (S[w_{1:j-1}] + acc_{j-1}) · ΔX^{(i_j)} / (n-j+1)."""
            acc = None
            for jj in range(1, n + 1):
                inv = 1.0 / (n - jj + 1)
                if jj == 1:          # innermost: S[eps] = 1
                    acc = (dxl[0] if s >= 1 else dx) * inv
                elif jj <= s:        # on-path step, width 1
                    acc = (path_val(jj - 1) + acc) * dxl[jj - 1] * inv
                else:                # cone expansion: width d^{jj-1-s} -> d^{jj-s}
                    prev = out_ref[cone_slice(jj - 1), :]
                    t = prev + acc
                    w = t.shape[0]
                    acc = (t[:, None, :] * dx[None, :, :]).reshape(w * d, B) * inv
            return acc

        # top-down over global target levels: reads touch strictly lower levels
        for n in range(depth, base - 1, -1):
            acc = chain(n)
            sl = cone_slice(n)
            out_ref[sl, :] = out_ref[sl, :] + acc
        # ancestor path levels n = s-1 .. 1 (width-1 chains)
        for n in range(min(s - 1, depth), 0, -1):
            acc = dxl[0] * (1.0 / n)
            for jj in range(2, n + 1):
                acc = (path_val(jj - 1) + acc) * dxl[jj - 1] * (1.0 / (n - jj + 1))
            out_ref[n - 1:n, :] = out_ref[n - 1:n, :] + acc
        return 0

    jax.lax.fori_loop(0, M, body, 0)


def _reassemble(out, d, depth, s, B):
    """(n_cells, n_path+cone, B_pad) -> flat (B, D_sig)."""
    n_cells = d**s
    n_path = max(0, s - 1)
    base = cone_base_level(s)
    co = cone_offsets(d, depth, s)
    levels = []
    for lev in range(1, s):  # ancestor levels, gathered from owning cells
        idx = np.arange(d**lev) * d ** (s - lev)
        levels.append(out[idx, lev - 1, :])  # (d^lev, B_pad)
    for n in range(base, depth + 1):  # cone global levels
        k = n - base
        blk = out[:, n_path + int(co[k]):n_path + int(co[k + 1]), :]
        levels.append(blk.reshape(n_cells * d ** (n - s), -1))
    flat = jnp.concatenate(levels, axis=0)  # (D_sig, B_pad)
    return flat[:, :B].T


@functools.partial(jax.jit, static_argnames=("depth", "batch_tile", "split",
                                             "interpret", "vmem_budget"))
def sig_trunc(increments: jax.Array, depth: int, *, batch_tile: int = 128,
              split: int | None = None, interpret: bool = True,
              vmem_budget: int = 6 * 2**20) -> jax.Array:
    """Truncated signature via the Pallas cone kernel.  (B, M, d) -> (B, D_sig)."""
    B, M, d = increments.shape
    if depth < 1:
        raise ValueError("depth must be >= 1")
    s = choose_split(d, depth, batch_tile, vmem_budget) if split is None else split
    if not 0 <= s < depth:
        raise ValueError(f"split {s} outside [0, {depth})")
    n_cells = d**s
    n_path = max(0, s - 1)
    rows = n_path + cone_rows(d, depth, s)

    B_pad = -(-B // batch_tile) * batch_tile
    x = jnp.moveaxis(increments, 0, -1)  # (M, d, B)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, B_pad - B))).astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, d=d, depth=depth, s=s, M=M),
        grid=(B_pad // batch_tile, n_cells),
        in_specs=[pl.BlockSpec((M, d, batch_tile), lambda bi, c: (0, 0, bi))],
        out_specs=pl.BlockSpec((rows, batch_tile), lambda bi, c: (c, bi)),
        out_shape=jax.ShapeDtypeStruct((n_cells * rows, B_pad), jnp.float32),
        interpret=interpret,
    )(x)
    out = out.reshape(n_cells, rows, B_pad)
    return _reassemble(out, d, depth, s, B).astype(increments.dtype)
