"""Pallas TPU kernel: signature coefficients over arbitrary word sets.

Implements the paper's word projections (§3.1-3.2, §7) on TPU.  The requested
set I is prefix-closed and partitioned host-side into prefix-closed tiles
(:func:`repro.core.words.make_tiled_plan`), each of which is updated
independently — the tile-level analogue of the paper's thread-per-``P_w``
CUDA assignment, including the redundant shared-ancestor rows.

TPU twist (DESIGN.md §2): per-row prefix *gathers* (cheap per CUDA thread,
slow/unsupported along TPU sublanes) are recast as one-hot matmuls on the
MXU: ``pfx = P_j @ S`` with ``P_j`` the (rows × rows) prefix-selection
matrix of Horner step j.  FLOPs go up by the tile width; wall-clock goes
down because the MXU is ~50× the VPU and the gather disappears.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.words import TiledPlan, WordPlan, make_tiled_plan


def _tile_tables(plan: WordPlan, W_pad: int, depth_pad: int):
    """One-hot tables for a tile, padded to (depth_pad, W_pad, ...) ."""
    W = plan.closure_size
    P = np.zeros((depth_pad, W_pad, 1 + W_pad), np.float32)
    L = np.zeros((depth_pad, W_pad, plan.d), np.float32)
    inv = np.zeros((depth_pad, W_pad), np.float32)
    emit = np.zeros((depth_pad, W_pad), np.float32)
    for j in range(plan.depth):
        for r in range(W):
            if j < plan.lengths[r]:
                P[j, r, plan.prefix_idx[r, j]] = 1.0
                L[j, r, plan.letters[r, j]] = 1.0
                inv[j, r] = plan.inv[r, j]
                emit[j, r] = plan.emit[r, j]
    return P, L, inv, emit


def tile_footprint(W_pad: int, depth: int, d: int, batch_tile: int,
                   itemsize: int = 4) -> int:
    """Per-tile VMEM bytes: the (1+W, B) closure state plus the one-hot
    tables.  ``itemsize`` is the element byte width of the state dtype (4
    for fp32, 2 for bf16) — the table bytes follow the same width so mixed-
    precision budgeting stays correct (mirrors sig_trunc.state_footprint)."""
    state = (1 + W_pad) * batch_tile * itemsize
    tables = depth * W_pad * (1 + W_pad + d + 2) * itemsize
    return state + tables


def _kernel(incs_ref, p_ref, l_ref, inv_ref, emit_ref, *refs,
            M: int, depth: int, stream_stride: int = 0,
            fuse_ll: bool = False, fuse_time: bool = False):
    """Tile update loop.  Non-streamed: ``out_ref`` IS the running closure
    buffer.  Streamed (``stream_stride >= 1``): the buffer lives in the
    trailing VMEM scratch ref and strided snapshots are stored into
    ``out_ref`` (one (1+W, B) slab per emitted step).

    Fused transforms (``fuse_ll`` / ``fuse_time``): the input block holds
    RAW increments (M, d_raw, B); each augmented increment ([t?, lag, lead]
    channels, matching ``core.transforms``) is built in VMEM per sub-step —
    the tables are over the AUGMENTED alphabet and emission is strided over
    the augmented step axis.  ``fuse_time`` reads a (2, B) aux ref
    ``[dt; n_valid_aug]``."""
    refs = list(refs)
    taux_ref = refs.pop(0) if fuse_time else None
    out_ref = refs.pop(0)
    scratch = refs
    stream = bool(scratch)
    state_ref = scratch[0] if stream else out_ref
    W1 = state_ref.shape[0]  # 1 + W_pad
    B = state_ref.shape[1]
    sub = 2 if fuse_ll else 1
    M_aug = M * sub
    init = jnp.zeros((W1, B), state_ref.dtype).at[0, :].set(1.0)  # S[eps] = 1
    state_ref[...] = init

    def body(j, _):
        g = incs_ref[pl.ds(j, 1), :, :][0].astype(state_ref.dtype)  # (d_raw, B)
        for p in range(sub):
            ja = sub * j + p  # augmented step index
            if fuse_ll or fuse_time:
                parts = ([jnp.zeros_like(g), g] if p == 0 else
                         [g, jnp.zeros_like(g)]) if fuse_ll else [g]
                if fuse_time:
                    trow = taux_ref[0:1, :] * (
                        ja < taux_ref[1:2, :]).astype(state_ref.dtype)
                    parts = [trow] + parts
                dx = jnp.concatenate(parts, axis=0)  # (d_aug, B) in VMEM
            else:
                dx = g
            S = state_ref[...]                      # (1+W, B), old values
            acc = jnp.zeros((W1 - 1, B), S.dtype)
            h = acc
            for jj in range(depth):                 # Horner steps (Alg. 1)
                pfx = jnp.dot(p_ref[0, jj], S,      # one-hot gather on MXU
                              preferred_element_type=S.dtype)
                dxl = jnp.dot(l_ref[0, jj], dx, preferred_element_type=S.dtype)
                acc = (pfx + acc) * dxl * inv_ref[0, jj][:, None]
                h = h + acc * emit_ref[0, jj][:, None]
            state_ref[1:, :] = S[1:, :] + h
            if stream:
                q = ja // stream_stride

                @pl.when((((ja + 1) % stream_stride) == 0) | (ja == M_aug - 1))
                def _emit():
                    # bf16 emission buffer under bf16_fp32: round on store,
                    # the fp32 accumulator state is untouched
                    pl.store(out_ref, (pl.ds(q, 1), slice(None), slice(None)),
                             state_ref[...].astype(out_ref.dtype)[None])
        return 0

    jax.lax.fori_loop(0, M, body, 0)


@functools.partial(jax.jit, static_argnames=("tplan", "batch_tile", "interpret",
                                             "stream", "stream_stride",
                                             "transform", "precision"))
def sig_words(increments: jax.Array, tplan: TiledPlan, *,
              batch_tile: int = 128, interpret: bool = True,
              stream: bool = False, stream_stride: int = 1, transform=None,
              taux=None, precision: str = "fp32") -> jax.Array:
    """Projected signature via the Pallas tile kernel.

    increments: (B, M, d)  ->  (B, |I|) coefficients in tplan.words order.
    ``stream=True`` emits every ``stream_stride``-th prefix state (terminal
    step always included): (B, M, d) -> (B, M_out, |I|).

    ``transform`` (a basepoint-free :class:`repro.core.transforms.Transform`)
    fuses lead_lag / time_augment into the time loop: ``increments`` stay raw
    (B, M, d_raw) while ``tplan`` is over the AUGMENTED alphabet
    (``tplan.d == transform_dim(transform, d_raw)``); ``taux`` is the (B, 2)
    ``transform_time_aux`` array, required iff the transform has a time
    channel.  ``precision="bf16_fp32"`` stores the increments block in bf16
    with fp32 accumulation.
    """
    from repro import obs
    from repro.kernels.sig_trunc import _fuse_flags, _storage_dtype
    obs.count_trace("sig_words", increments, tiles=len(tplan.tiles),
                    batch_tile=batch_tile, stream=stream,
                    precision=precision)
    B, M, d_raw = increments.shape
    fuse_ll, fuse_time = _fuse_flags(transform)
    if fuse_time and taux is None:
        raise ValueError("transform with a time channel needs taux= "
                         "(see repro.core.transforms.transform_time_aux)")
    sub = 2 if fuse_ll else 1
    d = (2 * d_raw if fuse_ll else d_raw) + (1 if fuse_time else 0)
    M_aug = M * sub
    assert d == tplan.d, (d, tplan.d)
    if stream_stride < 1:
        raise ValueError(f"stream_stride must be >= 1, got {stream_stride}")
    tiles = tplan.tiles
    T = len(tiles)
    W_pad = max(8, -(-max(p.closure_size for p in tiles) // 8) * 8)
    depth = max(p.depth for p in tiles)

    Ps, Ls, invs, emits = [], [], [], []
    for p in tiles:
        P, L, inv, emit = _tile_tables(p, W_pad, depth)
        Ps.append(P); Ls.append(L); invs.append(inv); emits.append(emit)
    Pt = jnp.asarray(np.stack(Ps))      # (T, depth, W, 1+W)
    Lt = jnp.asarray(np.stack(Ls))      # (T, depth, W, d)
    invt = jnp.asarray(np.stack(invs))  # (T, depth, W)
    emitt = jnp.asarray(np.stack(emits))

    B_pad = -(-B // batch_tile) * batch_tile
    x = jnp.moveaxis(increments, 0, -1)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, B_pad - B))).astype(
        _storage_dtype(precision))

    in_specs = [
        pl.BlockSpec((M, d_raw, batch_tile), lambda bi, t: (0, 0, bi)),
        pl.BlockSpec((1, depth, W_pad, 1 + W_pad), lambda bi, t: (t, 0, 0, 0)),
        pl.BlockSpec((1, depth, W_pad, d), lambda bi, t: (t, 0, 0, 0)),
        pl.BlockSpec((1, depth, W_pad), lambda bi, t: (t, 0, 0)),
        pl.BlockSpec((1, depth, W_pad), lambda bi, t: (t, 0, 0)),
    ]
    inputs = [x, Pt, Lt, invt, emitt]
    if fuse_time:
        ta = jnp.pad(jnp.asarray(taux, jnp.float32).T,
                     ((0, 0), (0, B_pad - B)))  # (2, B_pad)
        inputs.append(ta)
        in_specs.append(pl.BlockSpec((2, batch_tile), lambda bi, t: (0, bi)))
    kern = functools.partial(_kernel, M=M, depth=depth,
                             fuse_ll=fuse_ll, fuse_time=fuse_time)
    tile_idx = jnp.asarray([t for t, _ in tplan.gather], dtype=jnp.int32)
    row_idx = jnp.asarray(
        [tiles[t].out_rows[k] for t, k in tplan.gather], dtype=jnp.int32)

    if not stream:
        out = pl.pallas_call(
            kern,
            grid=(B_pad // batch_tile, T),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1 + W_pad, batch_tile),
                                   lambda bi, t: (t, bi)),
            out_shape=jax.ShapeDtypeStruct((T * (1 + W_pad), B_pad),
                                           jnp.float32),
            interpret=interpret,
        )(*inputs)
        out = out.reshape(T, 1 + W_pad, B_pad)
        vals = out[tile_idx, row_idx, :B]   # (n_words, B)
        return vals.T.astype(increments.dtype)

    M_out = -(-M_aug // stream_stride)
    out = pl.pallas_call(
        functools.partial(kern, stream_stride=stream_stride),
        grid=(B_pad // batch_tile, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((M_out, 1 + W_pad, batch_tile),
                               lambda bi, t: (0, t, bi)),
        # bf16_fp32: streamed emission buffer at the storage dtype, fp32
        # accumulator scratch (same discipline as sig_trunc's stream cell)
        out_shape=jax.ShapeDtypeStruct((M_out, T * (1 + W_pad), B_pad),
                                       _storage_dtype(precision)),
        scratch_shapes=[pltpu.VMEM((1 + W_pad, batch_tile), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    out = out.reshape(M_out, T, 1 + W_pad, B_pad)
    vals = out[:, tile_idx, row_idx, :B]    # (M_out, n_words, B)
    return jnp.moveaxis(vals, -1, 0).astype(increments.dtype)
