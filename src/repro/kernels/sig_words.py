"""Pallas TPU kernel: signature coefficients over arbitrary word sets.

Implements the paper's word projections (§3.1-3.2, §7) on TPU.  The requested
set I is prefix-closed and partitioned host-side into prefix-closed tiles
(:func:`repro.core.words.make_tiled_plan`), each of which is updated
independently — the tile-level analogue of the paper's thread-per-``P_w``
CUDA assignment, including the redundant shared-ancestor rows.

TPU twist (DESIGN.md §2): per-row prefix *gathers* (cheap per CUDA thread,
slow/unsupported along TPU sublanes) are recast as one-hot matmuls on the
MXU: ``pfx = P_j @ S`` with ``P_j`` the (rows × rows) prefix-selection
matrix of Horner step j.  FLOPs go up by the tile width; wall-clock goes
down because the MXU is ~50× the VPU and the gather disappears.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.words import TiledPlan, WordPlan, make_tiled_plan


def _tile_tables(plan: WordPlan, W_pad: int, depth_pad: int):
    """One-hot tables for a tile, padded to (depth_pad, W_pad, ...) ."""
    W = plan.closure_size
    P = np.zeros((depth_pad, W_pad, 1 + W_pad), np.float32)
    L = np.zeros((depth_pad, W_pad, plan.d), np.float32)
    inv = np.zeros((depth_pad, W_pad), np.float32)
    emit = np.zeros((depth_pad, W_pad), np.float32)
    for j in range(plan.depth):
        for r in range(W):
            if j < plan.lengths[r]:
                P[j, r, plan.prefix_idx[r, j]] = 1.0
                L[j, r, plan.letters[r, j]] = 1.0
                inv[j, r] = plan.inv[r, j]
                emit[j, r] = plan.emit[r, j]
    return P, L, inv, emit


def _kernel(incs_ref, p_ref, l_ref, inv_ref, emit_ref, out_ref, *scratch,
            M: int, depth: int, stream_stride: int = 0):
    """Tile update loop.  Non-streamed: ``out_ref`` IS the running closure
    buffer.  Streamed (``stream_stride >= 1``): the buffer lives in the
    trailing VMEM scratch ref and strided snapshots are stored into
    ``out_ref`` (one (1+W, B) slab per emitted step)."""
    stream = bool(scratch)
    state_ref = scratch[0] if stream else out_ref
    W1 = state_ref.shape[0]  # 1 + W_pad
    B = state_ref.shape[1]
    init = jnp.zeros((W1, B), state_ref.dtype).at[0, :].set(1.0)  # S[eps] = 1
    state_ref[...] = init

    def body(j, _):
        dx = incs_ref[pl.ds(j, 1), :, :][0]        # (d, B)
        S = state_ref[...]                          # (1+W, B), old values
        acc = jnp.zeros((W1 - 1, B), S.dtype)
        h = acc
        for jj in range(depth):                     # Horner steps (Alg. 1)
            pfx = jnp.dot(p_ref[0, jj], S,          # one-hot gather on MXU
                          preferred_element_type=S.dtype)
            dxl = jnp.dot(l_ref[0, jj], dx, preferred_element_type=S.dtype)
            acc = (pfx + acc) * dxl * inv_ref[0, jj][:, None]
            h = h + acc * emit_ref[0, jj][:, None]
        state_ref[1:, :] = S[1:, :] + h
        if stream:
            q = j // stream_stride

            @pl.when((((j + 1) % stream_stride) == 0) | (j == M - 1))
            def _emit():
                pl.store(out_ref, (pl.ds(q, 1), slice(None), slice(None)),
                         state_ref[...][None])
        return 0

    jax.lax.fori_loop(0, M, body, 0)


@functools.partial(jax.jit, static_argnames=("tplan", "batch_tile", "interpret",
                                             "stream", "stream_stride"))
def sig_words(increments: jax.Array, tplan: TiledPlan, *,
              batch_tile: int = 128, interpret: bool = True,
              stream: bool = False, stream_stride: int = 1) -> jax.Array:
    """Projected signature via the Pallas tile kernel.

    increments: (B, M, d)  ->  (B, |I|) coefficients in tplan.words order.
    ``stream=True`` emits every ``stream_stride``-th prefix state (terminal
    step always included): (B, M, d) -> (B, M_out, |I|).
    """
    B, M, d = increments.shape
    assert d == tplan.d
    if stream_stride < 1:
        raise ValueError(f"stream_stride must be >= 1, got {stream_stride}")
    tiles = tplan.tiles
    T = len(tiles)
    W_pad = max(8, -(-max(p.closure_size for p in tiles) // 8) * 8)
    depth = max(p.depth for p in tiles)

    Ps, Ls, invs, emits = [], [], [], []
    for p in tiles:
        P, L, inv, emit = _tile_tables(p, W_pad, depth)
        Ps.append(P); Ls.append(L); invs.append(inv); emits.append(emit)
    Pt = jnp.asarray(np.stack(Ps))      # (T, depth, W, 1+W)
    Lt = jnp.asarray(np.stack(Ls))      # (T, depth, W, d)
    invt = jnp.asarray(np.stack(invs))  # (T, depth, W)
    emitt = jnp.asarray(np.stack(emits))

    B_pad = -(-B // batch_tile) * batch_tile
    x = jnp.moveaxis(increments, 0, -1)
    x = jnp.pad(x, ((0, 0), (0, 0), (0, B_pad - B))).astype(jnp.float32)

    in_specs = [
        pl.BlockSpec((M, d, batch_tile), lambda bi, t: (0, 0, bi)),
        pl.BlockSpec((1, depth, W_pad, 1 + W_pad), lambda bi, t: (t, 0, 0, 0)),
        pl.BlockSpec((1, depth, W_pad, d), lambda bi, t: (t, 0, 0, 0)),
        pl.BlockSpec((1, depth, W_pad), lambda bi, t: (t, 0, 0)),
        pl.BlockSpec((1, depth, W_pad), lambda bi, t: (t, 0, 0)),
    ]
    tile_idx = jnp.asarray([t for t, _ in tplan.gather], dtype=jnp.int32)
    row_idx = jnp.asarray(
        [tiles[t].out_rows[k] for t, k in tplan.gather], dtype=jnp.int32)

    if not stream:
        out = pl.pallas_call(
            functools.partial(_kernel, M=M, depth=depth),
            grid=(B_pad // batch_tile, T),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1 + W_pad, batch_tile),
                                   lambda bi, t: (t, bi)),
            out_shape=jax.ShapeDtypeStruct((T * (1 + W_pad), B_pad),
                                           jnp.float32),
            interpret=interpret,
        )(x, Pt, Lt, invt, emitt)
        out = out.reshape(T, 1 + W_pad, B_pad)
        vals = out[tile_idx, row_idx, :B]   # (n_words, B)
        return vals.T.astype(increments.dtype)

    M_out = -(-M // stream_stride)
    out = pl.pallas_call(
        functools.partial(_kernel, M=M, depth=depth,
                          stream_stride=stream_stride),
        grid=(B_pad // batch_tile, T),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((M_out, 1 + W_pad, batch_tile),
                               lambda bi, t: (0, t, bi)),
        out_shape=jax.ShapeDtypeStruct((M_out, T * (1 + W_pad), B_pad),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((1 + W_pad, batch_tile), jnp.float32)],
        interpret=interpret,
    )(x, Pt, Lt, invt, emitt)
    out = out.reshape(M_out, T, 1 + W_pad, B_pad)
    vals = out[:, tile_idx, row_idx, :B]    # (M_out, n_words, B)
    return jnp.moveaxis(vals, -1, 0).astype(increments.dtype)
