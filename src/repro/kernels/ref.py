"""Pure-jnp oracles for the Pallas kernels.

These re-export the naive levelwise Chen engine (materialised tensor
exponentials, paper eq. (2)) and the word-table reference scan.  Every kernel
test asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tensor_ops as tops
from repro.core.projection import _scan_projected
from repro.core.words import WordPlan, make_plan


def sig_trunc_ref(increments: jax.Array, depth: int) -> jax.Array:
    """(B, M, d) -> (B, D_sig): naive exp/Chen oracle."""
    return tops.signature_exp_chen(increments, depth)


def sig_words_ref(increments: jax.Array, words, d: int | None = None,
                  plan: WordPlan | None = None) -> jax.Array:
    """(B, M, d) -> (B, |I|): word-table scan oracle (no kernel, no tiles)."""
    if plan is None:
        plan = make_plan(tuple(tuple(w) for w in words), d or increments.shape[-1])
    return _scan_projected(increments, plan, stream=False)
