"""``RaggedPaths``: a batch of variable-length paths as padding + lengths.

The whole ragged subsystem rests on one algebraic fact: a zero increment is
the identity Chen update, so a batch padded with *constant tails* (every
point past an example's true end frozen at its terminal value) has exactly
the per-example signatures — no kernel rewrite, no per-length compile.  This
container is the canonical spelling of that contract:

- ``values``   — (B, M_max+1, d) padded path points.  Constructors freeze
  the tail (repeat the last true point) so even length-oblivious consumers
  see zero increments past the end; the signature entry points additionally
  zero-mask by ``lengths``, so arbitrary tail garbage is also safe.
- ``lengths``  — (B,) int32 true increment counts (example b has
  ``lengths[b] + 1`` meaningful points).

``RaggedPaths`` is a registered pytree (both fields are data), so it passes
through ``jit``/``grad``/``vmap`` boundaries, and every signature entry
point (``repro.core.signature``, ``projected_signature``,
``repro.sigkernel.sig_gram`` / ``sig_mmd``) accepts it directly in place of
a plain path array.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.signature import as_lengths, length_mask, mask_increments
from repro.core import tensor_ops as tops


@dataclasses.dataclass(frozen=True)
class RaggedPaths:
    """Padded variable-length path batch (see module docstring).

    Build with :meth:`from_list` / :meth:`from_segments` / :meth:`from_dense`
    rather than the raw constructor unless the tail is already frozen.
    """
    values: jax.Array    # (B, M_max+1, d) padded points
    lengths: jax.Array   # (B,) int32 increments per example

    # -- construction ------------------------------------------------------

    @classmethod
    def from_list(cls, paths: Sequence, pad_to: int | None = None,
                  dtype=jnp.float32) -> "RaggedPaths":
        """From a list of (M_i+1, d) arrays; pads to max(M_i) (or ``pad_to``
        increments) with frozen tails."""
        if not len(paths):
            raise ValueError("RaggedPaths.from_list needs >= 1 path")
        arrs = [np.asarray(p) for p in paths]
        d = arrs[0].shape[-1]
        for a in arrs:
            if a.ndim != 2 or a.shape[-1] != d:
                raise ValueError(f"every path must be (M_i+1, {d}); got "
                                 f"{[tuple(a.shape) for a in arrs]}")
            if a.shape[0] < 1:
                raise ValueError("every path needs >= 1 point")
        lengths = np.asarray([a.shape[0] - 1 for a in arrs], np.int32)
        M = int(lengths.max()) if pad_to is None else int(pad_to)
        if M < lengths.max():
            raise ValueError(f"pad_to={M} < longest path ({lengths.max()} "
                             "increments)")
        out = np.empty((len(arrs), M + 1, d), np.dtype(dtype))
        for i, a in enumerate(arrs):
            out[i, :a.shape[0]] = a
            out[i, a.shape[0]:] = a[-1]          # frozen tail
        return cls(jnp.asarray(out), jnp.asarray(lengths))

    @classmethod
    def from_segments(cls, flat: jax.Array, segment_points: Sequence[int],
                      pad_to: int | None = None,
                      dtype=jnp.float32) -> "RaggedPaths":
        """From a flat (Σ(M_i+1), d) concatenation and per-path point counts
        (the CSR-style spelling used by request queues)."""
        flat = np.asarray(flat)
        pts = np.asarray(segment_points, np.int64)
        if pts.sum() != flat.shape[0]:
            raise ValueError(f"segment points sum to {pts.sum()} but flat "
                             f"has {flat.shape[0]} rows")
        splits = np.cumsum(pts)[:-1]
        return cls.from_list(np.split(flat, splits), pad_to=pad_to,
                             dtype=dtype)

    @classmethod
    def from_dense(cls, values: jax.Array, lengths) -> "RaggedPaths":
        """From an already-padded (B, M+1, d) batch + lengths.  The tail is
        NOT rewritten (signature entry points mask it anyway); use this when
        ``values`` stays on device."""
        values = jnp.asarray(values)
        if values.ndim != 3:
            raise ValueError(f"values must be (B, M+1, d), got {values.shape}")
        return cls(values, as_lengths(lengths, values.shape[0]))

    # -- views -------------------------------------------------------------

    @property
    def batch(self) -> int:
        return self.values.shape[0]

    @property
    def max_len(self) -> int:
        """Padded increment count M_max."""
        return self.values.shape[1] - 1

    @property
    def d(self) -> int:
        return self.values.shape[-1]

    def increments(self) -> jax.Array:
        """(B, M_max, d) increments with the padded tail zero-masked."""
        return mask_increments(tops.path_increments(self.values),
                               self.lengths)

    def point_mask(self) -> jax.Array:
        """(B, M_max+1) bool: True at meaningful points (k <= lengths)."""
        return length_mask(self.lengths + 1, self.values.shape[1])

    def terminal_points(self) -> jax.Array:
        """(B, d) each example's true endpoint X_{L_b}."""
        idx = self.lengths.astype(jnp.int32)[:, None, None]
        return jnp.take_along_axis(self.values, idx, axis=1)[:, 0]

    def pad_to(self, M: int) -> "RaggedPaths":
        """Re-pad to M increments (frozen tail); same lengths."""
        if M < self.max_len:
            raise ValueError(f"pad_to({M}) below current padding "
                             f"{self.max_len}")
        if M == self.max_len:
            return self
        tail = jnp.repeat(self.values[:, -1:], M - self.max_len, axis=1)
        return RaggedPaths(jnp.concatenate([self.values, tail], axis=1),
                           self.lengths)

    def take(self, idx) -> "RaggedPaths":
        """Row-gather (host or device indices)."""
        idx = jnp.asarray(idx)
        return RaggedPaths(jnp.take(self.values, idx, axis=0),
                           jnp.take(self.lengths, idx, axis=0))

    def __len__(self) -> int:
        return self.batch


jax.tree_util.register_dataclass(
    RaggedPaths, data_fields=("values", "lengths"), meta_fields=())
