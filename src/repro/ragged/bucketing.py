"""Length bucketing: a compile-cache-friendly ladder of padded shapes.

JAX compiles one executable per input shape, so serving raw ragged traffic
either pays one compile per distinct length (per-request serving) or pads
everything to the global maximum (wasted scan steps).  A *bucket ladder*
caps both: lengths are rounded up to a geometric ladder
``min_len, min_len·g, min_len·g², ..., >= max_len``, so the number of
compiled shapes is O(log(max_len / min_len)) while padding waste is bounded
by the growth factor ``g``.  All of this is host-side numpy — bucket
membership must be static to pick a compiled executable.
"""
from __future__ import annotations

import numpy as np

from .paths import RaggedPaths


def bucket_ladder(max_len: int, min_len: int = 16,
                  growth: float = 2.0) -> np.ndarray:
    """Increasing increment-count rungs covering [1, max_len].

    Every rung is the padded length of one compiled shape; the last rung is
    always >= ``max_len``.  ``growth`` bounds padding waste (a request is
    padded by at most that factor).
    """
    if max_len < 1:
        raise ValueError(f"max_len must be >= 1, got {max_len}")
    if min_len < 1:
        raise ValueError(f"min_len must be >= 1, got {min_len}")
    if growth <= 1.0:
        raise ValueError(f"growth must be > 1, got {growth}")
    rungs = [min(min_len, max_len)]
    while rungs[-1] < max_len:
        rungs.append(min(max(int(np.ceil(rungs[-1] * growth)),
                             rungs[-1] + 1), max_len))
    return np.asarray(rungs, np.int64)


def assign_buckets(lengths, ladder: np.ndarray) -> np.ndarray:
    """(N,) lengths -> (N,) index of the smallest rung >= length (host)."""
    lengths = np.asarray(lengths, np.int64)
    ladder = np.asarray(ladder, np.int64)
    if lengths.size and lengths.max() > ladder[-1]:
        raise ValueError(f"length {lengths.max()} exceeds the ladder's top "
                         f"rung {ladder[-1]}")
    if lengths.size and lengths.min() < 0:
        raise ValueError("lengths must be >= 0")
    return np.searchsorted(ladder, lengths, side="left").astype(np.int64)


def bucket_paths(rp: RaggedPaths, ladder=None, min_len: int = 16,
                 growth: float = 2.0) -> list[tuple[np.ndarray, RaggedPaths]]:
    """Split a ragged batch into per-rung sub-batches.

    Returns ``[(orig_indices, sub_batch), ...]`` where each sub-batch is
    padded to its rung's increment count — the bounded set of shapes the
    engine will compile.  ``lengths`` must be host-readable (concrete).
    """
    lengths = np.asarray(rp.lengths)
    if ladder is None:
        ladder = bucket_ladder(max(int(lengths.max()), 1), min_len=min_len,
                               growth=growth)
    ladder = np.asarray(ladder, np.int64)
    which = assign_buckets(lengths, ladder)
    out = []
    for k in range(len(ladder)):
        idx = np.nonzero(which == k)[0]
        if idx.size == 0:
            continue
        sub = rp.take(idx)
        rung = int(ladder[k])
        sub = RaggedPaths(sub.values[:, :rung + 1], sub.lengths)
        out.append((idx, sub.pad_to(rung)))
    return out


def pad_batch(rp: RaggedPaths, target_batch: int) -> RaggedPaths:
    """Pad the batch axis with zero-length dummy rows (results for the
    padded rows are dropped by the caller) so the batch dimension also
    draws from a bounded shape set."""
    B = rp.batch
    if target_batch < B:
        raise ValueError(f"target batch {target_batch} < current {B}")
    if target_batch == B:
        return rp
    import jax.numpy as jnp
    pad = target_batch - B
    values = jnp.concatenate(
        [rp.values, jnp.zeros((pad, *rp.values.shape[1:]),
                              rp.values.dtype)], axis=0)
    lengths = jnp.concatenate(
        [rp.lengths, jnp.zeros((pad,), rp.lengths.dtype)], axis=0)
    return RaggedPaths(values, lengths)


def batch_rung(n: int, max_batch: int) -> int:
    """Round a micro-batch size up the power-of-two ladder (capped)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    return min(int(2 ** np.ceil(np.log2(n))), max_batch)
