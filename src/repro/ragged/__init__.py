"""repro.ragged: variable-length path batches as a first-class axis.

Padding + ``lengths`` is the whole representation: zero increments are
identity Chen updates, so a zero-masked padded batch has *exactly* the
per-example signatures on every engine — see :mod:`repro.ragged.paths`.
The ``lengths=`` argument this package feeds is accepted across the stack
(``repro.core.signature`` / ``projected_signature`` / ``windowed_*``,
``repro.kernels.ops``, ``repro.sigkernel``), and
:class:`repro.serve.DynamicBatcher` turns the length-bucketing here into a
micro-batched serving layer with a bounded set of compiled shapes.
"""
from repro.core.signature import (as_lengths, length_mask, mask_increments,
                                  ragged_terminal, stream_emit_mask,
                                  stream_emit_slots)
from .paths import RaggedPaths
from .bucketing import (assign_buckets, batch_rung, bucket_ladder,
                        bucket_paths, pad_batch)

__all__ = [
    "RaggedPaths", "as_lengths", "length_mask", "mask_increments",
    "ragged_terminal", "stream_emit_mask", "stream_emit_slots",
    "assign_buckets", "batch_rung", "bucket_ladder", "bucket_paths",
    "pad_batch",
]
