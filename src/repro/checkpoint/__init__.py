from .checkpointer import Checkpointer, latest_step
