"""Sharded, async, elastic checkpointing (tensorstore-free).

Layout: <dir>/step_<n>/
    manifest.json           tree structure, shapes, dtypes, data-pipeline state
    shard_<p>.npz           per-process arrays (process-local shards)

Features needed at pod scale, implemented and unit-tested on CPU:
- async save: the host copy is snapshotted synchronously (cheap), the write
  happens on a background thread so the train loop is never blocked on disk;
- atomicity: writes go to step_<n>.tmp, renamed only after fsync — a
  preempted save can never corrupt the latest good checkpoint;
- elasticity / reshard-on-restore: arrays are saved unsharded per process
  (single-process case: full arrays) and re-laid-out on load against any
  mesh, so restarts may change topology (e.g. 512 -> 256 chips);
- garbage collection: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, ckpt_dir: str, *, keep: int = 3, async_save: bool = True,
                 process_index: int = 0, process_count: int = 1):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self.process_index = process_index
        self.process_count = process_count
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, params, opt_state, step: int, extra: dict | None = None):
        """Snapshot to host memory now; write to disk (possibly async)."""
        self.wait()  # one outstanding async save at a time
        tree = {"params": params, "opt_state": opt_state}
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        try:
            # informational only (restore flattens against params_like);
            # proto serialization rejects custom pytree nodes
            treedef_hex = treedef.serialize_using_proto().hex()
        except (AttributeError, ValueError):
            treedef_hex = None
        manifest = {
            "step": step,
            "treedef": treedef_hex,
            "n_leaves": len(host),
            "shapes": [list(x.shape) for x in host],
            "dtypes": [str(x.dtype) for x in host],
            "extra": extra or {},
            "process_count": self.process_count,
        }

        def write():
            final = os.path.join(self.dir, f"step_{step}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.process_index}.npz"),
                     **{f"a{i}": x for i, x in enumerate(host)})
            if self.process_index == 0:
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def peek_extra(self, step: int | None = None) -> dict:
        """The manifest's ``extra`` dict without touching the arrays.

        Restoring a stateful subsystem (e.g. a session pool) is a two-phase
        read: the extra block carries the host-side metadata needed to build
        the ``params_like`` template whose shapes :meth:`restore` validates.
        """
        self.wait()
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f).get("extra", {})

    def restore(self, params_like, opt_state_like, step: int | None = None,
                shardings=None):
        """Restore into the given tree structure; arrays are re-laid-out
        against ``shardings`` (elastic restore) if provided."""
        self.wait()
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"shard_{self.process_index}.npz"))
        host = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
        tree = {"params": params_like, "opt_state": opt_state_like}
        leaves, treedef = _flatten(tree)
        assert len(leaves) == len(host), "checkpoint/tree mismatch"
        for got, want in zip(host, leaves):
            assert tuple(got.shape) == tuple(want.shape), \
                (got.shape, want.shape)
        if shardings is not None:
            s_leaves = treedef.flatten_up_to(shardings)
            host = [jax.device_put(h.astype(w.dtype), s)
                    for h, w, s in zip(host, leaves, s_leaves)]
        else:
            host = [jax.numpy.asarray(h.astype(w.dtype))
                    for h, w in zip(host, leaves)]
        out = jax.tree_util.tree_unflatten(treedef, host)
        return out["params"], out["opt_state"], manifest.get("extra", {})
