"""repro: JAX/Pallas reproduction and scale-out framework for pathsig
(truncated & projected path signatures)."""
__version__ = "0.1.0"
