"""Training step + loop: mixed precision, microbatch accumulation, remat,
gradient clipping/compression hooks, checkpoint/restart, straggler-aware
step timing.

Data parallelism: run :func:`train_loop` inside ``sharding_ctx(mesh)``
(:mod:`repro.distributed.ctx`) and it goes SPMD — parameters and optimizer
state are replicated over the mesh, every batch is placed with
:func:`repro.distributed.sharding.batch_specs` (the "batch" logical axis
split over the mesh's data axes), and the gradient mean over the axis is
XLA's all-reduce (the loss means over the global batch, so GSPMD inserts
exactly one psum per step).  The signature heads / sig-MMD loss inside the
step ride the same context through the engine dispatch's ``shard_map`` path
(:mod:`repro.kernels.ops`), so hidden-path signatures are computed on the
shard that owns each example.  Outside any context nothing changes.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
import time
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro import obs
from repro.distributed.ctx import current_mesh, current_rules
from repro.models.config import ModelConfig
from repro.optim import Optimizer, global_norm


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = only at exit
    ckpt_dir: str = ""
    microbatch: int = 0                 # 0 = no accumulation
    remat: str = "dots"
    grad_compression: bool = False      # int8 EF over cross-pod axis
    straggler_deadline_s: float = 0.0   # 0 = disabled; see train_loop
    sig_backend: str = ""               # "" = honour cfg.sig_head.backend;
    sig_backward: str = ""              # else override the engine dispatch
    loss: str = "lm"                    # "lm" | "sig_mmd" (distribution match)
    run_dir: str = "runs"               # default JSONL run-log dir ("" = no
    run_name: str = ""                  # default sink); "" names by time
    # SLO enforcement (repro.obs.slo): active when slos or slo_callback is
    # set.  Objectives are evaluated over the trailing slo_window steps at
    # the slo_every cadence (0 = log_every); slos=() uses obs.train_slos().
    # The callback (if any) gets (step, report) at every evaluation; on a
    # breached report a "warn" action warns, while slo_action="abort" — or
    # the callback returning "abort" — raises SloBreach.
    slos: tuple = ()
    slo_every: int = 0
    slo_window: int = 64
    slo_action: str = "warn"            # "warn" | "abort"
    slo_callback: Optional[Callable[[int, dict], Any]] = None


def _apply_sig_overrides(cfg: ModelConfig, sig_backend: str,
                         sig_backward: str) -> ModelConfig:
    """Override the sig head's engine-dispatch routing (repro.kernels.ops)
    so a launch config can pin the trained path to a specific backend."""
    if cfg.sig_head is None or not (sig_backend or sig_backward):
        return cfg
    sc = cfg.sig_head
    if sig_backend:
        sc = dataclasses.replace(sc, backend=sig_backend)
    if sig_backward:
        sc = dataclasses.replace(sc, backward=sig_backward)
    return dataclasses.replace(cfg, sig_head=sc)


def make_sig_mmd_loss(cfg: ModelConfig):
    """Distribution-matching loss (``TrainLoopConfig.loss="sig_mmd"``):
    the unbiased signature-MMD² between the model's learned hidden-state
    paths and reference paths supplied in ``batch["paths"]``.

    The generated sample is the backbone's hidden trajectory projected to
    ``cfg.sig_head.channels`` dims (through ``params["sig_head"]["proj"]``
    when present, else the leading channels) and normalised exactly like
    :func:`repro.models.sig_head._learned_path`; the reference sample is
    ``batch["paths"]`` (B_ref, S'+1, channels).  Differentiable end to end —
    signature legs on the configured backend carry the §4.2 inverse VJP, so
    the trainer's O(B·D_sig) memory law holds for kernel losses too.

    Ragged batches: ``batch["mask"]`` (B, S right-padded attention mask)
    truncates each generated trajectory at its true end, and
    ``batch["path_lengths"]`` (B_ref,) marks the reference paths as ragged
    (the spelling :class:`repro.data.RaggedPathStream` emits) — both sides
    then compare TRUE variable-length paths with zero gradient past the end.
    """
    sc = cfg.sig_head
    if sc is None:
        raise ValueError("loss='sig_mmd' needs cfg.sig_head (depth/channels/"
                         "backend of the matched path distribution)")
    if cfg.family == "encdec":
        raise ValueError("loss='sig_mmd' matches decoder-style hidden "
                         "trajectories (decoder/rwkv/hybrid families); the "
                         "encdec family has no single backbone trajectory")
    from repro.models import transformer as T
    from repro.models.sig_head import _learned_path
    from repro.sigkernel import sig_mmd

    def loss_fn(params, batch, remat):
        hidden, aux = T.backbone(params, cfg, tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"),
                                 positions=batch.get("positions"),
                                 remat=remat)
        mask = batch.get("mask")
        lengths = None
        hp = params.get("sig_head")
        if hp is not None and "proj" in hp:
            if mask is None:
                path = _learned_path(hp, hidden, sc)
            else:
                path, lengths = _learned_path(hp, hidden, sc, mask)
        else:
            path = hidden[..., :sc.channels].astype(jnp.float32)
            if sc.stride > 1:
                path = path[:, ::sc.stride]
            if mask is None:
                path = path / jnp.sqrt(jnp.float32(path.shape[1]))
            else:
                from repro.models.sig_head import mask_path_lengths
                lengths, norm = mask_path_lengths(mask, sc.stride)
                path = path / norm[:, None, None]
        mmd = sig_mmd(path, batch["paths"].astype(jnp.float32), sc.depth,
                      backend=sc.backend, backward=sc.backward,
                      x_lengths=lengths,
                      y_lengths=batch.get("path_lengths"))
        loss = mmd + aux
        return loss, {"loss": loss, "sig_mmd": mmd, "aux": aux}

    return loss_fn


def replicate_tree(tree, mesh):
    """Place every leaf replicated over the mesh (params / optimizer state
    in data-parallel training — ZeRO sharding is the launcher's job)."""
    from jax.sharding import NamedSharding, PartitionSpec
    rep = NamedSharding(mesh, PartitionSpec())
    return jax.device_put(tree, jax.tree.map(lambda _: rep, tree))


def place_batch(batch, mesh=None, rules=None):
    """Shard a batch over the mesh's data axes via
    :func:`repro.distributed.sharding.batch_specs` (no-op without a mesh).
    Defaults come from the installed sharding context."""
    mesh = current_mesh() if mesh is None else mesh
    if mesh is None:
        return batch
    from repro.distributed.sharding import batch_specs
    rules = current_rules() if rules is None else rules
    return jax.device_put(batch, batch_specs(batch, mesh, rules))


def _resolve_loss(cfg: ModelConfig, loss: str):
    """loss name -> fn(params, batch, remat) -> (loss, metrics); shared by
    the train and eval steps so both score the trained objective."""
    if loss == "sig_mmd":
        return make_sig_mmd_loss(cfg)
    if loss == "lm":
        return lambda params, batch, remat: M.loss_fn(params, cfg, batch,
                                                      remat=remat)
    raise ValueError(f"unknown loss {loss!r}; expected 'lm' or 'sig_mmd'")


def make_train_step(cfg: ModelConfig, opt: Optimizer, *, remat: str = "dots",
                    microbatch: int = 0, sig_backend: str = "",
                    sig_backward: str = "", loss: str = "lm"):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With microbatch > 0, gradients are accumulated over
    `microbatch` slices of the batch (sequential, constant memory).
    ``sig_backend``/``sig_backward`` pin the signature head's engine dispatch
    for this training run (the speed path is the trained path).  ``loss``
    selects the objective: ``"lm"`` (token NLL) or ``"sig_mmd"`` (the
    signature-kernel distribution-matching loss, see
    :func:`make_sig_mmd_loss`)."""
    cfg = _apply_sig_overrides(cfg, sig_backend, sig_backward)
    base_loss = _resolve_loss(cfg, loss)

    def loss_fn(params, batch):
        return base_loss(params, batch, remat)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            def slice_mb(i, t):
                def f(x):
                    mb = x.shape[0] // microbatch
                    return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
                return jax.tree.map(f, t)

            def body(i, carry):
                acc, loss_acc = carry
                loss, _, grads = grads_of(params, slice_mb(i, batch))
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, loss_acc + loss

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            grads, loss_sum = jax.lax.fori_loop(
                0, microbatch, body, (zeros, jnp.zeros((), jnp.float32)))
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss_sum / microbatch
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, updates)
        metrics = dict(metrics, grad_norm=global_norm(grads), loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, remat: str = "none", *,
                   loss: str = "lm", sig_backend: str = "",
                   sig_backward: str = ""):
    """Eval with the same objective (and sig-head dispatch overrides) the
    model was trained with — loss='sig_mmd' evaluates the MMD statistic."""
    cfg = _apply_sig_overrides(cfg, sig_backend, sig_backward)
    base_loss = _resolve_loss(cfg, loss)

    def eval_step(params, batch):
        loss_val, metrics = base_loss(params, batch, remat)
        return metrics
    return eval_step


def train_loop(cfg: ModelConfig, params, opt: Optimizer, data_iter,
               loop: TrainLoopConfig,
               checkpointer=None, start_step: int = 0,
               on_metrics: Optional[Callable[[int, dict], None]] = None):
    """CPU-runnable reference loop with checkpoint/restart + straggler guard.

    Fault tolerance: if a checkpointer is given, state is saved every
    ``ckpt_every`` steps and on KeyboardInterrupt/SIGTERM-style exits; restart
    resumes from ``start_step`` (see repro.checkpoint).  The straggler guard
    flags steps slower than ``straggler_deadline_s`` (at pod scale the
    launcher replaces the slow host; on CPU we log + continue).

    Observability: every log step goes to ``on_metrics`` — when the caller
    passes none, a default JSONL sink appends run logs under
    ``loop.run_dir`` (gitignored ``runs/`` by default; ``run_dir=""``
    disables).  Each step runs inside a ``train.step`` tracer span, ticks
    the step-time histogram / straggler counter, and the jitted step's
    retraces land in ``pathsig_jit_traces_total{site="train_step"}``.

    SLO enforcement: with ``loop.slos`` or ``loop.slo_callback`` set, the
    trailing-window health (step-latency p99, grad-norm spikes, loss
    finiteness — :func:`repro.obs.slo.train_slos` by default) is evaluated
    at the log cadence; breaches warn, invoke the callback, and — with
    ``slo_action="abort"`` or a callback returning ``"abort"`` — raise
    :class:`repro.obs.slo.SloBreach`.  Any exception escaping a step
    (including that abort) dumps the flight-recorder ring
    (:mod:`repro.obs.flight`) before the final checkpoint save runs.
    """
    if on_metrics is None and loop.run_dir:
        name = loop.run_name or time.strftime("run-%Y%m%d-%H%M%S")
        on_metrics = obs.jsonl_sink(
            os.path.join(loop.run_dir, f"{name}.jsonl"))
    # donate (params, opt_state): the step returns trees of identical
    # shapes/dtypes, so XLA updates them in place instead of allocating a
    # fresh copy per step (input_output_alias on the lowered HLO — asserted
    # in tests/test_shard.py via repro.distributed.hlo.donation_stats)
    step_fn = obs.instrument_jit(
        make_train_step(cfg, opt, remat=loop.remat,
                        microbatch=loop.microbatch,
                        sig_backend=loop.sig_backend,
                        sig_backward=loop.sig_backward,
                        loss=loop.loss), site="train_step",
        donate_argnums=(0, 1))
    opt_state = opt.init(params)
    if checkpointer is not None and start_step:
        params, opt_state, _ = checkpointer.restore(params, opt_state,
                                                    start_step)
    mesh = current_mesh()          # data-parallel when a context is installed
    if mesh is not None:
        params = replicate_tree(params, mesh)    # fresh device copies: the
        opt_state = replicate_tree(opt_state, mesh)  # caller's tree survives
    else:
        # the first donated step would otherwise invalidate the CALLER's
        # param buffers — one defensive copy keeps ownership inside the loop
        params = jax.tree_util.tree_map(jnp.copy, params)
        opt_state = jax.tree_util.tree_map(jnp.copy, opt_state)
    slo_active = bool(loop.slos) or loop.slo_callback is not None
    slo_specs = tuple(loop.slos) or obs.train_slos()
    slo_every = loop.slo_every or loop.log_every
    window = collections.deque(maxlen=max(1, loop.slo_window))
    history = []
    try:
        with obs.dump_on_error("train.loop"):
            for step in range(start_step, loop.steps):
                t0 = time.perf_counter()
                with obs.span("train.step", step=step):
                    batch = next(data_iter)
                    if mesh is not None:
                        batch = place_batch(batch, mesh)
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                    jax.block_until_ready(metrics["loss"])  # honest timing
                dt = time.perf_counter() - t0
                straggler = bool(loop.straggler_deadline_s
                                 and dt > loop.straggler_deadline_s)
                if straggler:
                    metrics = dict(metrics, straggler=True)
                if obs.enabled():
                    obs.histogram("pathsig_train_step_seconds",
                                  "train step wall-clock "
                                  "(block_until_ready)").observe(dt)
                    if straggler:
                        obs.counter(
                            "pathsig_train_stragglers_total",
                            "steps exceeding straggler_deadline_s").inc()
                    obs.gauge("pathsig_train_loss",
                              "last train-step loss").set(
                        float(metrics["loss"]))
                    if "grad_norm" in metrics:
                        obs.gauge("pathsig_train_grad_norm",
                                  "last train-step global gradient norm"
                                  ).set(float(metrics["grad_norm"]))
                if slo_active:
                    window.append((dt, float(metrics["loss"]),
                                   float(metrics["grad_norm"])
                                   if "grad_norm" in metrics else 0.0))
                    if step % slo_every == 0 or step == loop.steps - 1:
                        _enforce_slos(loop, slo_specs, window, step)
                if step % loop.log_every == 0 or step == loop.steps - 1:
                    m = {k: float(v) if hasattr(v, "shape") else v
                         for k, v in metrics.items()}
                    m["step"], m["sec"] = step, dt
                    history.append(m)
                    if on_metrics:
                        on_metrics(step, m)
                if checkpointer is not None and loop.ckpt_every and \
                        step and step % loop.ckpt_every == 0:
                    checkpointer.save(params, opt_state, step)
    finally:
        if checkpointer is not None:
            checkpointer.save(params, opt_state, loop.steps)
    return params, opt_state, history


def _slo_window_values(window) -> dict:
    """Trailing-window observations for :func:`repro.obs.slo.train_slos`:
    step-latency percentiles, worst grad norm, loss finiteness."""
    secs = sorted(dt for dt, _, _ in window)
    i99 = max(0, min(len(secs) - 1, math.ceil(0.99 * len(secs)) - 1))
    last_loss = window[-1][1]
    return {
        "step_s": window[-1][0],
        "step_p99_s": secs[i99],
        "loss": last_loss,
        "loss_finite": 1.0 if math.isfinite(last_loss) else 0.0,
        "grad_norm_max": max(g for _, _, g in window),
    }


def _enforce_slos(loop: TrainLoopConfig, slo_specs, window,
                  step: int) -> None:
    results = obs.evaluate_values(slo_specs, _slo_window_values(window))
    rep = obs.slo.report(results)
    action = None
    if loop.slo_callback is not None:
        action = loop.slo_callback(step, rep)
    if rep["status"] == "breach":
        msg = (f"train SLO breach at step {step}: "
               f"{', '.join(rep['breaches'])}")
        if loop.slo_action == "abort" or action == "abort":
            raise obs.SloBreach(msg)
        warnings.warn(msg, stacklevel=2)
