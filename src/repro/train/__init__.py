from .trainer import TrainLoopConfig, make_train_step, make_eval_step, \
    train_loop
