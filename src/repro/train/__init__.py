from .trainer import TrainLoopConfig, make_sig_mmd_loss, make_train_step, \
    make_eval_step, place_batch, replicate_tree, train_loop
