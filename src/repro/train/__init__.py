from .trainer import TrainLoopConfig, make_sig_mmd_loss, make_train_step, \
    make_eval_step, train_loop
