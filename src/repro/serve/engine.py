"""Serving: prefill + decode steps and a small batched engine.

``serve_step`` is the unit the decode_* / long_* dry-run cells lower: one new
token for every sequence in the batch against a seq_len-sized KV/state cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro.models import encdec, transformer as T
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, remat: str = "dots"):
    """Forward over the full prompt; returns last-position logits.

    (The *_prefill dry-run cells lower this: inference forward, no loss.)
    """
    if cfg.family == "encdec":
        def prefill(params, batch):
            enc = encdec.encode(params, cfg, batch["frames"], remat=remat)
            hidden = encdec.decode_train(params, cfg, enc, batch["tokens"],
                                         remat=remat)
            logits = jnp.einsum("bd,vd->bv", hidden[:, -1],
                                params["embed"].astype(hidden.dtype))
            return logits.astype(jnp.float32)
        return prefill

    def prefill(params, batch):
        hidden, _ = T.backbone(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"), remat=remat)
        logits = T.logits_fn(params, cfg, hidden[:, -1:])
        return logits[:, 0].astype(jnp.float32)
    return prefill


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """One decode step: (params, cache, tokens, rng) -> (next_tokens, cache)."""

    def serve_step(params, cache, tokens, rng):
        logits, cache = M.decode_step(params, cfg, tokens, cache)
        logits = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            next_tok = jax.random.categorical(rng, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok[:, None].astype(jnp.int32), cache

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched generation engine (CPU-runnable reference).

    Continuous-batching-lite: fixed batch slots, per-slot stop tracking.
    """
    cfg: ModelConfig
    params: dict
    max_len: int
    temperature: float = 0.0
    eos_id: int = -1

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.cfg, self.temperature))

    def generate(self, prompt_tokens, n_steps: int, rng=None):
        B = prompt_tokens.shape[0]
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = M.init_cache(self.cfg, B, self.max_len, jnp.float32)
        # teacher-forced prefill through decode steps (simple + exact)
        for j in range(prompt_tokens.shape[1] - 1):
            _, cache = M.decode_step(self.params, self.cfg,
                                     prompt_tokens[:, j:j + 1], cache)
        tok = prompt_tokens[:, -1:]
        out = [prompt_tokens]
        done = jnp.zeros((B, 1), bool)
        for s in range(n_steps):
            rng, sub = jax.random.split(rng)
            tok, cache = self._step(self.params, cache, tok, sub)
            if self.eos_id >= 0:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
