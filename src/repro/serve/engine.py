"""Serving: prefill + decode steps, a small batched engine, and the online
signature-feature engines.

``serve_step`` is the unit the decode_* / long_* dry-run cells lower: one new
token for every sequence in the batch against a seq_len-sized KV/state cache.
``SigStreamEngine`` is the streaming analogue for signature features: fixed
batch slots whose per-step windowed signatures stay current as path chunks
arrive, on an O(B·D_sig) pooled carry — the slots are sessions in a
:class:`repro.serve.sessions.SessionStore` (a private pool by default, or a
shared multi-tenant one via ``store=``) instead of recomputation per
request.  ``SigScoreEngine`` layers the kernel
methods of :mod:`repro.sigkernel` on top: incoming streams are scored /
KRR-predicted against a cached reference Gram using the stream's terminal
signature states.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro.core import tensor_ops as tops
from repro.core.stream import SignatureStream
from repro.models import encdec, transformer as T
from repro.models.config import ModelConfig
from repro.serve.sessions import SessionHandle, SessionStore


def make_prefill_step(cfg: ModelConfig, remat: str = "dots"):
    """Forward over the full prompt; returns last-position logits.

    (The *_prefill dry-run cells lower this: inference forward, no loss.)
    """
    if cfg.family == "encdec":
        def prefill(params, batch):
            enc = encdec.encode(params, cfg, batch["frames"], remat=remat)
            hidden = encdec.decode_train(params, cfg, enc, batch["tokens"],
                                         remat=remat)
            logits = jnp.einsum("bd,vd->bv", hidden[:, -1],
                                params["embed"].astype(hidden.dtype))
            return logits.astype(jnp.float32)
        return prefill

    def prefill(params, batch):
        hidden, _ = T.backbone(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"), remat=remat)
        logits = T.logits_fn(params, cfg, hidden[:, -1:])
        return logits[:, 0].astype(jnp.float32)
    return prefill


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """One decode step: (params, cache, tokens, rng) -> (next_tokens, cache)."""

    def serve_step(params, cache, tokens, rng):
        logits, cache = M.decode_step(params, cfg, tokens, cache)
        logits = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            next_tok = jax.random.categorical(rng, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok[:, None].astype(jnp.int32), cache

    return serve_step


def _hop_window(length: int, increments: jax.Array, window: int):
    """Shared hopping-window step: truncate a chunk larger than the window to
    its tail, and compute how many oldest increments must drop to keep
    occupancy <= window.  Returns (need, increments) ready for the block
    extend — the ring-occupancy invariant that keeps ``rolling_drop`` exact
    lives HERE, once."""
    m = increments.shape[1]
    if window and m > window:
        increments = increments[:, m - window:]
        m = window
    need = max(0, length + m - window) if window else 0
    return need, increments


def _engine_block(engine, store: SessionStore | None) -> SessionStore:
    """Admit an engine's fixed batch slots into a session pool (the engine's
    own single-tenant pool by default, or a shared multi-tenant one)."""
    if store is None:
        store = SessionStore(engine.d, engine.depth,
                             ring_capacity=engine.window,
                             initial_sessions=engine.batch,
                             backend=engine.backend, dtype=engine.dtype)
    else:
        if (store.d, store.depth) != (engine.d, engine.depth):
            raise ValueError(
                f"shared store is (d={store.d}, depth={store.depth}) but the "
                f"engine needs (d={engine.d}, depth={engine.depth})")
        if engine.window and store.ring_capacity < engine.window:
            raise ValueError(
                f"shared store rings hold {store.ring_capacity} increments; "
                f"the engine's hopping window needs >= {engine.window}")
        if jnp.dtype(store.dtype) != jnp.dtype(engine.dtype):
            raise ValueError(
                f"shared store holds {jnp.dtype(store.dtype)} pool state but "
                f"the engine asked for dtype={jnp.dtype(engine.dtype)}; pool "
                f"updates always run in the store's dtype")
        if engine.backend not in ("auto", store.backend):
            raise ValueError(
                f"shared store dispatches pool updates on "
                f"backend={store.backend!r} but the engine asked for "
                f"backend={engine.backend!r}; pass backend='auto' (or the "
                f"store's backend) to join a shared pool")
    engine._handles = store.create_block(
        engine.batch, prefix=f"{type(engine).__name__.lower()}/")
    return store


@dataclasses.dataclass
class SigStreamEngine:
    """Batched online signature-feature engine (continuous-batching analogue
    for streaming features).

    Fixed batch slots live in a :class:`repro.serve.sessions.SessionStore`
    pool (the engine builds a private one, or joins a shared multi-tenant
    pool via ``store=``); every :meth:`push` of a (B, m, d) increment chunk
    returns the per-step signature features over the current window,
    (B, m_out, D_sig).  With ``window > 0`` the engine keeps a hopping
    window: before each push it drops however many oldest increments are
    needed so the window never exceeds ``window`` (chunks larger than the
    window keep only their tail).  The carry is O(B·D_sig + B·window·d) —
    independent of how long the streams run — and the hot loop is the engine
    dispatch's streamed forward on the configured backend.
    """
    d: int
    depth: int
    batch: int
    window: int = 0             # 0 = expanding window (never drop)
    backend: str = "auto"
    stream_stride: int = 1
    dtype: jnp.dtype = jnp.float32
    store: Optional[SessionStore] = None    # join a shared pool

    def __post_init__(self):
        self.store = _engine_block(self, self.store)

    @property
    def handles(self) -> list[SessionHandle]:
        """The pool sessions backing this engine's batch slots."""
        return self._handles

    @property
    def state(self) -> SignatureStream:
        """The slots' current carry as a (B,)-batched
        :class:`SignatureStream` view.  Assignable: installing a carry
        writes it back into the pool slots."""
        return self.store.block_view(self._handles)

    @state.setter
    def state(self, new: SignatureStream) -> None:
        self.store.set_block(self._handles, new)

    def push(self, increments: jax.Array) -> jax.Array:
        """Feed (B, m, d) new increments; returns (B, m_out, D_sig) per-step
        features of the emitted steps (terminal step always included)."""
        increments = jnp.asarray(increments)
        need, increments = _hop_window(
            self.store.length(self._handles[0]), increments, self.window)
        if need:
            self.store.drop_block(self._handles, need)
        return self.store.extend_block(
            self._handles, increments, return_stream=True,
            stream_stride=self.stream_stride)

    @property
    def features(self) -> jax.Array:
        """Current (B, D_sig) window signature for every slot."""
        return self.store.block_features(self._handles)

    def reset(self) -> None:
        self.store.reset_block(self._handles)


@dataclasses.dataclass
class SigScoreEngine:
    """Streaming kernel scorer: live streams vs a cached reference Gram.

    At construction the reference paths' signatures, the (R, R) reference
    Gram and (optionally) the KRR dual coefficients are computed ONCE through
    the engine dispatch and cached.  At serve time, fixed batch slots live in
    a :class:`repro.serve.sessions.SessionStore` pool (private by default,
    shared via ``store=``) with a hopping window like
    :class:`SigStreamEngine`; every :meth:`push` of an increment chunk
    updates the O(B·D_sig) carry and returns (B, R) kernel scores of the
    *terminal* window signatures against the references — one tiled
    cross-Gram per chunk, never a recomputation of reference signatures.
    :meth:`predict` turns the same cross-Gram into kernel-ridge predictions;
    :meth:`nearest` into retrieval indices.
    """
    d: int
    depth: int
    batch: int
    references: jax.Array                    # (R, M+1, d) reference paths
    targets: Optional[jax.Array] = None      # (R,) or (R, p) KRR targets
    window: int = 0                          # 0 = expanding window
    backend: str = "auto"
    level_weights: Optional[tuple] = None
    gamma: Optional[tuple] = None
    reg: float = 1e-3
    normalize: bool = True
    block_words: int = 512
    precision: str = "fp32"                  # "fp32" | "bf16_fp32"
    dtype: jnp.dtype = jnp.float32
    store: Optional[SessionStore] = None     # join a shared pool

    def __post_init__(self):
        from repro.kernels import ops
        from repro.sigkernel import krr_fit, word_weights
        refs = jnp.asarray(self.references)
        if refs.ndim != 3 or refs.shape[-1] != self.d:
            raise ValueError(f"references must be (R, M+1, {self.d}) paths, "
                             f"got {refs.shape}")
        self.weights = jnp.asarray(word_weights(
            self.d, self.depth, level_weights=self.level_weights,
            gamma=self.gamma))
        self.ref_sigs = ops.signature(tops.path_increments(refs), self.depth,
                                      backend=self.backend,
                                      precision=self.precision)
        self.ref_gram = ops.gram(self.ref_sigs, self.ref_sigs, self.weights,
                                 backend=self.backend,
                                 block_words=self.block_words,
                                 precision=self.precision)
        self.alpha = None if self.targets is None else krr_fit(
            self.ref_gram, jnp.asarray(self.targets), self.reg)
        self.store = _engine_block(self, self.store)
        self._cross = None          # cached raw (B, R) Gram of current state

    @property
    def handles(self) -> list[SessionHandle]:
        """The pool sessions backing this engine's batch slots."""
        return self._handles

    @property
    def state(self) -> SignatureStream:
        """The slots' current carry as a (B,)-batched
        :class:`SignatureStream` view.  Assignable: installing a carry
        writes it back into the pool slots."""
        return self.store.block_view(self._handles)

    @state.setter
    def state(self, new: SignatureStream) -> None:
        self.store.set_block(self._handles, new)
        self._cross = None

    def push(self, increments: jax.Array) -> jax.Array:
        """Feed (B, m, d) new increments; returns the refreshed (B, R)
        reference scores of every slot's current window."""
        increments = jnp.asarray(increments)
        need, increments = _hop_window(
            self.store.length(self._handles[0]), increments, self.window)
        if need:
            self.store.drop_block(self._handles, need)
        self.store.extend_block(self._handles, increments)
        self._cross = None          # state moved: invalidate the cached Gram
        return self.scores()

    def _terminal_sigs(self) -> jax.Array:
        return self.store.block_features(self._handles)

    def _cross_gram(self) -> jax.Array:
        """The raw (B, R) cross-Gram of the current terminal signatures,
        computed once per state — scores/predict/nearest all share it."""
        if self._cross is None:
            from repro.kernels import ops
            self._cross = ops.gram(self._terminal_sigs(), self.ref_sigs,
                                   self.weights, backend=self.backend,
                                   block_words=self.block_words,
                                   precision=self.precision)
        return self._cross

    def scores(self) -> jax.Array:
        """(B, R) kernel scores of the terminal window signatures (RKHS
        cosine when ``normalize=True``, raw k_ω otherwise)."""
        from repro.sigkernel import gram_diag
        K = self._cross_gram()
        if not self.normalize:
            return K
        qn = jnp.sqrt(jnp.maximum(
            gram_diag(self._terminal_sigs(), self.weights), 1e-12))
        rn = jnp.sqrt(jnp.maximum(jnp.diag(self.ref_gram), 1e-12))
        return K / (qn[:, None] * rn[None, :])

    def predict(self) -> jax.Array:
        """(B[, p]) kernel-ridge predictions against the cached duals."""
        if self.alpha is None:
            raise ValueError("SigScoreEngine has no targets: construct with "
                             "targets= to enable KRR predictions")
        from repro.sigkernel import krr_predict
        return krr_predict(self._cross_gram(), self.alpha)

    def nearest(self) -> jax.Array:
        """(B,) index of the best-scoring reference per slot."""
        return jnp.argmax(self.scores(), axis=-1)

    def reset(self) -> None:
        self.store.reset_block(self._handles)
        self._cross = None


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched generation engine (CPU-runnable reference).

    Continuous-batching-lite: fixed batch slots, per-slot stop tracking.
    """
    cfg: ModelConfig
    params: dict
    max_len: int
    temperature: float = 0.0
    eos_id: int = -1

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.cfg, self.temperature))

    def generate(self, prompt_tokens, n_steps: int, rng=None):
        B = prompt_tokens.shape[0]
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = M.init_cache(self.cfg, B, self.max_len, jnp.float32)
        # teacher-forced prefill through decode steps (simple + exact)
        for j in range(prompt_tokens.shape[1] - 1):
            _, cache = M.decode_step(self.params, self.cfg,
                                     prompt_tokens[:, j:j + 1], cache)
        tok = prompt_tokens[:, -1:]
        out = [prompt_tokens]
        done = jnp.zeros((B, 1), bool)
        for s in range(n_steps):
            rng, sub = jax.random.split(rng)
            tok, cache = self._step(self.params, cache, tok, sub)
            if self.eos_id >= 0:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
