"""Serving: prefill + decode steps, a small batched engine, and the online
signature-feature engine.

``serve_step`` is the unit the decode_* / long_* dry-run cells lower: one new
token for every sequence in the batch against a seq_len-sized KV/state cache.
``SigStreamEngine`` is the streaming analogue for signature features: fixed
batch slots whose per-step windowed signatures stay current as path chunks
arrive, on an O(B·D_sig) carry (:class:`repro.core.stream.SignatureStream`)
instead of recomputation per request.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro.core.stream import SignatureStream, signature_stream_init
from repro.models import encdec, transformer as T
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, remat: str = "dots"):
    """Forward over the full prompt; returns last-position logits.

    (The *_prefill dry-run cells lower this: inference forward, no loss.)
    """
    if cfg.family == "encdec":
        def prefill(params, batch):
            enc = encdec.encode(params, cfg, batch["frames"], remat=remat)
            hidden = encdec.decode_train(params, cfg, enc, batch["tokens"],
                                         remat=remat)
            logits = jnp.einsum("bd,vd->bv", hidden[:, -1],
                                params["embed"].astype(hidden.dtype))
            return logits.astype(jnp.float32)
        return prefill

    def prefill(params, batch):
        hidden, _ = T.backbone(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"), remat=remat)
        logits = T.logits_fn(params, cfg, hidden[:, -1:])
        return logits[:, 0].astype(jnp.float32)
    return prefill


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """One decode step: (params, cache, tokens, rng) -> (next_tokens, cache)."""

    def serve_step(params, cache, tokens, rng):
        logits, cache = M.decode_step(params, cfg, tokens, cache)
        logits = logits[:, -1].astype(jnp.float32)
        if temperature > 0:
            next_tok = jax.random.categorical(rng, logits / temperature)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok[:, None].astype(jnp.int32), cache

    return serve_step


@dataclasses.dataclass
class SigStreamEngine:
    """Batched online signature-feature engine (continuous-batching analogue
    for streaming features).

    Fixed batch slots share one :class:`SignatureStream` carry; every
    :meth:`push` of a (B, m, d) increment chunk returns the per-step
    signature features over the current window, (B, m_out, D_sig).  With
    ``window > 0`` the engine keeps a hopping window: before each push it
    drops however many oldest increments are needed so the window never
    exceeds ``window`` (chunks larger than the window keep only their tail).
    The carry is O(B·D_sig + B·window·d) — independent of how long the
    streams run — and the hot loop is the engine dispatch's streamed forward
    on the configured backend.
    """
    d: int
    depth: int
    batch: int
    window: int = 0             # 0 = expanding window (never drop)
    backend: str = "auto"
    stream_stride: int = 1
    dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        self.state: SignatureStream = signature_stream_init(
            self.batch, self.d, self.depth, capacity=self.window,
            dtype=self.dtype)

    def push(self, increments: jax.Array) -> jax.Array:
        """Feed (B, m, d) new increments; returns (B, m_out, D_sig) per-step
        features of the emitted steps (terminal step always included)."""
        B, m, d = increments.shape
        if self.window and m > self.window:
            increments = increments[:, m - self.window:]
            m = self.window
        if self.window:
            need = max(0, self.state.length + m - self.window)
            if need:
                self.state = self.state.rolling_drop(need)
        self.state, feats = self.state.extend(
            increments, backend=self.backend, return_stream=True,
            stream_stride=self.stream_stride)
        return feats

    @property
    def features(self) -> jax.Array:
        """Current (B, D_sig) window signature for every slot."""
        return self.state.sig

    def reset(self) -> None:
        self.__post_init__()


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched generation engine (CPU-runnable reference).

    Continuous-batching-lite: fixed batch slots, per-slot stop tracking.
    """
    cfg: ModelConfig
    params: dict
    max_len: int
    temperature: float = 0.0
    eos_id: int = -1

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.cfg, self.temperature))

    def generate(self, prompt_tokens, n_steps: int, rng=None):
        B = prompt_tokens.shape[0]
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        cache = M.init_cache(self.cfg, B, self.max_len, jnp.float32)
        # teacher-forced prefill through decode steps (simple + exact)
        for j in range(prompt_tokens.shape[1] - 1):
            _, cache = M.decode_step(self.params, self.cfg,
                                     prompt_tokens[:, j:j + 1], cache)
        tok = prompt_tokens[:, -1:]
        out = [prompt_tokens]
        done = jnp.zeros((B, 1), bool)
        for s in range(n_steps):
            rng, sub = jax.random.split(rng)
            tok, cache = self._step(self.params, cache, tok, sub)
            if self.eos_id >= 0:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id, tok)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
