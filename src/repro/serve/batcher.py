"""Dynamic batching for ragged signature serving.

Per-request serving of variable-length paths is the worst case for a
compiled runtime: every distinct length is a fresh executable, and batch=1
leaves the hardware idle.  ``DynamicBatcher`` turns that traffic into
micro-batched serving with a *bounded* set of compiled shapes:

1. requests are queued (:meth:`submit`) as (M_i+1, d) paths;
2. :meth:`flush` packs them into length buckets on the
   :func:`repro.ragged.bucket_ladder` (lengths rounded up a geometric
   ladder) and pads each micro-batch's row count up a power-of-two ladder;
3. each bucket runs ONE engine call over its padded
   :class:`repro.ragged.RaggedPaths` — exact per-request answers, because
   zero-masked padding is the identity (see :mod:`repro.ragged`);
4. results are scattered back to the submitting tickets.

Shape accounting is explicit: ``shapes_seen`` is the set of (padded_len,
padded_batch) pairs fed to the engine — at most ``len(ladder) ×
len(batch-rungs)`` entries no matter how many distinct request lengths
arrive — and ``stats()`` reports padding waste next to it.

The two factories bind the batcher to the serving engines of
:mod:`repro.serve.engine`: :meth:`signature_service` computes the terminal
window features a :class:`SigStreamEngine` tracks online, and
:meth:`scoring_service` rides a :class:`SigScoreEngine`'s cached reference
signatures/Gram for retrieval scores or KRR predictions per request.

Multi-device: give the batcher a mesh (``mesh=`` or build it inside an
installed ``sharding_ctx``) and every flushed rung is placed across it —
the batch rung rounds up to a multiple of the mesh's batch-shard count so
each device owns the same number of rows, values/lengths are device_put
batch-sharded, and the per-shape jitted computes trace under the mesh
context (so the engine calls inside take the SPMD path of
:mod:`repro.kernels.ops`).  :meth:`stats` then reports per-device occupancy
(``devices`` / ``rows_per_device`` / ``occupancy``) next to the shape
accounting.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.distributed.ctx import (current_mesh, logical_axis_size,
                                   named_sharding, sharding_ctx)
from repro.kernels.ops import BoundedCache
from repro.ragged import (RaggedPaths, assign_buckets, batch_rung,
                          bucket_ladder, pad_batch)


@dataclasses.dataclass
class _Request:
    ticket: int
    path: np.ndarray      # (M_i+1, d)
    length: int           # increments


@dataclasses.dataclass
class DynamicBatcher:
    """Queue → length-bucket → micro-batch executor (see module docstring).

    ``compute(batch: RaggedPaths) -> (B, ...) array`` is the per-bucket
    engine call; row b of its output is the answer for example b.  Build
    one with :meth:`signature_service` / :meth:`scoring_service`, or pass
    any custom callable (it sees zero-masked-exact padded batches).
    """
    compute: Callable[[RaggedPaths], jax.Array]
    d: int
    max_len: int                      # longest accepted request (increments)
    min_bucket: int = 16              # bottom rung of the length ladder
    growth: float = 2.0               # ladder growth factor
    max_batch: int = 64               # top rung of the batch ladder
    ladder: Optional[np.ndarray] = None   # explicit rungs override
    jit_compute: bool = True          # one executable per (rung, batch) shape
    mesh: Optional[object] = None     # jax Mesh: place rungs across devices
    mesh_rules: Optional[dict] = None     # logical-axis rule overrides
    slos: Optional[tuple] = None      # health() objectives (None -> defaults)
    latency_window: int = 1024        # recent flush latencies kept for health
    async_dispatch: bool = True       # prefetch next rung while current runs
    max_in_flight: int = 2            # bound on dispatched-not-retired rungs

    def __post_init__(self):
        if self.ladder is None:
            self.ladder = bucket_ladder(self.max_len,
                                        min_len=self.min_bucket,
                                        growth=self.growth)
        self.ladder = np.asarray(self.ladder, np.int64)
        self.max_len = int(self.ladder[-1])
        if self.mesh is None:  # adopt an installed context at build time
            self.mesh = current_mesh()
        # per-(rung, batch) jitted computes, bounded under the shared
        # plan-cache policy: evicting a shape frees its executable; traffic
        # returning to it just re-jits (bit-identical results)
        self._compute_cache = BoundedCache("dynamic_batcher_compute")
        if self.slos is None:
            self.slos = obs.batcher_slos()
        # host-side latency record so health() works with metrics disabled
        self._flush_latencies = collections.deque(
            maxlen=max(1, self.latency_window))
        if self.max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got "
                             f"{self.max_in_flight}")
        self._queue: list[_Request] = []
        self._next_ticket = 0
        self._in_flight_peak = 0      # most dispatched-not-retired rungs seen
        self._prefetched_rungs = 0    # Σ rungs device_put ahead of compute
        self.shapes_seen: set[tuple[int, int]] = set()
        self.padded_steps = 0         # Σ padded increments fed to the engine
        self.true_steps = 0           # Σ true increments served
        self.padded_rows = 0          # Σ batch rows fed to the engine
        self.true_rows = 0            # Σ real requests served

    # -- mesh placement ----------------------------------------------------

    def _mesh_scope(self):
        """Context manager installing this batcher's mesh (a no-op stack
        entry when the batcher is single-device)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self.mesh, self.mesh_rules)

    def _batch_shards(self) -> int:
        """Shards of the "batch" logical axis under this batcher's OWN mesh
        (fixed at construction — never the ambient context, so rung rounding
        and stats() accounting cannot drift with the call site)."""
        if self.mesh is None:
            return 1
        with self._mesh_scope():
            return logical_axis_size("batch")

    def _place(self, rp: RaggedPaths) -> RaggedPaths:
        """device_put a flushed rung across the mesh: values and lengths
        batch-sharded, so each device owns B_pad / P requests."""
        if self.mesh is None:
            return rp
        with self._mesh_scope():
            shardings = RaggedPaths(
                values=named_sharding("batch", "path_time", None),
                lengths=named_sharding("batch"))
        return jax.device_put(rp, shardings)

    # -- request side ------------------------------------------------------

    def submit(self, path) -> int:
        """Queue one (M_i+1, d) path; returns the ticket :meth:`flush`
        resolves."""
        path = np.asarray(path, np.float32)
        if path.ndim != 2 or path.shape[-1] != self.d:
            raise ValueError(f"request must be (M+1, {self.d}), got "
                             f"{path.shape}")
        length = path.shape[0] - 1
        if not 0 <= length <= self.max_len:
            raise ValueError(f"request length {length} outside [0, "
                             f"{self.max_len}] (the ladder's top rung)")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append(_Request(t, path, length))
        if obs.enabled():
            obs.gauge("pathsig_batcher_queue_depth",
                      "requests waiting in the DynamicBatcher queue",
                      ).set(len(self._queue))
        return t

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- execution side ----------------------------------------------------

    def _compute_fn(self, rung: int, B_pad: int):
        return (self._compute_cache.get(
            (rung, B_pad),
            lambda: obs.instrument_jit(
                self.compute, site="batcher_compute"))
            if self.jit_compute else self.compute)

    def _pack_groups(self, queue) -> list:
        """Bucket + split the queue into host-side micro-batches:
        [(rung, B_pad, part, host RaggedPaths)], with shape/padding
        accounting applied."""
        shards = self._batch_shards()
        lengths = np.asarray([r.length for r in queue], np.int64)
        which = assign_buckets(lengths, self.ladder)
        groups = []
        for k in np.unique(which):
            rung = int(self.ladder[k])
            group = [queue[i] for i in np.nonzero(which == k)[0]]
            # split oversized groups so the batch rung never exceeds
            # max_batch
            for off in range(0, len(group), self.max_batch):
                part = group[off:off + self.max_batch]
                rp = RaggedPaths.from_list([r.path for r in part],
                                           pad_to=rung)
                B_pad = batch_rung(len(part), self.max_batch)
                # round the rung up to a multiple of the mesh's batch
                # shards so every device owns the same number of rows
                B_pad = -(-B_pad // shards) * shards
                self.shapes_seen.add((rung, B_pad))
                self.padded_steps += rung * B_pad
                self.true_steps += int(sum(r.length for r in part))
                self.padded_rows += B_pad
                self.true_rows += len(part)
                groups.append((rung, B_pad, part, pad_batch(rp, B_pad)))
        return groups

    def _run_groups(self, groups) -> list:
        """Async-dispatch executor: device_put the next rungs' host buffers
        while the current rung computes (each rung's transfer is issued up
        to ``max_in_flight`` groups ahead), dispatch every compute without
        blocking on its result (jax dispatch is async), and retire the
        oldest outstanding rung whenever more than ``max_in_flight`` are in
        flight.  Returns [(part, result_array)]; with ``async_dispatch=
        False`` this degrades to strict place→compute→next serial order."""
        window = self.max_in_flight if self.async_dispatch else 0
        placed = collections.deque()
        next_put = 0

        def top_up(limit):
            nonlocal next_put
            while next_put < len(groups) and next_put < limit:
                rung, B_pad, part, rp = groups[next_put]
                placed.append((rung, B_pad, part, self._place(rp)))
                next_put += 1

        results: list = []
        in_flight: collections.deque = collections.deque()
        for i in range(len(groups)):
            top_up(i + 1 + window)
            self._prefetched_rungs += len(placed) - 1
            rung, B_pad, part, rp = placed.popleft()
            fn = self._compute_fn(rung, B_pad)
            with self._mesh_scope(), \
                    obs.span("serve.batcher.rung", rung=rung, B_pad=B_pad,
                             rows=len(part), prefetched=len(placed)):
                res = fn(rp)
            results.append((part, res))
            in_flight.append(res)
            self._in_flight_peak = max(self._in_flight_peak, len(in_flight))
            while len(in_flight) > max(1, window):
                jax.block_until_ready(in_flight.popleft())
        return results

    @obs.dump_on_error("batcher.flush")
    def flush(self) -> dict[int, jax.Array]:
        """Run every queued request through bucketed micro-batches; returns
        {ticket: result_row}."""
        queue, self._queue = self._queue, []
        out: dict[int, jax.Array] = {}
        if not queue:
            return out
        t_flush = time.perf_counter()
        with obs.span("serve.batcher.flush", requests=len(queue)):
            for part, res in self._run_groups(self._pack_groups(queue)):
                for row, req in enumerate(part):
                    out[req.ticket] = res[row]
        self._flush_latencies.append(time.perf_counter() - t_flush)
        if obs.enabled():
            obs.histogram(
                "pathsig_batcher_flush_seconds",
                "wall-clock of one DynamicBatcher.flush (dispatch side)",
            ).observe(time.perf_counter() - t_flush)
            obs.counter("pathsig_batcher_requests_total",
                        "requests served through DynamicBatcher.flush",
                        ).inc(len(queue))
            obs.gauge("pathsig_batcher_padding_overhead",
                      "cumulative padded/true step ratio fed to the engine",
                      ).set(self.padded_steps / self.true_steps
                            if self.true_steps else 0.0)
            obs.gauge("pathsig_batcher_occupancy",
                      "cumulative true/padded batch-row occupancy",
                      ).set(self.true_rows / self.padded_rows
                            if self.padded_rows else 0.0)
            obs.gauge("pathsig_batcher_compiled_shapes",
                      "distinct (rung, B_pad) shapes fed to the engine",
                      ).set(len(self.shapes_seen))
            obs.gauge("pathsig_batcher_queue_depth",
                      "requests waiting in the DynamicBatcher queue",
                      ).set(len(self._queue))
        return out

    def _flush_pctl(self, q: float) -> float:
        lat = sorted(self._flush_latencies)
        if not lat:
            return 0.0
        i = max(0, min(len(lat) - 1,
                       int(np.ceil(q / 100.0 * len(lat))) - 1))
        return lat[i]

    def stats(self) -> dict:
        """Shape-count + padding-waste accounting for the traffic so far,
        plus per-device occupancy when the batcher places across a mesh."""
        shards = self._batch_shards()
        return {
            "flush_p50_s": self._flush_pctl(50),
            "flush_p99_s": self._flush_pctl(99),
            "flushes_recorded": len(self._flush_latencies),
            "compiled_shapes": len(self.shapes_seen),
            "shapes": sorted(self.shapes_seen),
            "ladder": self.ladder.tolist(),
            "padded_steps": self.padded_steps,
            "true_steps": self.true_steps,
            "padding_overhead": (self.padded_steps / self.true_steps
                                 if self.true_steps else 0.0),
            "devices": shards,
            "rows_per_device": self.padded_rows // shards,
            "occupancy": (self.true_rows / self.padded_rows
                          if self.padded_rows else 0.0),
            "async_dispatch": self.async_dispatch,
            "max_in_flight": self.max_in_flight,
            "in_flight_peak": self._in_flight_peak,
            "prefetched_rungs": self._prefetched_rungs,
            "compute_cache": dict(self._compute_cache.info()._asdict()),
        }

    def health(self, slos: Optional[tuple] = None) -> dict:
        """Machine-readable SLO health evaluated over :meth:`stats` —
        ``{"status": "ok"|"breach", "breaches": [...], "results": [...]}``.
        Host-side only (the recent-flush latency window feeds the p99), so
        it works with the metrics registry disabled."""
        use = self.slos if slos is None else tuple(slos)
        return obs.slo.report(obs.evaluate_values(use, self.stats()))

    # -- engine factories --------------------------------------------------

    @classmethod
    def signature_service(cls, d: int, depth: int, *, max_len: int,
                          backend: str = "auto", transform=None,
                          precision: str = "fp32", **kw) -> "DynamicBatcher":
        """Batcher computing each request's terminal signature features —
        the batched analogue of draining a :class:`SigStreamEngine` slot
        (same (D_sig,) feature vector its ``features`` property holds).
        ``transform`` fuses path transforms into the sweep (no augmented
        intermediate per batch); ``precision="bf16_fp32"`` serves the
        mixed-precision sweep."""
        from repro.kernels import ops
        from repro.core import tensor_ops as tops
        from repro.core.transforms import as_transform
        spec = as_transform(transform)

        def compute(rp: RaggedPaths) -> jax.Array:
            incs = tops.path_increments(rp.values)
            x0 = (rp.values[:, 0] if spec is not None and spec.basepoint
                  else None)
            return ops.signature(incs, depth, backend=backend,
                                 lengths=rp.lengths, transform=spec, x0=x0,
                                 precision=precision)

        return cls(compute, d, max_len, **kw)

    @classmethod
    def scoring_service(cls, engine, *, max_len: int, mode: str = "scores",
                        **kw) -> "DynamicBatcher":
        """Batcher scoring requests against a :class:`SigScoreEngine`'s
        cached reference signatures: ``mode="scores"`` returns (R,) kernel
        scores per request (RKHS cosine if the engine normalises),
        ``"nearest"`` the argmax reference index, ``"predict"`` the KRR
        prediction from the engine's cached duals."""
        if mode not in ("scores", "nearest", "predict"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "predict" and engine.alpha is None:
            raise ValueError("scoring_service(mode='predict') needs a "
                             "SigScoreEngine constructed with targets=")
        from repro.kernels import ops
        from repro.core import tensor_ops as tops
        from repro.sigkernel import gram_diag, krr_predict

        def compute(rp: RaggedPaths) -> jax.Array:
            incs = tops.path_increments(rp.values)
            S = ops.signature(incs, engine.depth, backend=engine.backend,
                              lengths=rp.lengths,
                              precision=getattr(engine, "precision", "fp32"))
            K = ops.gram(S, engine.ref_sigs, engine.weights,
                         backend=engine.backend,
                         block_words=engine.block_words,
                         precision=getattr(engine, "precision", "fp32"))
            if mode == "predict":
                return krr_predict(K, engine.alpha)
            if engine.normalize:
                qn = jnp.sqrt(jnp.maximum(gram_diag(S, engine.weights),
                                          1e-12))
                rn = jnp.sqrt(jnp.maximum(jnp.diag(engine.ref_gram), 1e-12))
                K = K / (qn[:, None] * rn[None, :])
            if mode == "nearest":
                return jnp.argmax(K, axis=-1)
            return K

        return cls(compute, engine.d, max_len, **kw)
