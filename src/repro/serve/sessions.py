"""Pooled multi-tenant session layer: millions of live signature streams in
ONE struct-of-arrays device pool.

Per-user streaming state used to be one ``SignatureStream`` pytree per user:
N users meant N carries, N dispatch calls per round, and a Python object
graph the device never saw.  ``SessionStore`` turns that into a serving
subsystem shaped like an LLM-serving KV pool:

- **Pool** — one :class:`repro.core.stream.StreamCarry`: (N, D_sig)
  signatures + (N, R, d) rings + per-row ``length``/``end``/``valid`` lanes,
  resident on device (batch-sharded across a mesh under ``sharding_ctx``).
  Slots are recycled through a free list; *generation counters* make stale
  handles detectable instead of silently reading another tenant's lane.
  The pool grows by doubling, so compiled shapes stay bounded (log₂ many
  pool sizes ever exist).

- **Continuous-batching ingest** — :meth:`ingest` / :meth:`ingest_many`
  queue ticks per session on the host; :meth:`flush` buckets whichever
  sessions have new ticks by tick-count rung (powers of two, zero-padded —
  a zero increment is the identity Chen update, so padding is exact), pads
  the row count up a power-of-two rung, and runs ONE gather → extend →
  scatter compute per bucket.  Compiled shapes are bounded by
  (tick rungs × row rungs × pool sizes) no matter what the traffic does,
  and the per-shape jitted computes live in a :class:`repro.kernels.ops.
  BoundedCache` under the shared plan-cache policy.

- **Eviction** — explicit (:meth:`evict`), TTL (sessions idle longer than
  ``ttl`` logical-clock units are swept at flush), and LRU (a full pool at
  ``max_sessions`` evicts the least-recently-seen session to admit a new
  one).  All three are accounted in :meth:`stats`, next to occupancy, flush
  shapes, and p99 ingest staleness.

- **Checkpoint/restore** — :meth:`checkpoint` writes the whole pool (device
  carry + host metadata) through :class:`repro.checkpoint.Checkpointer`;
  :meth:`restore` brings every session back bit-identically, including onto
  a different mesh (elastic restore).

Time is a *logical clock*: every flush advances ``now`` by 1.0, and every
public mutator takes ``now=`` to override — deterministic TTL tests, no
wall-clock in semantics.  Wall-clock is used only for the staleness numbers
reported by :meth:`stats`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import Checkpointer
from repro.core.stream import (SignatureStream, StreamCarry, stream_extend,
                               stream_init, stream_rolling_drop,
                               stream_scatter, stream_take)
from repro.distributed.ctx import (current_mesh, logical_axis_size,
                                   named_sharding, sharding_ctx)
from repro.kernels.ops import BoundedCache
from repro.ragged import batch_rung

import contextlib

Sid = Union[str, int]


@dataclasses.dataclass(frozen=True)
class SessionHandle:
    """Ticket for one live session: (sid, slot, generation).

    The generation is the slot's reuse counter — a handle outlives its
    session only as a *detectably* stale ticket (store methods raise on it),
    never as a silent read of whichever tenant holds the slot now.
    """
    sid: Sid
    slot: int
    generation: int


def _pctl(sample, q: float) -> float:
    """Percentile of a host-side sample that is 0.0 — never NaN — when the
    sample is empty (``np.percentile([]...)`` returns NaN with a warning)."""
    a = np.asarray(sample, np.float64)
    if a.size == 0:
        return 0.0
    return float(np.percentile(a, q))


def _pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@dataclasses.dataclass
class _Pending:
    """Host-side per-session ingest buffer."""
    chunks: list            # list of (m_i, d) np arrays, arrival order
    ticks: int              # total queued increments
    t_enqueue: float        # wall time of the oldest undelivered tick


class SessionStore:
    """Pooled multi-tenant signature sessions (see module docstring).

    Parameters
    ----------
    d, depth        signature configuration of every session in the pool.
    ring_capacity   per-session increment ring R (0 = expanding windows
                    only; rolling drops need R > 0).
    initial_sessions  starting pool size (rounded up to a power of two and
                    to the mesh's batch-shard count); the pool doubles as
                    sessions exceed it.
    max_sessions    hard pool bound; a full pool LRU-evicts (when
                    ``lru_evict``) or refuses creates.
    ttl             idle time (logical-clock units) after which a session
                    is evicted at flush; None disables.
    max_ticks       top tick-count rung per session per flush wave; a
                    session with more queued ticks drains over several
                    waves in arrival order.
    max_rows        top row rung per flush bucket.
    backend / dtype engine dispatch configuration for the hot loop.
    mesh            place the pool batch-sharded across this mesh (or the
                    ambient ``sharding_ctx`` at construction).
    """

    def __init__(self, d: int, depth: int, *, ring_capacity: int = 0,
                 initial_sessions: int = 64,
                 max_sessions: Optional[int] = None,
                 ttl: Optional[float] = None, max_ticks: int = 64,
                 max_rows: int = 4096, backend: str = "jax",
                 lru_evict: bool = True, dtype=jnp.float32,
                 mesh=None, mesh_rules: Optional[dict] = None,
                 staleness_window: int = 100_000,
                 slos: Optional[tuple] = None):
        if d < 1 or depth < 1:
            raise ValueError(f"need d >= 1 and depth >= 1, got {d}, {depth}")
        if ring_capacity < 0:
            raise ValueError("ring_capacity must be >= 0")
        if max_ticks < 1 or max_rows < 1:
            raise ValueError("max_ticks and max_rows must be >= 1")
        self.d, self.depth = d, depth
        self.ring_capacity = ring_capacity
        self.max_sessions = max_sessions
        self.ttl = ttl
        self.max_ticks = _pow2(max_ticks)
        self.max_rows = _pow2(max_rows)
        self.backend = backend
        self.lru_evict = lru_evict
        self.dtype = dtype
        self.mesh = mesh if mesh is not None else current_mesh()
        self.mesh_rules = mesh_rules
        self.slos = obs.session_slos() if slos is None else tuple(slos)

        n0 = max(_pow2(initial_sessions), self._batch_shards())
        if max_sessions is not None and n0 > _pow2(max_sessions):
            n0 = max(_pow2(max_sessions), self._batch_shards())
        self._carry: StreamCarry = self._place(stream_init(
            n0, d, depth, capacity=ring_capacity, dtype=dtype))

        # host mirrors (the schedulable truth; device lanes are belt-and-
        # braces for padded rows inside compiled flushes)
        self._ids: dict[Sid, int] = {}
        self._valid = np.zeros(n0, bool)
        self._length = np.zeros(n0, np.int64)
        self._end = np.zeros(n0, np.int64)
        self._generation = np.zeros(n0, np.int64)
        self._last_seen = np.zeros(n0, np.float64)
        self._free: list[int] = list(range(n0 - 1, -1, -1))
        self._pending: dict[int, _Pending] = {}
        self._auto_sid = 0

        self.now = 0.0                      # logical clock
        self._jit = BoundedCache("session_flush")
        self._shape_keys: set[tuple] = set()
        self._flush_shapes: set[tuple[int, int]] = set()
        self._pool_sizes: list[int] = [n0]
        self._staleness = deque(maxlen=staleness_window)
        self.created = 0
        self.updates = 0                    # ticks applied to the pool
        self.flushes = 0
        self.evictions = {"explicit": 0, "ttl": 0, "lru": 0}
        self.dropped_ticks = 0              # queued ticks lost to eviction

    # -- mesh placement ----------------------------------------------------

    def _mesh_scope(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        return sharding_ctx(self.mesh, self.mesh_rules)

    def _batch_shards(self) -> int:
        if self.mesh is None:
            return 1
        with self._mesh_scope():
            return logical_axis_size("batch")

    def _pool_shardings(self) -> Optional[StreamCarry]:
        """Batch-sharded placement of every pool lane (None off-mesh)."""
        if self.mesh is None:
            return None
        with self._mesh_scope():
            return StreamCarry(
                sig=named_sharding("batch", None),
                ring=named_sharding("batch", None, None),
                length=named_sharding("batch"), end=named_sharding("batch"),
                valid=named_sharding("batch"), d=self.d, depth=self.depth)

    def _place(self, carry: StreamCarry) -> StreamCarry:
        sh = self._pool_shardings()
        return carry if sh is None else jax.device_put(carry, sh)

    # -- pool views --------------------------------------------------------

    @property
    def pool(self) -> StreamCarry:
        """The live struct-of-arrays carry (read-only by convention)."""
        return self._carry

    @property
    def pool_size(self) -> int:
        return self._carry.size

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, sid: Sid) -> bool:
        return sid in self._ids

    # -- id / handle resolution --------------------------------------------

    def lookup(self, session: Union[Sid, SessionHandle]) -> SessionHandle:
        """sid or handle -> fresh valid handle.  Raises ``KeyError`` on an
        unknown sid and ``ValueError`` on a stale-generation handle."""
        if isinstance(session, SessionHandle):
            slot = self._ids.get(session.sid)
            if slot is None or slot != session.slot or \
                    self._generation[slot] != session.generation:
                raise ValueError(
                    f"stale session handle {session}: the session was "
                    f"evicted (or its slot was reassigned); look the sid up "
                    f"again or create a new session")
            return session
        slot = self._ids.get(session)
        if slot is None:
            raise KeyError(f"unknown session id {session!r}")
        return SessionHandle(session, slot, int(self._generation[slot]))

    def _slots_of(self, sessions) -> np.ndarray:
        return np.asarray([self.lookup(s).slot for s in sessions], np.int64)

    # -- create / evict ----------------------------------------------------

    def create(self, sid: Optional[Sid] = None, *,
               now: Optional[float] = None) -> SessionHandle:
        """Admit one session (auto-generated sid when None).  Double-create
        raises; a full pool grows (doubling) up to ``max_sessions``, then
        LRU-evicts or refuses."""
        return self.create_many([sid], now=now)[0]

    def create_many(self, sids: Iterable[Optional[Sid]], *,
                    now: Optional[float] = None) -> list[SessionHandle]:
        """Bulk admission: one device reset for the whole batch of slots."""
        now = self.now if now is None else float(now)
        sids = list(sids)
        out_sids: list[Sid] = []
        for sid in sids:
            if sid is None:
                while f"s{self._auto_sid}" in self._ids:
                    self._auto_sid += 1
                sid = f"s{self._auto_sid}"
                self._auto_sid += 1
            if sid in self._ids:
                raise ValueError(f"session {sid!r} already exists "
                                 f"(double-create); evict it first or use "
                                 f"a fresh id")
            if sid in out_sids:
                raise ValueError(f"duplicate sid {sid!r} in create_many")
            out_sids.append(sid)
        if self.max_sessions is not None and not self.lru_evict and \
                len(self._ids) + len(out_sids) > self.max_sessions:
            raise RuntimeError(
                f"session pool full: admitting {len(out_sids)} sessions "
                f"would hold {len(self._ids) + len(out_sids)} > "
                f"max_sessions={self.max_sessions} and lru_evict is off")
        # admission is interleaved: each sid registers as its slot is taken,
        # so _take_slot's max_sessions check sees the in-flight creations
        # (bulk creates respect the strict bound, LRU-evicting per slot)
        slots = []
        handles = []
        for sid in out_sids:
            slot = self._take_slot(now)
            self._ids[sid] = slot
            self._valid[slot] = True
            self._length[slot] = 0
            self._end[slot] = 0
            self._last_seen[slot] = now
            slots.append(slot)
            handles.append(SessionHandle(sid, slot,
                                         int(self._generation[slot])))
        self.created += len(handles)
        # one scatter resets every admitted row (sig/ring zero, valid True)
        idx = jnp.asarray(np.asarray(slots, np.int64))
        fresh = stream_init(len(slots), self.d, self.depth,
                            capacity=self.ring_capacity, dtype=self.dtype,
                            valid=True)
        self._carry = stream_scatter(self._carry, idx, fresh)
        return handles

    def _take_slot(self, now: float) -> int:
        if self.max_sessions is not None and \
                len(self._ids) >= self.max_sessions:
            if self.lru_evict and self._ids:
                # prefer victims without queued ticks: ingest() already
                # acknowledged that data, so drop it only when every live
                # session is pending (the drop is counted in stats)
                idle = [s for s in self._ids
                        if self._ids[s] not in self._pending]
                victim = min(idle or self._ids,
                             key=lambda s: self._last_seen[self._ids[s]])
                self._evict_sids([victim], reason="lru")
            else:
                raise RuntimeError(
                    f"session pool full ({len(self._ids)} sessions, "
                    f"max_sessions={self.max_sessions}) and lru_evict is off")
        if not self._free:
            self._grow(2 * self._carry.size)
        return self._free.pop()

    def _grow(self, new_n: int) -> None:
        """Double the pool: copy rows into a fresh (new_n, ...) carry."""
        new_n = max(_pow2(new_n), self._carry.size * 2)
        old_n = self._carry.size
        self._carry = self._place(jax.tree.map(
            lambda a: jnp.zeros((new_n, *a.shape[1:]), a.dtype).at[:old_n]
            .set(a), self._carry))
        for arr in ("_valid", "_length", "_end", "_generation", "_last_seen"):
            old = getattr(self, arr)
            new = np.zeros(new_n, old.dtype)
            new[:old_n] = old
            setattr(self, arr, new)
        self._free = list(range(new_n - 1, old_n - 1, -1)) + self._free
        self._pool_sizes.append(new_n)

    def evict(self, session: Union[Sid, SessionHandle], *,
              reason: str = "explicit") -> None:
        """Release a session's slot (pending ticks are dropped).  The slot's
        generation bumps, so outstanding handles go stale."""
        h = self.lookup(session)
        self._evict_sids([h.sid], reason=reason)

    def _evict_sids(self, sids: list[Sid], *, reason: str) -> None:
        slots = []
        dropped_ticks = 0
        for sid in sids:
            slot = self._ids.pop(sid)
            self._valid[slot] = False
            self._generation[slot] += 1
            dropped = self._pending.pop(slot, None)
            if dropped is not None:
                self.dropped_ticks += dropped.ticks
                dropped_ticks += dropped.ticks
            self._free.append(slot)
            slots.append(slot)
        self.evictions[reason] = self.evictions.get(reason, 0) + len(sids)
        if obs.enabled():
            obs.counter("pathsig_sessions_evictions_total",
                        "SessionStore slot evictions by reason",
                        ("reason",)).inc(len(sids), reason=reason)
            if dropped_ticks:
                obs.counter("pathsig_sessions_dropped_ticks_total",
                            "queued ticks lost to eviction"
                            ).inc(dropped_ticks)
        idx = jnp.asarray(np.asarray(slots, np.int64))
        self._carry = dataclasses.replace(
            self._carry,
            valid=self._carry.valid.at[idx].set(False, mode="drop"))

    def sweep(self, *, now: Optional[float] = None) -> int:
        """Evict sessions idle for more than ``ttl`` (no-op without one).
        Runs automatically at every flush; returns the eviction count."""
        if self.ttl is None:
            return 0
        now = self.now if now is None else float(now)
        stale = [sid for sid, slot in self._ids.items()
                 if now - self._last_seen[slot] > self.ttl
                 and slot not in self._pending]
        if stale:
            self._evict_sids(stale, reason="ttl")
        return len(stale)

    # -- ingest ------------------------------------------------------------

    @obs.dump_on_error("sessions.ingest")
    def ingest(self, session: Union[Sid, SessionHandle], increments, *,
               now: Optional[float] = None) -> None:
        """Queue (m, d) new increments for one session (delivered at the
        next :meth:`flush`)."""
        h = self.lookup(session)
        inc = np.asarray(increments, np.float32)
        if inc.ndim != 2 or inc.shape[-1] != self.d:
            raise ValueError(f"increments must be (m, {self.d}), got "
                             f"{inc.shape}")
        self._queue(h.slot, inc, now)

    @obs.dump_on_error("sessions.ingest_many")
    def ingest_many(self, sids, counts, ticks, *,
                    now: Optional[float] = None,
                    auto_create: bool = False) -> None:
        """Bulk ingest: ``ticks`` is the (Σ counts, d) concatenation of each
        session's new increments, in ``sids`` order.  With ``auto_create``
        unknown sids are admitted first (the serving arrival path)."""
        sids = list(sids)
        counts = np.asarray(counts, np.int64)
        ticks = np.asarray(ticks, np.float32)
        if len(sids) != len(counts):
            raise ValueError(f"{len(sids)} sids vs {len(counts)} counts")
        if ticks.ndim != 2 or ticks.shape[-1] != self.d:
            raise ValueError(f"ticks must be (sum(counts), {self.d}), got "
                             f"{ticks.shape}")
        if counts.sum() != ticks.shape[0]:
            raise ValueError(f"counts sum to {counts.sum()} but ticks has "
                             f"{ticks.shape[0]} rows")
        if auto_create:
            fresh = [s for s in sids if s not in self._ids]
            if fresh:
                self.create_many(dict.fromkeys(fresh), now=now)
        bounds = np.cumsum(counts)[:-1]
        for sid, chunk in zip(sids, np.split(ticks, bounds)):
            h = self.lookup(sid)
            if len(chunk):
                self._queue(h.slot, chunk, now)

    def _queue(self, slot: int, inc: np.ndarray, now: Optional[float]) -> None:
        t = time.perf_counter()
        p = self._pending.get(slot)
        if p is None:
            self._pending[slot] = _Pending([inc], inc.shape[0], t)
        else:
            p.chunks.append(inc)
            p.ticks += inc.shape[0]
        self._last_seen[slot] = self.now if now is None else float(now)

    @property
    def pending_sessions(self) -> int:
        return len(self._pending)

    @property
    def pending_ticks(self) -> int:
        return sum(p.ticks for p in self._pending.values())

    # -- flush: continuous-batching delivery -------------------------------

    @obs.dump_on_error("sessions.flush")
    def flush(self, *, now: Optional[float] = None) -> int:
        """Deliver every queued tick through bucketed pool updates; advance
        the logical clock; TTL-sweep.  Returns the number of ticks applied.

        Occupancy is validated up front (host mirrors), so a ring overflow
        raises *before* any device work — the pool is never left corrupted.
        """
        R = self.ring_capacity
        if R:
            for slot, p in self._pending.items():
                if self._length[slot] + p.ticks > R:
                    sid = next(s for s, sl in self._ids.items() if sl == slot)
                    raise ValueError(
                        f"flushing {p.ticks} queued increments for session "
                        f"{sid!r} would hold {self._length[slot] + p.ticks} "
                        f"in a ring of capacity {R}; rolling_drop at least "
                        f"{self._length[slot] + p.ticks - R} first")
        pending, self._pending = self._pending, {}
        applied = 0
        t0 = time.perf_counter()
        metrics_on = obs.enabled()
        stale_h = obs.histogram(
            "pathsig_sessions_staleness_seconds",
            "queue residency (enqueue -> flush) per pending session"
        ) if metrics_on else None
        for p in pending.values():
            self._staleness.append(t0 - p.t_enqueue)
            if stale_h is not None:
                stale_h.observe(t0 - p.t_enqueue)
        with obs.span("serve.sessions.flush", sessions=len(pending)):
            # waves: each wave takes at most max_ticks per session, arrival
            # order
            work = {s: np.concatenate(p.chunks) if len(p.chunks) > 1
                    else p.chunks[0] for s, p in pending.items()}
            while work:
                wave = {s: a[:self.max_ticks] for s, a in work.items()}
                work = {s: a[self.max_ticks:] for s, a in work.items()
                        if a.shape[0] > self.max_ticks}
                applied += self._apply_wave(wave)
        self.flushes += 1
        self.now = (self.now + 1.0) if now is None else float(now)
        self.sweep()
        if metrics_on:
            obs.histogram(
                "pathsig_sessions_flush_seconds",
                "wall-clock of one SessionStore.flush (dispatch side)"
            ).observe(time.perf_counter() - t0)
            obs.counter("pathsig_sessions_ticks_applied_total",
                        "increments delivered to the pool by flushes"
                        ).inc(applied)
            obs.gauge("pathsig_sessions_pool_occupancy",
                      "live sessions / pool slots").set(
                len(self._ids) / self._carry.size)
            obs.gauge("pathsig_sessions_rung_shapes",
                      "distinct (tick rung, row rung) flush shapes so far"
                      ).set(len(self._flush_shapes))
        return applied

    def _apply_wave(self, wave: dict[int, np.ndarray]) -> int:
        """Bucket one wave's (slot -> (m_i, d)) chunks by tick rung and run
        the gather → extend → scatter compute per bucket."""
        shards = self._batch_shards()
        slots = np.fromiter(wave.keys(), np.int64, len(wave))
        ms = np.asarray([wave[s].shape[0] for s in slots], np.int64)
        rungs = np.minimum(self.max_ticks,
                           2 ** np.ceil(np.log2(np.maximum(ms, 1))).astype(
                               np.int64))
        applied = 0
        for rung in np.unique(rungs):
            sel = slots[rungs == rung]
            for off in range(0, len(sel), self.max_rows):
                part = sel[off:off + self.max_rows]
                B = batch_rung(len(part), self.max_rows)
                B = -(-B // shards) * shards
                incs = np.zeros((B, int(rung), self.d), np.float32)
                counts = np.zeros(B, np.int32)
                for i, slot in enumerate(part):
                    m = wave[slot].shape[0]
                    incs[i, :m] = wave[slot]
                    counts[i] = m
                # padding rows point one past the pool: gathers clamp with
                # count 0 (pass-through), scatters drop
                idx = np.full(B, self._carry.size, np.int64)
                idx[:len(part)] = part
                self._run_flush_step(int(rung), B, idx, incs, counts)
                self._length[part] += counts[:len(part)]
                if self.ring_capacity:
                    self._end[part] = (self._end[part] + counts[:len(part)]) \
                        % self.ring_capacity
                applied += int(counts.sum())
                self._flush_shapes.add((int(rung), B))
                self._shape_keys.add(("flush", int(rung), B,
                                      self._carry.size))
        self.updates += applied
        return applied

    def _run_flush_step(self, rung: int, B: int, idx, incs, counts) -> None:
        key = ("flush", rung, B, self._carry.size, self.backend)

        def make():
            def step(carry, slots, inc, cnt):
                sub = stream_take(carry, slots)
                sub = stream_extend(sub, inc, counts=cnt,
                                    backend=self.backend)
                return stream_scatter(carry, slots, sub)
            return obs.instrument_jit(step, site="session_flush",
                                      donate_argnums=self._donate)

        fn = self._jit.get(key, make)
        with self._mesh_scope():
            self._carry = fn(self._carry, jnp.asarray(idx),
                             jnp.asarray(incs), jnp.asarray(counts))

    @property
    def _donate(self) -> tuple:
        # buffer donation is a no-op (plus a warning) on CPU; elsewhere it
        # keeps the O(N·D_sig) pool from being copied every flush
        return () if jax.default_backend() == "cpu" else (0,)

    # -- reads -------------------------------------------------------------

    def features(self, session: Union[Sid, SessionHandle]) -> jax.Array:
        """(D_sig,) current window signature of one session."""
        return self._carry.sig[self.lookup(session).slot]

    def block_features(self, sessions) -> jax.Array:
        """(B, D_sig) gathered signatures for a block of sessions."""
        return jnp.take(self._carry.sig,
                        jnp.asarray(self._slots_of(sessions)), axis=0)

    def length(self, session: Union[Sid, SessionHandle]) -> int:
        return int(self._length[self.lookup(session).slot])

    def block_view(self, sessions) -> SignatureStream:
        """A :class:`SignatureStream` view of a uniform-occupancy block —
        the N=1-per-row spelling the engines expose as ``.state``."""
        slots = self._slots_of(sessions)
        lens, ends = self._length[slots], self._end[slots]
        if len(slots) and (np.any(lens != lens[0]) or np.any(ends != ends[0])):
            raise ValueError("block_view needs uniform occupancy across the "
                             "block (use features()/length() per session)")
        idx = jnp.asarray(slots)
        return SignatureStream(
            sig=jnp.take(self._carry.sig, idx, axis=0),
            ring=jnp.take(self._carry.ring, idx, axis=0),
            length=int(lens[0]) if len(slots) else 0,
            end=int(ends[0]) if len(slots) else 0,
            d=self.d, depth=self.depth)

    def set_block(self, sessions, state: SignatureStream) -> None:
        """Write a (B,)-batched :class:`SignatureStream` carry back into a
        block's slots — the inverse of :meth:`block_view`, for call sites
        that advance the view functionally and reinstall it."""
        slots = self._slots_of(sessions)
        if state.batch != len(slots):
            raise ValueError(f"carry batch {state.batch} != block size "
                             f"{len(slots)}")
        if (state.d, state.depth) != (self.d, self.depth):
            raise ValueError(f"carry is (d={state.d}, depth={state.depth}) "
                             f"but the pool holds (d={self.d}, "
                             f"depth={self.depth})")
        if state.capacity != self.ring_capacity:
            raise ValueError(f"carry ring capacity {state.capacity} != pool "
                             f"ring capacity {self.ring_capacity}")
        B = len(slots)
        sub = StreamCarry(
            sig=jnp.asarray(state.sig), ring=jnp.asarray(state.ring),
            length=jnp.full((B,), int(state.length), jnp.int32),
            end=jnp.full((B,), int(state.end), jnp.int32),
            valid=jnp.ones((B,), bool), d=self.d, depth=self.depth)
        self._carry = stream_scatter(self._carry, jnp.asarray(slots), sub)
        self._length[slots] = int(state.length)
        self._end[slots] = int(state.end)

    # -- synchronous block updates (the engines' fixed-slot path) ----------

    def create_block(self, n: int, *,
                     prefix: str = "slot") -> list[SessionHandle]:
        """n fresh sessions with generated ids ``{prefix}0..`` (skipping
        taken ids) — the fixed batch slots a serving engine owns."""
        sids: list[str] = []
        k = 0
        while len(sids) < n:
            sid = f"{prefix}{k}"
            k += 1
            if sid not in self._ids:
                sids.append(sid)
        return self.create_many(sids)

    def extend_block(self, sessions, increments, *,
                     return_stream: bool = False, stream_stride: int = 1,
                     backward: str = "inverse",
                     now: Optional[float] = None):
        """Synchronously append one uniform (B, m, d) chunk to a block of
        sessions (bypassing the ingest queue).  Returns the (B, m_out,
        D_sig) per-step features when ``return_stream``.  Raises on ring
        overflow exactly like ``SignatureStream.extend``."""
        slots = self._slots_of(sessions)
        increments = jnp.asarray(increments)
        if increments.ndim != 3 or increments.shape[-1] != self.d:
            raise ValueError(f"increments must be (B, m, {self.d}), got "
                             f"{increments.shape}")
        if increments.shape[0] != len(slots):
            raise ValueError(f"batch {increments.shape[0]} != block size "
                             f"{len(slots)}")
        m = increments.shape[1]
        R = self.ring_capacity
        if R:
            worst = int(self._length[slots].max(initial=0))
            if worst + m > R:
                raise ValueError(
                    f"extending by {m} would hold {worst + m} increments in "
                    f"a ring of capacity {R}; rolling_drop at least "
                    f"{worst + m - R} first")
        key = ("extend", len(slots), m, self._carry.size, return_stream,
               stream_stride, backward, self.backend)

        def make():
            def step(carry, idx, inc):
                sub = stream_take(carry, idx)
                out = stream_extend(sub, inc, backend=self.backend,
                                    backward=backward,
                                    return_stream=return_stream,
                                    stream_stride=stream_stride)
                sub, feats = out if return_stream else (out, None)
                carry = stream_scatter(carry, idx, sub)
                return (carry, feats) if return_stream else carry
            return obs.instrument_jit(step, site="session_extend",
                                      donate_argnums=self._donate)

        fn = self._jit.get(key, make)
        with self._mesh_scope():
            out = fn(self._carry, jnp.asarray(slots), increments)
        self._carry, feats = out if return_stream else (out, None)
        self._shape_keys.add(key)
        self._length[slots] += m
        if R:
            self._end[slots] = (self._end[slots] + m) % R
        self._last_seen[slots] = self.now if now is None else float(now)
        self.updates += int(m * len(slots))
        return feats

    def drop_block(self, sessions, n: int) -> None:
        """Synchronously drop each block session's ``n`` oldest increments
        (the exact left-inverse update)."""
        slots = self._slots_of(sessions)
        if self.ring_capacity == 0:
            raise ValueError("rolling_drop needs ring buffers: build the "
                             "store with ring_capacity > 0")
        shortest = int(self._length[slots].min()) if len(slots) else 0
        if not 0 <= n <= shortest:
            raise ValueError(f"cannot drop {n} increments from a window of "
                             f"length {shortest}")
        if n == 0:
            return
        key = ("drop", len(slots), int(n), self._carry.size)

        def make():
            def step(carry, idx):
                sub = stream_take(carry, idx)
                sub = stream_rolling_drop(sub, int(n))
                return stream_scatter(carry, idx, sub)
            return obs.instrument_jit(step, site="session_drop",
                                      donate_argnums=self._donate)

        fn = self._jit.get(key, make)
        with self._mesh_scope():
            self._carry = fn(self._carry, jnp.asarray(slots))
        self._shape_keys.add(key)
        self._length[slots] -= n

    def reset_block(self, sessions) -> None:
        """Zero a block's windows in place (lengths back to 0, handles stay
        valid)."""
        slots = self._slots_of(sessions)
        fresh = stream_init(len(slots), self.d, self.depth,
                            capacity=self.ring_capacity, dtype=self.dtype,
                            valid=True)
        self._carry = stream_scatter(self._carry, jnp.asarray(slots), fresh)
        self._length[slots] = 0
        self._end[slots] = 0

    # -- accounting --------------------------------------------------------

    def stats(self) -> dict:
        """Occupancy / eviction / flush-shape / staleness accounting."""
        stale = self._staleness
        return {
            "sessions": len(self._ids),
            "pool_size": self._carry.size,
            "occupancy": len(self._ids) / self._carry.size,
            "pool_sizes": list(self._pool_sizes),
            "created": self.created,
            "evictions": dict(self.evictions),
            "dropped_ticks": self.dropped_ticks,
            "updates": self.updates,
            "flushes": self.flushes,
            "pending_sessions": self.pending_sessions,
            "pending_ticks": self.pending_ticks,
            "flush_shapes": sorted(self._flush_shapes),
            "compiled_shapes": len(self._shape_keys),
            "compute_cache": dict(self._jit.info()._asdict()),
            "devices": self._batch_shards(),
            "p50_staleness_s": _pctl(stale, 50),
            "p99_staleness_s": _pctl(stale, 99),
            "now": self.now,
        }

    def health(self, slos: Optional[tuple] = None) -> dict:
        """Machine-readable SLO health evaluated over :meth:`stats` —
        ``{"status": "ok"|"breach", "breaches": [...], "results": [...]}``.
        Host-side only, so it works with the metrics registry disabled;
        pass custom :class:`repro.obs.slo.Slo` specs (or configure the
        store's ``slos=``) to change objectives."""
        use = self.slos if slos is None else tuple(slos)
        return obs.slo.report(obs.evaluate_values(use, self.stats()))

    # -- checkpoint / restore ----------------------------------------------

    def _host_state(self) -> dict:
        return {
            "kind": "session_store",
            "d": self.d, "depth": self.depth,
            "ring_capacity": self.ring_capacity,
            "pool_size": self._carry.size,
            "max_sessions": self.max_sessions, "ttl": self.ttl,
            "max_ticks": self.max_ticks, "max_rows": self.max_rows,
            "backend": self.backend, "lru_evict": self.lru_evict,
            "dtype": str(np.dtype(self.dtype)),
            "ids": [[sid, int(slot)] for sid, slot in self._ids.items()],
            "generation": self._generation.tolist(),
            "valid": self._valid.astype(int).tolist(),
            "length": self._length.tolist(),
            "end": self._end.tolist(),
            "last_seen": self._last_seen.tolist(),
            "free": list(self._free),
            "auto_sid": self._auto_sid,
            "now": self.now,
            "created": self.created, "updates": self.updates,
            "flushes": self.flushes,
            "evictions": dict(self.evictions),
            "dropped_ticks": self.dropped_ticks,
            "pool_sizes": list(self._pool_sizes),
            "flush_shapes": sorted(self._flush_shapes),
        }

    def checkpoint(self, ckptr: Checkpointer, step: int) -> None:
        """Write the whole pool (device carry + host metadata).  Pending
        ticks are flushed first, so a restore resumes every session from
        exactly this state."""
        if self._pending:
            self.flush()
        ckptr.save(self._carry, {}, step, extra=self._host_state())

    @classmethod
    def restore(cls, ckptr: Checkpointer, *, step: Optional[int] = None,
                backend: Optional[str] = None, mesh=None,
                mesh_rules: Optional[dict] = None) -> "SessionStore":
        """Rebuild a store from a checkpoint, bit-identically: every
        session's signature, ring, occupancy, id, generation and the
        logical clock come back exactly.  ``mesh`` (or the ambient context)
        re-places the pool — restarts may change topology."""
        extra = ckptr.peek_extra(step)
        if extra.get("kind") != "session_store":
            raise ValueError(f"checkpoint is not a session pool: {extra!r}")
        store = cls(
            extra["d"], extra["depth"],
            ring_capacity=extra["ring_capacity"],
            initial_sessions=extra["pool_size"],
            max_sessions=extra["max_sessions"], ttl=extra["ttl"],
            max_ticks=extra["max_ticks"], max_rows=extra["max_rows"],
            backend=backend or extra["backend"],
            lru_evict=extra["lru_evict"],
            dtype=jnp.dtype(extra["dtype"]), mesh=mesh,
            mesh_rules=mesh_rules)
        if store.pool_size != extra["pool_size"]:
            raise ValueError(f"pool size {extra['pool_size']} does not "
                             f"round-trip (got {store.pool_size})")
        sh = store._pool_shardings()
        carry, _, _ = ckptr.restore(
            store._carry, {}, step,
            shardings={"params": sh, "opt_state": {}} if sh is not None
            else None)
        store._carry = carry
        store._ids = {sid: int(slot) for sid, slot in extra["ids"]}
        store._generation = np.asarray(extra["generation"], np.int64)
        store._valid = np.asarray(extra["valid"], bool)
        store._length = np.asarray(extra["length"], np.int64)
        store._end = np.asarray(extra["end"], np.int64)
        store._last_seen = np.asarray(extra["last_seen"], np.float64)
        store._free = list(extra["free"])
        store._auto_sid = int(extra["auto_sid"])
        store.now = float(extra["now"])
        store.created = int(extra["created"])
        store.updates = int(extra["updates"])
        store.flushes = int(extra["flushes"])
        store.evictions = dict(extra["evictions"])
        store.dropped_ticks = int(extra.get("dropped_ticks", 0))
        store._pool_sizes = list(extra["pool_sizes"])
        store._flush_shapes = {tuple(s) for s in extra["flush_shapes"]}
        return store
