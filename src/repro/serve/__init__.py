from .sessions import SessionHandle, SessionStore
from .engine import (make_prefill_step, make_serve_step, ServeEngine,
                     SigScoreEngine, SigStreamEngine)
from .batcher import DynamicBatcher

__all__ = ["DynamicBatcher", "ServeEngine", "SessionHandle", "SessionStore",
           "SigScoreEngine", "SigStreamEngine", "make_prefill_step",
           "make_serve_step"]
