from .engine import (make_prefill_step, make_serve_step, ServeEngine,
                     SigScoreEngine, SigStreamEngine)
from .batcher import DynamicBatcher
