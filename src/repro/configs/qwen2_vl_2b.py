"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution; patch frontend is a stub —
input_specs() supplies precomputed patch/text embeddings.
[arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="decoder",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    act="silu", attn_bias=True, rope_type="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), tie_embeddings=True,
    source="arXiv:2409.12191",
)
