"""whisper-large-v3 [audio]: enc-dec; conv/mel frontend is a STUB —
input_specs() supplies precomputed frame embeddings.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_encoder_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    act="gelu", rope_type="sinusoidal", tie_embeddings=True,
    n_audio_frames=1500, decoder_max_len=448,
    source="arXiv:2212.04356",
)
