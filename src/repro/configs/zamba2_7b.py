"""zamba2-7b [hybrid]: Mamba2 backbone + two weight-shared attention blocks
applied every 6 layers (alternating). [arXiv:2411.15242; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    act="silu", rope_theta=1e4,
    ssm_state=64, mamba_head_dim=64, mamba_expand=2, conv_width=4,
    hybrid_attn_every=6, n_shared_attn_blocks=2,
    source="arXiv:2411.15242",
)
