"""command-r-35b [dense]: GQA, no-bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="decoder",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    act="silu", attn_bias=False, rope_theta=8e6, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
