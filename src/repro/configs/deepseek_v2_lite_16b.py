"""deepseek-v2-lite-16b [moe]: MLA kv_lora=512, 64 routed experts top-6 +
2 shared, first layer dense.  The sheet's "160 routed" belongs to full
DeepSeek-V2; V2-Lite is 64 (see DESIGN.md §4). [arXiv:2405.04434; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="decoder",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    act="silu", rope_theta=1e4,
    moe=True, n_experts=64, n_shared_experts=2, top_k=6,
    d_ff_expert=1408, moe_layer_start=1, d_ff_dense=10944,
    mla=True, kv_lora_rank=512, q_lora_rank=0,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    source="arXiv:2405.04434",
)
