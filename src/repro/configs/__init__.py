"""Assigned architecture pool: one module per architecture (exact configs
from the assignment sheet) + reduced smoke variants + the paper's own
signature-model example configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, SigHeadConfig

ARCH_IDS = [
    "command-r-35b", "llama3-405b", "qwen1.5-32b", "qwen3-4b", "qwen2-vl-2b",
    "deepseek-v2-lite-16b", "phi3.5-moe-42b-a6.6b", "zamba2-7b",
    "rwkv6-1.6b", "whisper-large-v3",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests: tiny widths/layers,
    few experts, small vocab — same code paths as the full config."""
    upd: dict = dict(
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=96,
        vocab_size=128,
        sig_head=cfg.sig_head,
    )
    if cfg.moe:
        upd.update(n_experts=4, top_k=2, d_ff_expert=32,
                   n_shared_experts=min(cfg.n_shared_experts, 1),
                   d_ff_dense=96 if cfg.d_ff_dense else 0)
    if cfg.mla:
        upd.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                   v_head_dim=16, head_dim=0)
    if cfg.family == "hybrid":
        upd.update(ssm_state=16, mamba_head_dim=16, hybrid_attn_every=2,
                   n_shared_attn_blocks=2, head_dim=0)
    if cfg.family == "rwkv":
        upd.update(rwkv_head_dim=16, n_heads=4, n_kv_heads=4)
    if cfg.family == "encdec":
        upd.update(n_encoder_layers=2, n_audio_frames=16, decoder_max_len=32)
    if cfg.rope_type == "mrope":
        upd.update(mrope_sections=(2, 3, 3), head_dim=16)
    return dataclasses.replace(cfg, **upd)


def with_sig_head(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, sig_head=SigHeadConfig(**kw))
