"""phi3.5-moe-42b-a6.6b [moe]: 16 experts top-2, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="decoder",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064,
    act="silu", rope_theta=1e4,
    moe=True, n_experts=16, n_shared_experts=0, top_k=2,
    d_ff_expert=6400, moe_layer_start=0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
