"""Self-contained optimizers (no external deps): AdamW, Adafactor, SGD.

Functional API mirroring optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``.  Optimizer states
inherit the parameter sharding (ZeRO: m/v shard exactly like params), which
the trainer enforces via matching PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), g


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        t = jnp.minimum(step, total_steps) / max(1, total_steps)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * t)))
    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup), min_frac)

    def lr(step):
        w = jnp.minimum(step / max(1, warmup), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: float | None = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              min_dim_factored=128) -> Optimizer:
    """Memory-factored second-moment optimizer (for 100B+ params on v5e:
    ~2 extra bytes/param instead of AdamW's 8)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and \
            p.shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(one, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def one(p, g, slot):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta * slot["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * slot["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps))
                u = g / (denom + eps)
                new = {"vr": vr, "vc": vc}
            else:
                v = beta * slot["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps)
                new = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr_t * u).astype(p.dtype), new

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        outs = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        updates = tdef.unflatten([o[0] for o in outs])
        slots = tdef.unflatten([o[1] for o in outs])
        return updates, {"slots": slots, "step": step}

    return Optimizer(init, update)


def sgd(lr=1e-2, momentum=0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p,
                                                             jnp.float32),
                                    params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                           state["mom"], grads)
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda p, m: (-lr_t * m).astype(p.dtype),
                               params, mom)
        return updates, {"mom": mom, "step": step}

    return Optimizer(init, update)
