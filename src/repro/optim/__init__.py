from .optimizers import (Optimizer, adamw, adafactor, sgd, global_norm,
                         clip_by_global_norm, cosine_schedule,
                         linear_warmup_cosine)
from .compression import int8_error_feedback_allreduce, compress_int8, \
    decompress_int8

__all__ = ["Optimizer", "adamw", "adafactor", "sgd", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup_cosine",
           "int8_error_feedback_allreduce", "compress_int8",
           "decompress_int8"]
