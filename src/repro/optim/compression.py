"""Gradient compression for cross-pod data parallelism.

int8 error-feedback compression: gradients are quantised to int8 with a
per-tensor scale before the cross-pod all-reduce; the quantisation error is
fed back into the next step (EF-SGD).  Cuts cross-pod DCN traffic 4× with
negligible quality loss at LLM scale; off by default, enabled per-run via
TrainLoopConfig.grad_compression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_error_feedback_allreduce(grads, error_state, axis_name: str):
    """Inside shard_map/pmap over `axis_name`: quantise + all-reduce + EF.

    Returns (reduced_grads_f32, new_error_state).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        new_e = g32 - deq
        red = jax.lax.pmean(deq, axis_name)
        return red, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
