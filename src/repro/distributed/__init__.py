from .ctx import sharding_ctx, shard, resolve_spec, current_mesh, DEFAULT_RULES
