from .ctx import (DEFAULT_RULES, current_mesh, current_rules, logical_axes,
                  logical_axis_size, named_sharding, resolve_spec, shard,
                  sharding_ctx)
from .hlo import collective_stats
