"""HLO analysis: collective-byte accounting for the roofline.

``cost_analysis()`` does not report collective traffic, so we parse the
post-SPMD optimized HLO text and sum the bytes moved by every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, applying
ring-algorithm factors per op kind and participating-group size.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of all tensors in an HLO shape signature (handles
    tuple shapes '(f32[...], f32[...])')."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota v2 format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclasses.dataclass
class CollectiveStats:
    # per-kind: (count, result_bytes, wire_bytes_per_device)
    by_kind: dict
    total_wire_bytes: float   # per device, ring-model estimate
    total_result_bytes: float

    def summary(self) -> str:
        lines = [f"{k}: n={v[0]} result={v[1]/2**20:.1f}MiB "
                 f"wire/dev={v[2]/2**20:.1f}MiB" for k, v in
                 sorted(self.by_kind.items())]
        lines.append(f"TOTAL wire/device = {self.total_wire_bytes/2**20:.1f} MiB")
        return "\n".join(lines)


def collective_stats(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    by_kind = defaultdict(lambda: [0, 0.0, 0.0])
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if kind not in _COLLECTIVES:
            continue
        if "all-reduce-start" in ls or "all-gather-start" in ls:
            pass  # async starts carry the shape; done ops are pass-through
        result_bytes = _shape_bytes(m.group(1))
        n = _group_size(ls, default_group)
        # ring-model wire bytes per device
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            wire = result_bytes * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)  # result is the scattered shard
        elif kind == "all-to-all":
            wire = result_bytes * (n - 1) / max(n, 1)
        else:  # collective-permute: point-to-point
            wire = result_bytes
        s = by_kind[kind]
        s[0] += 1
        s[1] += result_bytes
        s[2] += wire
    total_wire = sum(v[2] for v in by_kind.values())
    total_res = sum(v[1] for v in by_kind.values())
    return CollectiveStats(dict(by_kind), total_wire, total_res)


# -- donation ---------------------------------------------------------------

# optimized-HLO module header: input_output_alias={ {0}: (0, {}, may-alias) }
_ALIAS_PAIR_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(may-alias|must-alias)\)")
# StableHLO carries donation as a function-arg attribute instead
_STABLE_ALIAS_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")


@dataclasses.dataclass
class DonationStats:
    # (output_index, param_number, kind) per aliased pair
    pairs: list
    n_aliased: int

    def summary(self) -> str:
        if not self.pairs:
            return "no input/output aliasing"
        return "; ".join(f"out{o} <- arg{p} ({k})" for o, p, k in self.pairs)


def donation_stats(hlo_text: str) -> DonationStats:
    """Count donated (input-output aliased) buffers in lowered HLO text.

    Accepts either optimized HLO (``compiled.as_text()``, where aliasing
    lives in the module header's ``input_output_alias={...}``) or StableHLO
    (``lowered.as_text()``, where it appears as ``tf.aliasing_output``
    argument attributes).
    """
    pairs = []
    for line in hlo_text.splitlines():
        if "input_output_alias=" not in line:
            continue
        for out_idx, param, kind in _ALIAS_PAIR_RE.findall(line):
            pairs.append((out_idx.strip() or "0", int(param), kind))
    if not pairs:
        for i, m2 in enumerate(_STABLE_ALIAS_RE.finditer(hlo_text)):
            pairs.append((m2.group(1), i, "tf.aliasing_output"))
    return DonationStats(pairs, len(pairs))


def assert_donation(hlo_text: str, min_aliased: int = 1) -> DonationStats:
    """Assert at least ``min_aliased`` donated buffers were actually aliased
    in the lowered computation (donation silently degrades to a copy when
    XLA can't use the buffer — this catches that)."""
    st = donation_stats(hlo_text)
    if st.n_aliased < min_aliased:
        raise AssertionError(
            f"expected >= {min_aliased} input/output-aliased buffers, found "
            f"{st.n_aliased} ({st.summary()})")
    return st


# -- ring overlap ------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\s)")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# the op kind is the first identifier followed by '(' after the result
# shape — tuple shapes like '(s32[], f32[8,16]{1,0})' contain no 'ident('
_KIND_RE = re.compile(r"([A-Za-z][\w\-]*)\(")
_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")


def _parse_computations(hlo_text: str) -> dict:
    """Split optimized HLO text into computations: name -> list of
    (instr_name, kind, operand_names, called_comp_names)."""
    comps = {}
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{") and ("(" in line and "->" in line
                                   or line.startswith("ENTRY")):
            m = _COMP_HEAD_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        km = _KIND_RE.search(rhs)
        if not km:
            continue
        kind = km.group(1)
        called = _CALLED_RE.findall(rhs)
        # data operands: % tokens after the op kind, excluding called
        # computation refs, metadata, and the defined name itself
        rest = rhs[km.end():]
        rest = _CALLED_RE.sub("", rest)
        rest = re.sub(r'metadata=\{[^}]*\}', "", rest)
        operands = [t for t in re.findall(r"%([\w.\-]+)", rest)
                    if t != name]
        comps[cur].append((name, kind, operands, called))
    return comps


@dataclasses.dataclass
class RingOverlap:
    n_permutes: int
    n_dots: int
    in_loop: bool                 # any permute inside a while body/cond
    permute_depends_on_dot: bool  # any permute data-dependent on a dot

    @property
    def overlapped(self) -> bool:
        """True when permutes can overlap tile compute: they are unrolled
        (not serialized behind a loop carry) and issued independently of
        the dots (no permute waits on a dot result)."""
        return (self.n_permutes > 0 and self.n_dots > 0
                and not self.in_loop and not self.permute_depends_on_dot)

    def summary(self) -> str:
        return (f"permutes={self.n_permutes} dots={self.n_dots} "
                f"in_loop={self.in_loop} "
                f"permute_depends_on_dot={self.permute_depends_on_dot}")


def ring_overlap(hlo_text: str) -> RingOverlap:
    """Analyse a lowered ring computation for permute/compute overlap.

    A serialized ring keeps its ``collective-permute`` inside a while-loop
    body (each permute waits on the previous iteration's carry) or makes the
    permute's operand data-dependent on the tile dot.  An overlapped ring is
    unrolled with every permute issued from loop-independent values, so the
    scheduler may run step k's dot while step k+1's shard is on the wire.
    """
    comps = _parse_computations(hlo_text)

    # computations reachable from a while body/condition are "in loop"
    loop_roots = set()
    for instrs in comps.values():
        for _name, kind, _ops, called in instrs:
            if kind == "while":
                loop_roots.update(called)
    loop_comps = set()
    frontier = list(loop_roots)
    while frontier:
        c = frontier.pop()
        if c in loop_comps or c not in comps:
            continue
        loop_comps.add(c)
        for _n, _k, _o, called in comps[c]:
            frontier.extend(called)

    # "dotty" computations: contain a dot directly or call one (fixpoint)
    dotty = set()
    changed = True
    while changed:
        changed = False
        for cname, instrs in comps.items():
            if cname in dotty:
                continue
            for _n, kind, _o, called in instrs:
                if kind == "dot" or any(c in dotty for c in called):
                    dotty.add(cname)
                    changed = True
                    break

    n_permutes = 0
    n_dots = len(re.findall(r"\bdot\(", hlo_text))
    in_loop = False
    depends = False
    for cname, instrs in comps.items():
        defs = {n: (kind, ops, called) for n, kind, ops, called in instrs}
        for name, kind, _ops, _called in instrs:
            if not kind.startswith("collective-permute"):
                continue
            n_permutes += 1
            if cname in loop_comps:
                in_loop = True
            # def-use closure: does this permute wait on a dot result?
            seen, stack = set(), list(defs[name][1])
            while stack:
                op = stack.pop()
                if op in seen or op not in defs:
                    continue
                seen.add(op)
                okind, oops, ocalled = defs[op]
                if okind == "dot" or any(c in dotty for c in ocalled):
                    depends = True
                    stack = []
                    break
                stack.extend(oops)
    return RingOverlap(n_permutes, n_dots, in_loop, depends)


def remat_duplication(hlo_text: str) -> float:
    """Heuristic recompute indicator: ratio of dot/convolution op count to
    unique dot shapes (remat re-emits identical dots)."""
    dots = re.findall(r" = (.+?) dot\(", hlo_text)
    if not dots:
        return 1.0
    return len(dots) / max(1, len(set(dots)))
