"""HLO analysis: collective-byte accounting for the roofline.

``cost_analysis()`` does not report collective traffic, so we parse the
post-SPMD optimized HLO text and sum the bytes moved by every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, applying
ring-algorithm factors per op kind and participating-group size.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of all tensors in an HLO shape signature (handles
    tuple shapes '(f32[...], f32[...])')."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota v2 format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split("{")[-1]
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclasses.dataclass
class CollectiveStats:
    # per-kind: (count, result_bytes, wire_bytes_per_device)
    by_kind: dict
    total_wire_bytes: float   # per device, ring-model estimate
    total_result_bytes: float

    def summary(self) -> str:
        lines = [f"{k}: n={v[0]} result={v[1]/2**20:.1f}MiB "
                 f"wire/dev={v[2]/2**20:.1f}MiB" for k, v in
                 sorted(self.by_kind.items())]
        lines.append(f"TOTAL wire/device = {self.total_wire_bytes/2**20:.1f} MiB")
        return "\n".join(lines)


def collective_stats(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    by_kind = defaultdict(lambda: [0, 0.0, 0.0])
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if kind not in _COLLECTIVES:
            continue
        if "all-reduce-start" in ls or "all-gather-start" in ls:
            pass  # async starts carry the shape; done ops are pass-through
        result_bytes = _shape_bytes(m.group(1))
        n = _group_size(ls, default_group)
        # ring-model wire bytes per device
        if kind == "all-reduce":
            wire = 2.0 * result_bytes * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            wire = result_bytes * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            wire = result_bytes * (n - 1)  # result is the scattered shard
        elif kind == "all-to-all":
            wire = result_bytes * (n - 1) / max(n, 1)
        else:  # collective-permute: point-to-point
            wire = result_bytes
        s = by_kind[kind]
        s[0] += 1
        s[1] += result_bytes
        s[2] += wire
    total_wire = sum(v[2] for v in by_kind.values())
    total_res = sum(v[1] for v in by_kind.values())
    return CollectiveStats(dict(by_kind), total_wire, total_res)


def remat_duplication(hlo_text: str) -> float:
    """Heuristic recompute indicator: ratio of dot/convolution op count to
    unique dot shapes (remat re-emits identical dots)."""
    dots = re.findall(r" = (.+?) dot\(", hlo_text)
    if not dots:
        return 1.0
    return len(dots) / max(1, len(set(dots)))
