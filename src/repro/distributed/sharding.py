"""Parameter / activation / cache sharding rules (FSDP × TP × EP × CP).

Strategy (DESIGN.md §5):
- 'model' axis: Megatron tensor parallelism — heads, d_ff, vocab, experts.
- 'data' axis: batch data-parallel AND ZeRO-3 parameter/optimizer sharding
  ("fsdp" logical axis).  XLA inserts per-layer all-gathers inside the layer
  scan; the latency-hiding scheduler overlaps them with compute.
- 'pod' axis: pure data parallelism across pods (gradient all-reduce over
  DCN), parameters replicated per pod.
- long-context decode cells re-map "kv_seq" -> 'data' (context parallelism).

Rules are name-based over the param-tree path, shape-checked, with an
automatic leading-axis pad for layer-stacked leaves.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.ctx import resolve_spec, sharding_ctx

# (regex over path, logical spec per trailing dims)
_PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding.  embed d-dim must NOT be sharded over the
    # batch ('data') axis: a data-sharded lookup from a d-over-'data' table
    # makes SPMD replicate the full global batch (f32!) before the gather
    # (§Perf cell A it5) — vocab-parallel only, Megatron style.
    (r"embed$",            ("vocab", None)),
    (r"lm_head$",          ("fsdp", "vocab")),
    (r"pos_dec$",          (None, "fsdp")),
    # attention.  wk/wv out-dims are (kv_heads*hd) and NO arch in the pool
    # has kv_heads divisible by model=16 — flat-sharding them splits single
    # heads across devices and forces a full KV-cache reshard (all-gather of
    # the whole cache) every layer; replicate instead (§Perf cell B it2).
    (r"attn/wq$",          ("fsdp", "heads")),
    (r"attn/w[kv]$",       ("fsdp", None)),
    (r"attn/wo$",          ("heads", "fsdp")),
    (r"attn/bq$",          ("heads",)),
    (r"attn/b[kv]$",       (None,)),
    (r"attn/(q_norm|k_norm)$", (None,)),
    # MLA
    (r"attn/w_dkv$",       ("fsdp", None)),
    (r"attn/w_krope$",     ("fsdp", None)),
    (r"attn/w_dq$",        ("fsdp", None)),
    (r"attn/w_u[kvq]$",    (None, "heads")),
    (r"attn/kv_norm$",     (None,)),
    # dense MLP
    (r"mlp/w_(up|gate)$",  ("fsdp", "ff")),
    (r"mlp/w_down$",       ("ff", "fsdp")),
    # MoE
    (r"moe/router$",       ("fsdp", None)),
    (r"moe/w_(up|gate)$",  ("expert", "fsdp", None)),
    (r"moe/w_down$",       ("expert", None, "fsdp")),
    (r"moe/shared/w_(up|gate)$", ("fsdp", "ff")),
    (r"moe/shared/w_down$", ("ff", "fsdp")),
    # mamba
    (r"mamba/w_in$",       ("fsdp", "ff")),
    (r"mamba/w_out$",      ("ff", "fsdp")),
    (r"mamba/conv_[wb]$",  None),             # tiny; replicate
    (r"mamba/(A_log|D|dt_bias|norm)$", None),
    # rwkv
    (r"w_(r|k|v|g|ck|cr)$", ("fsdp", "ff")),
    (r"w_(o|cv)$",         ("ff", "fsdp")),
    (r"w_lora_[ab]$",      None),
    (r"(mu_\w+|w0|u|ln_x|ln1|ln2)$", None),
    # norms & defaults
    (r"ln_\w+$",           None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_logical_spec(path: str, ndim: int) -> tuple:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            if spec is None:
                return (None,) * ndim
            if len(spec) < ndim:   # layer-stacked leading axes -> replicated
                return (None,) * (ndim - len(spec)) + tuple(spec)
            return tuple(spec)
    return (None,) * ndim


def _clean_spec(shape, spec, mesh: Mesh) -> P:
    """Divisibility + uniqueness guard: drop sharding on non-divisible dims
    (GSPMD can pad, but padded matmul dims waste flops and uneven shardings
    trigger involuntary full rematerialisation), and let a mesh axis shard
    at most one dim (first dim wins)."""
    clean = []
    used: set = set()
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            clean.append(None)
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        names = tuple(a for a in names if a not in used)
        size = int(np.prod([mesh.shape[a] for a in names])) if names else 0
        if not names or dim % size:
            clean.append(None)
        else:
            used.update(names)
            clean.append(names if len(names) > 1 else names[0])
    return P(*clean)


def param_specs(params, mesh: Mesh, rules: dict | None = None):
    """Pytree of NamedShardings matching `params` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        ps = _path_str(path)
        logical = param_logical_spec(ps, leaf.ndim)
        with sharding_ctx(mesh, rules):
            spec = resolve_spec(*logical)
        return NamedSharding(mesh, _clean_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------

def batch_specs(batch, mesh: Mesh, rules: dict | None = None):
    def one(path, leaf):
        name = _path_str(path)
        with sharding_ctx(mesh, rules):
            if name.endswith("positions") and leaf.ndim == 3:
                spec = resolve_spec(None, "batch", "seq")
            elif leaf.ndim >= 3:   # embeds / frames (B, S, d)
                spec = resolve_spec("batch", "seq", *([None] * (leaf.ndim - 2)))
            elif leaf.ndim == 2:   # tokens / labels
                spec = resolve_spec("batch", "seq")
            else:
                spec = P()
        return NamedSharding(mesh, _clean_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cache, mesh: Mesh, rules: dict | None = None):
    """KV / state cache shardings.  Heads on 'model'; kv_seq optionally on
    'data' (context parallelism for B=1 long-context decode)."""
    def one(path, leaf):
        name = _path_str(path)
        with sharding_ctx(mesh, rules):
            if re.search(r"(^|/)(k|v|self_k|self_v|cross_k|cross_v)$", name) \
                    and leaf.ndim == 5:
                # (L, B, S, H, hd)
                spec = resolve_spec(None, "batch", "kv_seq", "kv_heads", None)
            elif re.search(r"c_kv$", name):
                spec = resolve_spec(None, "batch", "kv_seq", None)
            elif re.search(r"k_rope$", name):
                spec = resolve_spec(None, "batch", "kv_seq", None)
            elif re.search(r"ssm$", name) and leaf.ndim == 5:
                # (L, B, nh, hd, ds)
                spec = resolve_spec(None, "batch", "heads", None, None)
            elif re.search(r"wkv$", name) and leaf.ndim == 5:
                spec = resolve_spec(None, "batch", "heads", None, None)
            elif re.search(r"conv$", name) and leaf.ndim == 4:
                spec = resolve_spec(None, "batch", None, "ff")
            elif re.search(r"(shift_a|shift_c)$", name) and leaf.ndim == 3:
                spec = resolve_spec(None, "batch", None)
            else:
                spec = P()
        return NamedSharding(mesh, _clean_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def opt_state_specs(opt_state, params_specs, mesh: Mesh):
    """Optimizer slots shard exactly like their parameters (ZeRO)."""
    flat_ps = {_path_str(p): s for p, s in
               jax.tree_util.tree_flatten_with_path(params_specs)[0]}

    def one(path, leaf):
        name = _path_str(path)
        # match trailing param path inside the slot path (m/..., v/...)
        for ppath, spec in flat_ps.items():
            if name.endswith(ppath) and spec.spec is not None and \
                    len(spec.spec) == leaf.ndim:
                return spec
        # adafactor factored slots & scalars: replicate
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_state)
