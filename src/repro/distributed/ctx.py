"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names; the launcher
installs a mesh + rule set mapping logical names to mesh axes.  Outside any
mesh context the annotations are no-ops, so the same model code runs on a
laptop and on a 512-chip two-pod mesh.

Rules are intentionally a mutable dict — the §Perf hillclimb flips entries
(e.g. ``"kv_seq": "data"`` to turn on context parallelism) and re-lowers.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar[Optional[tuple[Mesh, dict]]] = \
    contextvars.ContextVar("repro_sharding_ctx", default=None)

# default logical-axis rules; tuple values mean "sharded over several axes"
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),   # axes absent from the mesh are dropped
    "seq": None,
    "kv_seq": None,             # flipped to "data" for long-context decode
    "model": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "embed": None,
    "expert": "model",
    # expert-slot axis of the MoE dispatch (E, slots, d): the factors of the
    # token sharding NOT consumed by the expert axis — keeps expert GEMMs
    # fully local after the EP all-to-all (§Perf cell A)
    "moe_slots": ("pod", "data"),
    "fsdp": "data",             # parameter sharding axis (ZeRO-3)
    # signature-stack axes (repro.kernels.ops mesh path): the time axis of a
    # path and the word-coordinate axis of a signature are never sharded by
    # default — the engines scan over time and the word basis is the unit of
    # kernel tiling.  They exist as logical names so rules can annotate them
    # (with_sharding_constraint) without touching the SPMD batch split.
    "path_time": None,
    "sig_words": None,
}


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: dict | None = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    token = _CTX.set((mesh, merged))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return ctx[0] if ctx else None


def current_rules() -> Optional[dict]:
    """The merged rule dict of the innermost context (None outside any)."""
    ctx = _CTX.get()
    return ctx[1] if ctx else None


def logical_axes(logical: str) -> tuple[str, ...]:
    """Mesh axis names a logical axis maps to under the current context
    (() outside any context, when the rule is None, or when no mapped axis
    is present in the mesh)."""
    ctx = _CTX.get()
    if ctx is None:
        return ()
    mesh, rules = ctx
    r = rules.get(logical)
    if r is None:
        return ()
    names = (r,) if isinstance(r, str) else tuple(r)
    return tuple(a for a in names if a in set(mesh.axis_names))


def logical_axis_size(logical: str) -> int:
    """Total number of shards of a logical axis under the current context
    (1 outside any context / when unmapped)."""
    ctx = _CTX.get()
    if ctx is None:
        return 1
    mesh = ctx[0]
    size = 1
    for a in logical_axes(logical):
        size *= mesh.shape[a]
    return size


def resolve_spec(*logical: Optional[str]) -> Optional[P]:
    """Logical axis names -> PartitionSpec under the current rules."""
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    names = set(mesh.axis_names)
    dims = []
    for l in logical:
        if l is None:
            dims.append(None)
            continue
        r = rules.get(l)
        if r is None:
            dims.append(None)
        elif isinstance(r, tuple):
            use = tuple(a for a in r if a in names)
            dims.append(use if use else None)
        else:
            dims.append(r if r in names else None)
    return P(*dims)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the current logical rules (no-op
    when no mesh is installed).  Dims not divisible by their axis product are
    left unconstrained — uneven shardings trigger involuntary full
    rematerialisation in the SPMD partitioner."""
    spec = resolve_spec(*logical)
    ctx = _CTX.get()
    if spec is None or ctx is None:
        return x
    mesh = ctx[0]
    clean = []
    used: set = set()   # a mesh axis may shard at most one dim; first wins
    for dim, axes in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axes is None:
            clean.append(None)
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        names = tuple(a for a in names if a not in used)
        if not names:
            clean.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if dim % size:
            clean.append(None)
        else:
            used.update(names)
            clean.append(names if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*clean)))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    return NamedSharding(ctx[0], resolve_spec(*logical))
