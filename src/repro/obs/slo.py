"""Declarative SLOs evaluated against metric snapshots, plain value dicts,
and JSONL run logs.

An :class:`Slo` names one metric, an objective, and how to reduce the
observations (``p99``/``max``/``sum``/...).  Three evaluation surfaces:

- :func:`evaluate_values` — a flat ``{name: value}`` dict.  This is what
  ``SessionStore.health()`` / ``DynamicBatcher.health()`` use: they
  evaluate their own host-side ``stats()``, so health works even when the
  :mod:`repro.obs.metrics` registry is disabled.
- :func:`evaluate_snapshot` — a ``Registry.snapshot()`` dict, with label
  filtering and ``group_by`` (e.g. retrace budget *per site*: every site
  is checked, the worst one is reported).
- :func:`evaluate_log` — JSONL run-log rows (the train loop's default
  sink) over a trailing window, with budgeted *burn-rate* evaluation: the
  SLO breaches when the fraction of violating samples exceeds ``budget``
  (``budget=0`` reduces the window and checks the reduced value).

Default bundles cover the stack's known failure modes: serve-flush p99
latency and session staleness, per-site retrace budgets, plan-cache
eviction pressure, and train-step latency / grad-norm spikes.  This
module imports nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import dataclasses
import json
import math

__all__ = [
    "Slo", "SloResult", "SloBreach", "evaluate_values",
    "evaluate_snapshot", "evaluate_log", "breached", "report",
    "default_slos", "session_slos", "batcher_slos", "train_slos",
]

_OPS = {
    "<=": lambda v, o: v <= o,
    ">=": lambda v, o: v >= o,
    "<": lambda v, o: v < o,
    ">": lambda v, o: v > o,
}

_REDUCERS = ("value", "sum", "max", "min", "p50", "p99")


class SloBreach(RuntimeError):
    """Raised by abort-mode SLO enforcement (``train_loop``)."""


@dataclasses.dataclass(frozen=True)
class Slo:
    """One objective: ``reducer(metric observations) op objective``.

    ``labels`` filters snapshot rows (tuple of ``(name, value)`` pairs);
    ``group_by`` evaluates per value of that label and reports the worst
    group; ``budget`` switches log evaluation to burn-rate mode (allowed
    violating fraction of the window)."""

    name: str
    metric: str
    objective: float
    op: str = "<="
    reducer: str = "value"
    labels: tuple = ()
    group_by: str = ""
    budget: float = 0.0
    description: str = ""

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"slo {self.name!r}: op {self.op!r} not in "
                             f"{sorted(_OPS)}")
        if self.reducer not in _REDUCERS:
            raise ValueError(f"slo {self.name!r}: reducer {self.reducer!r} "
                             f"not in {_REDUCERS}")

    def holds(self, value: float) -> bool:
        return _OPS[self.op](value, self.objective)


@dataclasses.dataclass(frozen=True)
class SloResult:
    slo: Slo
    status: str                 # ok | breach | no_data
    observed: float | None = None
    detail: str = ""
    burn_rate: float = 0.0

    @property
    def breached(self) -> bool:
        return self.status == "breach"

    def to_json(self) -> dict:
        return {"name": self.slo.name, "metric": self.slo.metric,
                "objective": self.slo.objective, "op": self.slo.op,
                "status": self.status, "observed": self.observed,
                "detail": self.detail, "burn_rate": self.burn_rate}


def _pctl(vals: list, q: float) -> float:
    s = sorted(vals)
    i = max(0, min(len(s) - 1, math.ceil(q / 100.0 * len(s)) - 1))
    return s[i]


def _reduce(vals: list, reducer: str) -> float:
    if reducer == "sum":
        return sum(vals)
    if reducer == "min":
        return min(vals)
    if reducer == "p50":
        return _pctl(vals, 50)
    if reducer == "p99":
        return _pctl(vals, 99)
    return max(vals)            # "max" and "value" (last-wins ~ worst-wins)


def _result(slo: Slo, observed, detail: str = "") -> SloResult:
    if observed is None:
        return SloResult(slo, "no_data", None, detail)
    status = "ok" if slo.holds(observed) else "breach"
    return SloResult(slo, status, observed, detail)


# ---------------------------------------------------------------------------
# evaluation surfaces
# ---------------------------------------------------------------------------

def evaluate_values(slos, values) -> list[SloResult]:
    """Evaluate against a flat ``{metric: value}`` mapping (host-side
    ``stats()`` dicts).  Metrics absent from the dict yield ``no_data``."""
    out = []
    for slo in slos:
        v = values.get(slo.metric)
        try:
            v = None if v is None else float(v)
        except (TypeError, ValueError):
            v = None
        if v is not None and not math.isfinite(v):
            # a non-finite observation can never satisfy a finite objective
            out.append(SloResult(slo, "breach", v, "non-finite"))
            continue
        out.append(_result(slo, v))
    return out


def _snapshot_rows(snap: dict, metric: str):
    m = snap.get("metrics", {}).get(metric)
    if m is None:
        return None, []
    return m.get("type", "untyped"), m.get("values", [])


def _row_value(row: dict, kind: str, reducer: str):
    if kind == "histogram":
        field = reducer if reducer in ("p50", "p99", "min", "max",
                                       "sum") else "p99"
        if row.get("count", 0) == 0:
            return None
        return row.get(field)
    return row.get("value")


def evaluate_snapshot(slos, snap: dict) -> list[SloResult]:
    """Evaluate against ``Registry.snapshot()``.  Histogram rows already
    carry p50/p99/min/max/sum; counter/gauge rows carry ``value`` and are
    combined across label sets by the reducer."""
    out = []
    for slo in slos:
        kind, rows = _snapshot_rows(snap, slo.metric)
        if kind is None:
            out.append(_result(slo, None))
            continue
        want = dict(slo.labels)
        rows = [r for r in rows
                if all(r.get("labels", {}).get(k) == v
                       for k, v in want.items())]
        groups: dict[str, list] = {}
        for r in rows:
            g = str(r.get("labels", {}).get(slo.group_by, "")) \
                if slo.group_by else ""
            v = _row_value(r, kind, slo.reducer)
            if v is not None:
                groups.setdefault(g, []).append(float(v))
        if not groups:
            out.append(_result(slo, None))
            continue
        worst_g, worst_v = None, None
        for g, vals in groups.items():
            v = (_reduce(vals, slo.reducer) if kind != "histogram"
                 else max(vals))   # per-row reducer already applied
            keep = worst_v is None or (
                v < worst_v if slo.op in (">=", ">") else v > worst_v)
            if keep:
                worst_g, worst_v = g, v
        detail = f"{slo.group_by}={worst_g}" if slo.group_by else ""
        out.append(_result(slo, worst_v, detail))
    return out


def evaluate_log(slos, rows, *, window: int = 100) -> list[SloResult]:
    """Evaluate against JSONL run-log rows (a path or an iterable of
    dicts) over the trailing ``window``.  With ``budget > 0`` the SLO
    breaches when the violating *fraction* of the window exceeds the
    budget; ``burn_rate`` is fraction/budget (1.0 = exactly on budget)."""
    if isinstance(rows, str):
        parsed = []
        with open(rows) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        parsed.append(json.loads(line))
                    except ValueError:
                        continue
        rows = parsed
    rows = list(rows)[-window:]
    out = []
    for slo in slos:
        vals = []
        for r in rows:
            v = r.get(slo.metric)
            try:
                v = None if v is None else float(v)
            except (TypeError, ValueError):
                v = None
            if v is not None and math.isfinite(v):
                vals.append(v)
        if not vals:
            out.append(_result(slo, None))
            continue
        reduced = _reduce(vals, slo.reducer)
        frac = sum(1 for v in vals if not slo.holds(v)) / len(vals)
        detail = f"{len(vals)} samples, {frac:.1%} violating"
        if slo.budget > 0:
            burn = frac / slo.budget
            status = "breach" if frac > slo.budget else "ok"
            out.append(SloResult(slo, status, reduced, detail, burn))
        else:
            res = _result(slo, reduced, detail)
            out.append(dataclasses.replace(
                res, burn_rate=math.inf if frac and res.breached else frac))
    return out


def breached(results) -> list[SloResult]:
    return [r for r in results if r.breached]


def report(results) -> dict:
    """Machine-readable health report: overall status + per-SLO rows."""
    rows = [r.to_json() for r in results]
    bad = [r for r in results if r.breached]
    return {"status": "breach" if bad else "ok",
            "breaches": [r.slo.name for r in bad],
            "results": rows}


# ---------------------------------------------------------------------------
# default bundles
# ---------------------------------------------------------------------------

def session_slos(*, p99_staleness_s: float = 0.25,
                 occupancy: float = 0.98,
                 compiled_shapes: float = 64) -> tuple:
    """Host-side bundle for ``SessionStore.health()`` (keys from
    ``SessionStore.stats()``)."""
    return (
        Slo("sessions_p99_staleness", "p99_staleness_s", p99_staleness_s,
            description="p99 enqueue→flush staleness stays under the "
                        "serving freshness target"),
        Slo("sessions_occupancy", "occupancy", occupancy,
            description="pool occupancy below the eviction-thrash point"),
        Slo("sessions_compiled_shapes", "compiled_shapes", compiled_shapes,
            description="flush rung shapes stay bounded (plan-cache "
                        "friendly)"),
    )


def batcher_slos(*, flush_p99_s: float = 1.0,
                 padding_overhead: float = 8.0,
                 compiled_shapes: float = 64) -> tuple:
    """Host-side bundle for ``DynamicBatcher.health()`` (keys from
    ``DynamicBatcher.stats()`` plus the recent-flush p99)."""
    return (
        Slo("batcher_flush_p99", "flush_p99_s", flush_p99_s,
            description="p99 flush wall-clock under the latency target"),
        Slo("batcher_padding_overhead", "padding_overhead",
            padding_overhead,
            description="bucketing keeps padded/real work bounded"),
        Slo("batcher_compiled_shapes", "compiled_shapes", compiled_shapes,
            description="rung ladder keeps compiled shapes bounded"),
    )


def train_slos(*, step_p99_s: float = 30.0,
               grad_norm_max: float = 1e3) -> tuple:
    """Bundle the train loop evaluates over its trailing step window."""
    return (
        Slo("train_step_p99", "step_p99_s", step_p99_s,
            description="p99 step wall-clock (straggler/retrace spikes)"),
        Slo("train_grad_norm_spike", "grad_norm_max", grad_norm_max,
            description="gradient norm stays under the blow-up threshold"),
        Slo("train_loss_finite", "loss_finite", 1.0, op=">=",
            description="loss is finite (NaN/Inf divergence guard)"),
    )


def default_slos(*, retrace_budget: float = 32,
                 plan_cache_evictions: float = 1000,
                 staleness_p99_s: float = 0.25,
                 flush_p99_s: float = 1.0,
                 step_p99_s: float = 30.0) -> tuple:
    """Registry-snapshot bundle covering the whole stack — evaluate with
    ``evaluate_snapshot(default_slos(), obs.snapshot())``."""
    return (
        Slo("retrace_budget_per_site", "pathsig_jit_traces_total",
            retrace_budget, reducer="sum", group_by="site",
            description="jit retraces per instrumented site stay bounded"),
        Slo("plan_cache_evictions", "pathsig_plan_cache",
            plan_cache_evictions, reducer="max", group_by="cache",
            labels=(("stat", "evictions"),),
            description="plan caches are not thrashing"),
        Slo("sessions_staleness_p99",
            "pathsig_sessions_staleness_seconds", staleness_p99_s,
            reducer="p99",
            description="session enqueue→flush staleness p99"),
        Slo("batcher_flush_p99", "pathsig_batcher_flush_seconds",
            flush_p99_s, reducer="p99",
            description="batcher flush latency p99"),
        Slo("train_step_p99", "pathsig_train_step_seconds", step_p99_s,
            reducer="p99",
            description="train step latency p99"),
    )
