"""Span tracer exporting Chrome-trace / Perfetto JSON.

A *span* is a named wall-clock interval with optional key/value args.  The
tracer buffers complete events in memory and writes the standard Chrome
trace-event JSON object (``{"traceEvents": [...]}``, timestamps in µs) that
``chrome://tracing`` and https://ui.perfetto.dev load directly.

Usage::

    from repro import obs

    obs.start_trace("trace.json")          # or PATHSIG_TRACE=trace.json
    with obs.span("serve.flush", rungs=3):
        ...
    obs.stop_trace()                       # writes + returns the path

Design rules (mirroring :mod:`repro.obs.metrics`):

- **Disabled costs one flag check.** ``span()`` returns a shared null
  context manager when no trace is active, so instrumented code paths pay
  ~an attribute lookup when tracing is off.
- **Nesting is implicit.** Spans emit Chrome "complete" (``ph: "X"``)
  events on one thread-id track; the viewer reconstructs the stack from
  containment.  A thread-local depth counter is recorded in ``args.depth``
  so tests (and offline tooling) can assert nesting without a viewer.
- **jit-friendly.** Spans measure *host* wall-clock; device work launched
  asynchronously inside a span is attributed to it only up to dispatch.
  Pass ``block=jax_array`` to :meth:`Span.done` — or use
  :func:`span_blocked` — to include device completion.  With
  ``PATHSIG_TRACE_JAX=1`` each span also enters ``jax.profiler.TraceAnnotation``
  so the same names show up inside XLA's own profiler timeline.

- **Bounded buffer.** The in-memory event list is a ring of
  ``PATHSIG_TRACE_MAX_EVENTS`` (default 100000) most-recent events; on a
  long traced run the oldest events are evicted and counted in
  ``Tracer.dropped`` / the ``pathsig_trace_events_dropped_total`` metric,
  and the save-at-exit still writes whatever the ring holds.

``PATHSIG_TRACE=<path>`` starts tracing at import and registers an atexit
save to ``<path>``.
"""
from __future__ import annotations

import atexit
import collections
import json
import os
import threading
import time

from . import metrics as _metrics

__all__ = [
    "Tracer", "TRACER", "span", "span_blocked", "instant",
    "start_trace", "stop_trace", "trace_active", "trace_scope",
    "DEFAULT_MAX_EVENTS",
]

_PID = os.getpid()

DEFAULT_MAX_EVENTS = 100_000

DROP_COUNTER_NAME = "pathsig_trace_events_dropped_total"


def _env_max_events() -> int:
    raw = os.environ.get("PATHSIG_TRACE_MAX_EVENTS", "").strip()
    try:
        n = int(raw) if raw else DEFAULT_MAX_EVENTS
    except ValueError:
        n = DEFAULT_MAX_EVENTS
    return max(1, n)


class _NullSpan:
    """Shared do-nothing context manager: the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):      # same surface as Span
        return self


_NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("_tracer", "name", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._depth = 0

    def set(self, **args) -> "Span":
        """Attach/update args after entry (e.g. results known at exit)."""
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        tr = self._tracer
        self._depth = tr._enter_depth()
        if tr._jax_ann is not None:
            ann = tr._jax_ann(self.name)
            ann.__enter__()
            tr._ann_stack_local().append(ann)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tracer
        if tr._jax_ann is not None:
            stack = tr._ann_stack_local()
            if stack:
                stack.pop().__exit__(*exc)
        tr._exit_depth()
        tr._emit(self.name, self._t0, t1, self._depth, self.args)
        return False


class Tracer:
    """Buffers Chrome trace events; one per process (:data:`TRACER`)."""

    def __init__(self, max_events: int | None = None):
        self._active = False
        self._path: str | None = None
        self._max_events = _env_max_events() if max_events is None \
            else max(1, int(max_events))
        self._events: collections.deque = collections.deque(
            maxlen=self._max_events)
        self._lock = threading.Lock()
        self._epoch = 0.0
        self._local = threading.local()
        self._jax_ann = None       # jax.profiler.TraceAnnotation when bridged
        self._flight = None        # repro.obs.flight ring (always-on sink)
        self._record = False       # := _active or _flight is not None
        self.dropped = 0           # ring evictions since last reset

    # -- lifecycle ---------------------------------------------------------

    @property
    def active(self) -> bool:
        return self._active

    def _update_record(self) -> None:
        self._record = self._active or self._flight is not None

    def set_flight(self, recorder) -> None:
        """Attach/detach the flight-recorder ring — spans keep feeding it
        even when no trace file is active."""
        self._flight = recorder
        self._update_record()

    def start(self, path: str | None = None, *, jax_bridge: bool = False,
              reset: bool = True) -> None:
        with self._lock:
            if reset:
                self._events.clear()
                self.dropped = 0
            self._path = path
            self._epoch = time.perf_counter()
            if jax_bridge:
                try:
                    import jax.profiler
                    self._jax_ann = jax.profiler.TraceAnnotation
                except Exception:
                    self._jax_ann = None
            else:
                self._jax_ann = None
            self._active = True
            self._update_record()

    def stop(self, path: str | None = None) -> str | None:
        """Deactivate and, when a path is known, write the JSON file.
        Returns the written path (None if nothing was written)."""
        with self._lock:
            self._active = False
            self._update_record()
            out = path or self._path
        if out:
            self.save(out)
        return out

    def save(self, path: str) -> str:
        """Write buffered events as Chrome trace JSON (tracer may still be
        active; events keep accumulating)."""
        with self._lock:
            doc = {
                "traceEvents": list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs.trace",
                              "events_dropped": self.dropped,
                              "max_events": self._max_events},
            }
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return path

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    @property
    def events(self) -> list[dict]:
        """Snapshot of buffered events (tests/tooling)."""
        with self._lock:
            return list(self._events)

    # -- emission ----------------------------------------------------------

    def _enter_depth(self) -> int:
        d = getattr(self._local, "depth", 0)
        self._local.depth = d + 1
        return d

    def _exit_depth(self) -> None:
        self._local.depth = max(0, getattr(self._local, "depth", 1) - 1)

    def _ann_stack_local(self) -> list:
        st = getattr(self._local, "ann_stack", None)
        if st is None:
            st = self._local.ann_stack = []
        return st

    def _append(self, ev: dict) -> None:
        dropped = False
        with self._lock:
            if len(self._events) == self._max_events:
                self.dropped += 1       # deque(maxlen) evicts the oldest
                dropped = True
            self._events.append(ev)
        if dropped:
            _metrics.counter(
                DROP_COUNTER_NAME,
                "trace events evicted from the bounded ring "
                "(PATHSIG_TRACE_MAX_EVENTS)").inc()

    def _emit(self, name, t0, t1, depth, args) -> None:
        fl = self._flight
        if fl is not None:
            fl.record_span(name, t0, t1, depth, args)
        if not self._active:
            return
        self._append({
            "name": name,
            "ph": "X",
            "ts": (t0 - self._epoch) * 1e6,
            "dur": (t1 - t0) * 1e6,
            "pid": _PID,
            "tid": threading.get_ident() & 0xFFFF,
            "args": {"depth": depth, **args},
        })

    def _emit_instant(self, name, args) -> None:
        fl = self._flight
        if fl is not None:
            fl.record_instant(name, args)
        if not self._active:
            return
        self._append({
            "name": name,
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": _PID,
            "tid": threading.get_ident() & 0xFFFF,
            "args": dict(args),
        })

    # -- user API ----------------------------------------------------------

    def span(self, name: str, **args):
        if not self._record:
            return _NULL_SPAN
        return Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        if not self._record:
            return
        self._emit_instant(name, args)


TRACER = Tracer()


def span(name: str, **args):
    """``with obs.span("kernels.signature", backend="pallas"):`` — null
    context manager when neither a trace nor the flight recorder is
    active."""
    if not TRACER._record:
        return _NULL_SPAN
    return Span(TRACER, name, args)


def span_blocked(name: str, fn, *fn_args, **span_args):
    """Run ``fn(*fn_args)`` inside a span and ``block_until_ready`` the
    result so device time lands in the span.  Returns fn's result."""
    if not TRACER._record:
        return fn(*fn_args)
    with TRACER.span(name, **span_args):
        out = fn(*fn_args)
        try:
            import jax
            jax.block_until_ready(out)
        except Exception:
            pass
    return out


def instant(name: str, **args) -> None:
    TRACER.instant(name, **args)


def start_trace(path: str | None = None, *, jax_bridge: bool = False,
                reset: bool = True) -> None:
    TRACER.start(path, jax_bridge=jax_bridge, reset=reset)


def stop_trace(path: str | None = None) -> str | None:
    return TRACER.stop(path)


def trace_active() -> bool:
    return TRACER._active


class trace_scope:
    """``with obs.trace_scope("t.json"):`` — start on entry, stop+write on
    exit.  Used by tests and ``benchmarks/run.py``."""

    def __init__(self, path: str | None = None, *, jax_bridge: bool = False):
        self._path = path
        self._jax = jax_bridge

    def __enter__(self) -> Tracer:
        TRACER.start(self._path, jax_bridge=self._jax)
        return TRACER

    def __exit__(self, *exc):
        TRACER.stop()
        return False


_ENV_TRACE = os.environ.get("PATHSIG_TRACE", "").strip()
if _ENV_TRACE:
    TRACER.start(
        _ENV_TRACE,
        jax_bridge=os.environ.get("PATHSIG_TRACE_JAX", "").strip()
        in ("1", "on", "true"))
    atexit.register(TRACER.stop)
