"""Compile / retrace accounting and lowered-cost helpers.

The central trick: the Python body of a ``jax.jit``-wrapped function runs
exactly once per compiled variant (one trace per new static/shape
signature), so a counter incremented at the top of the jitted body *is* the
compile/retrace counter.  Two entry points use it:

- :func:`instrument_jit` — drop-in replacement for ``jax.jit(fn, **kw)``
  that wires the counting body in; labels the counter with a compact
  shape key of the offending call so a retrace storm names its cause.
- :func:`count_trace` — one line placed inside an already-jitted body
  (module-level kernels like ``sig_trunc``) when rebuilding the jit wrapper
  isn't practical.

Both route through the ``pathsig_jit_traces_total`` counter of the global
registry, labelled ``(site, shapes)``.  Tracing-time work is off the
execution hot path by construction — a trace happens once per variant —
so these are safe even at full metric volume.

Cost helpers (:func:`record_cost`, :func:`record_collectives`) publish
lowered-cost gauges from ``Compiled.cost_analysis()`` and collective
counters from :func:`repro.distributed.hlo.collective_stats`.  They compile
(AOT) on purpose, so they are opt-in: benchmarks and the observability
example call them; the dispatch hot path does not.
"""
from __future__ import annotations

import functools

from . import metrics

__all__ = [
    "shape_key", "count_trace", "instrument_jit", "record_cost",
    "record_collectives", "TRACE_COUNTER_NAME", "set_retrace_sink",
]

TRACE_COUNTER_NAME = "pathsig_jit_traces_total"

# repro.obs.flight mirror: (site, shape_key) per trace, fed even when the
# registry is disabled — compiles are rare, and the last-N retrace keys
# are the flight recorder's most useful breadcrumb
_RETRACE_SINK = None


def set_retrace_sink(fn) -> None:
    global _RETRACE_SINK
    _RETRACE_SINK = fn


def _trace_counter() -> metrics.Counter:
    return metrics.counter(
        TRACE_COUNTER_NAME,
        "jit traces (== compiles) per site, labelled with the shape key "
        "that caused the trace", ("site", "shapes"))


def shape_key(*xs, **kxs) -> str:
    """Compact, stable description of argument shapes/dtypes — the label a
    retrace counter carries so the offending signature is visible.

    Arrays render as ``f32[32,100,6]``; pytrees recurse; everything else
    falls back to ``repr`` truncated to keep label cardinality sane.
    """
    parts = [_describe(x) for x in xs]
    parts += [f"{k}={_describe(v)}" for k, v in sorted(kxs.items())]
    return ",".join(parts)


def _describe(x) -> str:
    shape = getattr(x, "shape", None)
    dtype = getattr(x, "dtype", None)
    if shape is not None and dtype is not None:
        return f"{_short_dtype(dtype)}[{','.join(map(str, shape))}]"
    if isinstance(x, (list, tuple)):
        inner = ",".join(_describe(v) for v in x[:4])
        if len(x) > 4:
            inner += ",..."
        return f"({inner})"
    if isinstance(x, dict):
        inner = ",".join(f"{k}:{_describe(v)}"
                         for k, v in sorted(x.items())[:4])
        return f"{{{inner}}}"
    r = repr(x)
    return r if len(r) <= 24 else r[:21] + "..."


def _short_dtype(dtype) -> str:
    s = str(dtype)
    return (s.replace("float", "f").replace("int", "i").replace("uint", "u")
            .replace("complex", "c").replace("bool", "pred"))


def count_trace(site: str, *xs, **kxs) -> None:
    """Tick the retrace counter for ``site``.  Call at the top of a jitted
    body: it runs once per compiled variant, so ticks == compiles.  No-op
    when metrics are disabled and no flight recorder is attached."""
    sink = _RETRACE_SINK
    if not metrics.REGISTRY._enabled and sink is None:
        return
    key = shape_key(*xs, **kxs)
    if sink is not None:
        sink(site, key)
    if metrics.REGISTRY._enabled:
        _trace_counter().inc(site=site, shapes=key)


def instrument_jit(fn, *, site: str, **jit_kw):
    """``jax.jit`` with retrace accounting: returns a jitted callable whose
    every trace ticks ``pathsig_jit_traces_total{site=...,shapes=...}``.

    The shape label is computed *inside* the traced body (from the tracers'
    abstract shapes), so it costs nothing per execution — only per compile.
    Static args configured via ``jit_kw`` pass through untouched.
    """
    import jax

    @functools.wraps(fn)
    def counted(*args, **kwargs):
        count_trace(site, *args, **kwargs)
        return fn(*args, **kwargs)

    return jax.jit(counted, **jit_kw)


def record_cost(site: str, fn, *args, **kwargs) -> dict:
    """AOT-lower ``fn(*args, **kwargs)`` and publish its lowered cost as
    gauges: ``pathsig_lowered_flops{site=}`` and
    ``pathsig_lowered_bytes{site=}``.  Returns the raw cost dict.

    Compiles (cached by jax's jit cache when fn is already jitted with the
    same signature) — opt-in for benchmarks/examples, not the hot path.
    """
    import jax

    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    try:
        compiled = jfn.lower(*args, **kwargs).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0] if ca else {}
    except Exception:
        ca = {}
    flops = float(ca.get("flops", 0.0) or 0.0)
    nbytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    metrics.gauge("pathsig_lowered_flops",
                  "XLA cost_analysis flops of the lowered computation",
                  ("site",)).set(flops, site=site)
    metrics.gauge("pathsig_lowered_bytes",
                  "XLA cost_analysis bytes accessed of the lowered "
                  "computation", ("site",)).set(nbytes, site=site)
    return {"flops": flops, "bytes": nbytes, "raw": ca}


def record_collectives(site: str, stats) -> None:
    """Publish a :class:`repro.distributed.hlo.CollectiveStats` (from
    ``collective_stats(hlo_text)``) as per-kind counters:
    ``pathsig_hlo_collectives_total{site=,kind=}`` plus wire-byte totals."""
    c = metrics.counter(
        "pathsig_hlo_collectives_total",
        "collective op count in lowered HLO", ("site", "kind"))
    b = metrics.counter(
        "pathsig_hlo_collective_wire_bytes_total",
        "wire bytes moved by collectives in lowered HLO", ("site", "kind"))
    for kind, (count, _result_bytes, wire_bytes) in stats.by_kind.items():
        c.inc(count, site=site, kind=kind)
        b.inc(wire_bytes, site=site, kind=kind)
