"""Benchmark baseline store + statistical regression gate.

Every benchmark suite writes a differently shaped ``BENCH_*.json``; this
module flattens them all onto one canonical record schema so a committed
baseline directory (``benchmarks/baselines/*.json``) can gate perf in CI:

``Record(suite, key, value, unit, higher_is_better, noise_floor)``

- ``key`` is a stable path-like metric id within the suite
  (``routes/B16_M144_K16_w16_s8_d4_N3/auto_ms``).
- ``unit`` drives the default noise floor (wall-clock units are noisy on
  shared runners, byte/shape counts are exact).
- ``noise_floor`` is a *relative* tolerance.  Extractors seed it from the
  unit default; :func:`aggregate` widens it with the scaled MAD measured
  across ``--reruns K`` repeats, so a metric that is noisy *on this
  machine* gets a wider gate than the unit default alone.

Comparison (:func:`compare`) is against the committed baseline's median:
verdicts are ``ok`` / ``improved`` / ``regressed`` / ``new`` (no baseline
yet) / ``missing`` (baselined metric the current run no longer emits).  A
metric regresses when it is worse than baseline by more than
``max(baseline.noise_floor, current.noise_floor, extra_rel)``.

Suites can opt out of per-shape extractors by emitting the schema natively:
a top-level ``"baseline_records"`` list in their ``BENCH_*.json`` is taken
verbatim (see ``benchmarks/baselines/README.md``).  This module imports
nothing from the rest of :mod:`repro`.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import statistics

__all__ = [
    "SCHEMA_VERSION", "Record", "Verdict", "UNIT_NOISE_FLOORS",
    "unit_floor", "extract_records", "aggregate", "load_baseline",
    "load_baseline_dir", "write_baseline", "compare", "verdict_table",
    "regressions",
]

SCHEMA_VERSION = 1

# Default *relative* noise floors by unit, calibrated across five
# back-to-back uncontended full runs on the CI runner class (shared,
# oversubscribed CPU): any individual wall-clock can land a 2-2.25x
# slow mode run-to-run, so per-metric time gating below 150% trips
# somewhere almost every run.  Same-run ratios partially cancel those
# modes (observed <=32% drift) and stay tighter, byte/shape/coefficient
# counts are deterministic and gate exactly, and relative errors only
# regress on order-of-magnitude blowups (reduction-order jitter is
# harmless).  Tighten the time floors on quiet bare metal.
UNIT_NOISE_FLOORS = {
    "ms": 1.5, "s": 1.5, "req/s": 0.60, "updates/s": 0.60,
    "x": 0.60, "frac": 0.50, "relerr": 1.0,
    "bytes": 0.0, "count": 0.0,
}
_DEFAULT_FLOOR = 0.10          # unknown units
_MAD_SIGMAS = 3.0 * 1.4826     # 3σ gate, MAD→σ for normal noise
_MAX_FLOOR = 2.0               # a floor wider than 200% gates nothing useful


def unit_floor(unit: str) -> float:
    return UNIT_NOISE_FLOORS.get(unit, _DEFAULT_FLOOR)


@dataclasses.dataclass(frozen=True)
class Record:
    """One flat benchmark metric (see module docstring)."""

    suite: str
    key: str
    value: float
    unit: str = ""
    higher_is_better: bool = False
    noise_floor: float = -1.0   # -1 → derive from unit

    def __post_init__(self):
        if self.noise_floor < 0:
            object.__setattr__(self, "noise_floor", unit_floor(self.unit))

    def to_json(self) -> dict:
        return {"key": self.key, "value": float(self.value),
                "unit": self.unit,
                "higher_is_better": bool(self.higher_is_better),
                "noise_floor": round(float(self.noise_floor), 4)}

    @classmethod
    def from_json(cls, suite: str, d: dict) -> "Record":
        return cls(suite=suite, key=str(d["key"]), value=float(d["value"]),
                   unit=str(d.get("unit", "")),
                   higher_is_better=bool(d.get("higher_is_better", False)),
                   noise_floor=float(d.get("noise_floor", -1.0)))


def _rec(suite, key, value, unit, higher=False, floor=-1.0):
    if value is None:
        return None
    v = float(value)
    if not math.isfinite(v):
        return None
    return Record(suite, key, v, unit, higher, floor)


# ---------------------------------------------------------------------------
# per-suite extractors: BENCH_*.json shape -> flat records
# ---------------------------------------------------------------------------

def _extract_table1(suite, doc):
    # Lever before/afters are interpret-mode kernel timings with
    # autotune-dependent bimodality: 2.5-2.7x run-to-run swings on
    # whether the sweep lands good tiles.  Gate only on
    # order-of-magnitude blowups, like the shard wall-clocks.
    out = []
    for lv in doc.get("levers", []):
        k = f"levers/{lv.get('name', '?')}"
        out += [_rec(suite, f"{k}/after_ms", lv.get("after_ms"), "ms",
                     floor=_MAX_FLOOR),
                _rec(suite, f"{k}/speedup", lv.get("speedup"), "x", True,
                     floor=_MAX_FLOOR)]
    return out


def _extract_fig3(suite, doc):
    out = [_rec(suite, "grad_streamed_pallas_vs_oracle_relerr",
                doc.get("grad_streamed_pallas_vs_oracle_relerr"), "relerr")]
    for r in doc.get("records", []):
        k = (f"routes/B{r['B']}_M{r['M']}_K{r['K']}_w{r['wlen']}"
             f"_s{r['stride']}_d{r['d']}_N{r['depth']}")
        out += [_rec(suite, f"{k}/fold_ms", r.get("fold_ms"), "ms"),
                _rec(suite, f"{k}/chen_ms", r.get("chen_ms"), "ms"),
                _rec(suite, f"{k}/auto_ms", r.get("auto_ms"), "ms"),
                _rec(suite, f"{k}/chen_speedup_vs_fold",
                     r.get("chen_speedup_vs_fold"), "x", True),
                _rec(suite, f"{k}/fold_vs_chen_relerr",
                     r.get("fold_vs_chen_relerr"), "relerr")]
    return out


def _extract_gram(suite, doc):
    out = [_rec(suite, "mmd_grad_jax_vs_pallas_relerr",
                doc.get("mmd_grad_jax_vs_pallas_relerr"), "relerr")]
    for r in doc.get("records", []):
        k = f"gram/B{r['B']}_M{r['M']}_d{r['d']}_N{r['depth']}"
        out += [_rec(suite, f"{k}/oracle_ms", r.get("oracle_ms"), "ms"),
                _rec(suite, f"{k}/tiled_jax_ms", r.get("tiled_jax_ms"),
                     "ms"),
                _rec(suite, f"{k}/tiled_backend_ms",
                     r.get("tiled_backend_ms"), "ms"),
                _rec(suite, f"{k}/tiled_vs_oracle_relerr",
                     r.get("tiled_vs_oracle_relerr"), "relerr")]
        for bs in r.get("block_sweep", []):
            out.append(_rec(suite, f"{k}/temp_bytes_bw{bs['block_words']}",
                            bs.get("temp_bytes"), "bytes"))
    return out


def _extract_ragged(suite, doc):
    # Same-run speedup ratios cancel most machine modes (observed <= 19%
    # drift) and can carry a floor tighter than the unit default.
    out = []
    for name, s in doc.get("strategies", {}).items():
        k = f"ragged/{name}"
        out += [_rec(suite, f"{k}/req_per_s_warm", s.get("req_per_s_warm"),
                     "req/s", True),
                _rec(suite, f"{k}/compiled_shapes", s.get("compiled_shapes"),
                     "count"),
                _rec(suite, f"{k}/padded_steps", s.get("padded_steps"),
                     "count")]
    cmp_ = doc.get("comparison", {})
    for key in ("bucketed_vs_pad_to_max_speedup_warm",
                "bucketed_vs_per_request_speedup_warm"):
        out.append(_rec(suite, f"comparison/{key}", cmp_.get(key), "x",
                        True, floor=0.50))
    return out


def _extract_sessions(suite, doc):
    # Pool throughput at >= 100k sessions is bimodal under memory pressure
    # (observed 5x swings between uncontended runs) — only a near-collapse
    # gates there; smaller points keep the unit default.
    out = []
    for p in doc.get("points", []):
        k = f"sessions/S{p['n_sessions']}"
        tput_floor = 0.90 if p["n_sessions"] >= 100_000 else -1.0
        pooled = p.get("pooled", {})
        out += [_rec(suite, f"{k}/pooled_updates_per_s_warm",
                     pooled.get("updates_per_s_warm"), "updates/s", True,
                     floor=tput_floor),
                # sub-10ms tail percentile with observed 13x run-to-run
                # scheduler swings: tracked for trajectory, effectively
                # ungated (the serve-time SLO layer owns staleness)
                _rec(suite, f"{k}/pooled_p99_staleness_s",
                     pooled.get("p99_staleness_s"), "s", floor=99.0),
                _rec(suite, f"{k}/pooled_compiled_shapes",
                     pooled.get("compiled_shapes"), "count"),
                _rec(suite, f"{k}/speedup_vs_per_object",
                     p.get("pooled_vs_per_object_speedup_warm"), "x", True,
                     floor=tput_floor),
                _rec(suite, f"{k}/max_abs_err_pooled_vs_per_object",
                     p.get("max_abs_err_pooled_vs_per_object"), "relerr")]
    return out


def _extract_shard(suite, doc):
    # The shard suite forces 8 host devices, oversubscribing the CPU; its
    # wall-clock routinely varies 2x between invocations from thread
    # scheduling alone.  Gate those timings only on order-of-magnitude
    # blowups (the byte counters and relerrs stay exact/tight).
    out = []
    for r in doc.get("weak_scaling", []):
        k = f"weak_scaling/P{r['P']}"
        out += [_rec(suite, f"{k}/ms", r.get("ms"), "ms",
                     floor=_MAX_FLOOR),
                _rec(suite, f"{k}/efficiency_vs_P1",
                     r.get("efficiency_vs_P1"), "frac", True)]
    g = doc.get("gram_ring", {})
    if g:
        out += [_rec(suite, "gram_ring/ring_ms", g.get("ring_ms"), "ms",
                     floor=_MAX_FLOOR),
                _rec(suite, "gram_ring/oracle_ms", g.get("oracle_ms"),
                     "ms", floor=_MAX_FLOOR),
                _rec(suite, "gram_ring/relerr", g.get("relerr"), "relerr"),
                _rec(suite, "gram_ring/permute_wire_bytes_per_dev",
                     g.get("permute_wire_bytes_per_dev"), "bytes")]
    return out


def _extract_table3(suite, doc):
    out = []
    for r in doc.get("records", []):
        k = f"logsig/B{r['B']}_M{r['M']}_d{r['d']}_N{r['depth']}"
        out += [_rec(suite, f"{k}/fwd_projected_ms",
                     r.get("fwd_projected_ms"), "ms"),
                _rec(suite, f"{k}/fwd_speedup", r.get("fwd_speedup"), "x",
                     True),
                _rec(suite, f"{k}/train_projected_ms",
                     r.get("train_projected_ms"), "ms"),
                _rec(suite, f"{k}/train_speedup", r.get("train_speedup"),
                     "x", True),
                _rec(suite, f"{k}/coeffs_projected",
                     r.get("coeffs_projected"), "count")]
    return out


_EXTRACTORS = {
    "table1": _extract_table1,
    "table3": _extract_table3,
    "fig3": _extract_fig3,
    "gram": _extract_gram,
    "ragged": _extract_ragged,
    "sessions": _extract_sessions,
    "shard": _extract_shard,
}


def extract_records(suite: str, doc: dict) -> list[Record]:
    """Flatten one suite's BENCH json into records.  A top-level
    ``baseline_records`` list (the native schema) wins over the per-shape
    extractor; suites with neither yield no gated metrics."""
    if "baseline_records" in doc:
        return [Record.from_json(suite, d) for d in doc["baseline_records"]]
    fn = _EXTRACTORS.get(suite)
    recs = fn(suite, doc) if fn else []
    return [r for r in recs if r is not None]


# ---------------------------------------------------------------------------
# rerun aggregation: median value, MAD-widened noise floor
# ---------------------------------------------------------------------------

def aggregate(runs: list[list[Record]]) -> list[Record]:
    """Collapse K reruns of one suite: per key, the median value and a
    noise floor widened to ``max(unit floor, 3σ-scaled relative MAD)``.
    Keys missing from some reruns aggregate over the runs that have them."""
    by_key: dict[str, list[Record]] = {}
    order: list[str] = []
    for run in runs:
        for r in run:
            if r.key not in by_key:
                by_key[r.key] = []
                order.append(r.key)
            by_key[r.key].append(r)
    out = []
    for key in order:
        rs = by_key[key]
        vals = [r.value for r in rs]
        med = statistics.median(vals)
        floor = rs[0].noise_floor
        if len(vals) > 1 and med != 0:
            mad = statistics.median(abs(v - med) for v in vals)
            floor = max(floor, min(_MAX_FLOOR, _MAD_SIGMAS * mad / abs(med)))
        out.append(dataclasses.replace(rs[0], value=med,
                                       noise_floor=floor))
    return out


# ---------------------------------------------------------------------------
# baseline directory i/o
# ---------------------------------------------------------------------------

def _suite_path(dirname: str, suite: str) -> str:
    return os.path.join(dirname, f"{suite}.json")


def load_baseline(path: str) -> list[Record]:
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(f"{path}: baseline schema {schema!r}, this build "
                         f"reads {SCHEMA_VERSION}")
    suite = doc.get("suite", os.path.splitext(os.path.basename(path))[0])
    return [Record.from_json(suite, d) for d in doc.get("records", [])]


def load_baseline_dir(dirname: str) -> dict[str, list[Record]]:
    """``{suite: records}`` for every ``<suite>.json`` in the directory
    (empty when the directory does not exist yet)."""
    out: dict[str, list[Record]] = {}
    if not os.path.isdir(dirname):
        return out
    for fn in sorted(os.listdir(dirname)):
        if fn.endswith(".json"):
            recs = load_baseline(os.path.join(dirname, fn))
            if recs:
                out[recs[0].suite] = recs
            else:
                out[os.path.splitext(fn)[0]] = recs
    return out


def write_baseline(dirname: str, suite: str, records: list[Record],
                   *, reruns: int = 1) -> str:
    os.makedirs(dirname, exist_ok=True)
    path = _suite_path(dirname, suite)
    doc = {"schema": SCHEMA_VERSION, "suite": suite, "reruns": reruns,
           "records": [r.to_json() for r in records]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Verdict:
    suite: str
    key: str
    status: str                 # ok | improved | regressed | new | missing
    current: float | None
    baseline: float | None
    rel_delta: float | None     # signed, positive = better
    threshold: float
    unit: str = ""


def compare(current: dict[str, list[Record]],
            baselines: dict[str, list[Record]],
            *, extra_rel: float = 0.0) -> list[Verdict]:
    """Verdict per metric.  ``missing`` only fires for suites present in
    ``current`` (a suite that didn't run can't lose metrics)."""
    out = []
    for suite in sorted(current):
        cur = {r.key: r for r in current[suite]}
        base = {r.key: r for r in baselines.get(suite, [])}
        for key in list(cur) + [k for k in sorted(base) if k not in cur]:
            c, b = cur.get(key), base.get(key)
            if b is None:
                out.append(Verdict(suite, key, "new", c.value, None, None,
                                   max(c.noise_floor, extra_rel), c.unit))
                continue
            if c is None:
                out.append(Verdict(suite, key, "missing", None, b.value,
                                   None, b.noise_floor, b.unit))
                continue
            thr = max(b.noise_floor, c.noise_floor, extra_rel)
            denom = abs(b.value) if b.value else max(abs(c.value), 1e-30)
            rel = (c.value - b.value) / denom
            better = rel if b.higher_is_better else -rel
            status = ("regressed" if better < -max(thr, 1e-9)
                      else "improved" if better > max(thr, 1e-9) else "ok")
            out.append(Verdict(suite, key, status, c.value, b.value, better,
                               thr, b.unit))
    return out


def regressions(verdicts: list[Verdict]) -> list[Verdict]:
    return [v for v in verdicts if v.status == "regressed"]


def _fmt(v) -> str:
    return "-" if v is None else f"{v:.5g}"


def verdict_table(verdicts: list[Verdict], *,
                  hide_ok: bool = False) -> str:
    """A fixed-width verdict table (regressions first)."""
    rank = {"regressed": 0, "missing": 1, "new": 2, "improved": 3, "ok": 4}
    rows = sorted(verdicts, key=lambda v: (rank[v.status], v.suite, v.key))
    if hide_ok:
        rows = [v for v in rows if v.status != "ok"]
    lines = [f"{'verdict':<10} {'suite':<9} {'metric':<58} "
             f"{'baseline':>12} {'current':>12} {'delta':>8} {'floor':>7}"]
    lines.append("-" * len(lines[0]))
    for v in rows:
        delta = "-" if v.rel_delta is None else f"{v.rel_delta:+.1%}"
        lines.append(f"{v.status:<10} {v.suite:<9} {v.key:<58} "
                     f"{_fmt(v.baseline):>12} {_fmt(v.current):>12} "
                     f"{delta:>8} {v.threshold:>6.0%}")
    n = len(verdicts)
    by = {s: sum(1 for v in verdicts if v.status == s) for s in rank}
    lines.append("-" * len(lines[0]))
    lines.append(f"{n} metrics: " + ", ".join(
        f"{c} {s}" for s, c in by.items() if c))
    return "\n".join(lines)
