"""Crash flight recorder: an always-on bounded ring of recent spans,
instants, and metric deltas, dumped as Chrome-trace JSON when something
dies.

The tracer and registry are opt-in — a production crash usually happens
with both off, leaving nothing to debug from.  The flight recorder closes
that gap with the same one-flag-check discipline: every ``obs.span`` /
``obs.instant`` feeds a fixed-size ``deque`` ring (no I/O, no growth),
every metric write mirrors its delta when the registry is enabled, and
:func:`repro.obs.compile.count_trace` records the last-N retrace keys
*even with metrics disabled* (compiles are rare; knowing what retraced
right before a crash is the single most useful breadcrumb this stack
has).

Dump triggers:

- unhandled exceptions crossing the production boundaries —
  ``train_loop``, ``DynamicBatcher.flush``, ``SessionStore`` ingest/flush
  — via :func:`dump_on_error` (the exception is attached to the dump and
  marked so nested boundaries don't double-dump);
- ``SIGUSR2`` (inspect a live, wedged process);
- explicit :func:`dump`.

The dump (``flight_<ts>_<pid>.json`` under ``PATHSIG_FLIGHT_DIR``,
default ``runs/``) is Chrome-trace-compatible — load it in
``chrome://tracing`` / Perfetto — with the triggering exception and
retrace keys in ``otherData``.

Environment: ``PATHSIG_FLIGHT=off`` disables everything;
``PATHSIG_FLIGHT_EVENTS`` sizes the ring (default 2048);
``PATHSIG_FLIGHT_DIR`` sets the dump directory.
"""
from __future__ import annotations

import collections
import contextlib
import os
import signal
import threading
import time
import traceback

from . import compile as _compile
from . import metrics as _metrics
from . import trace as _trace

__all__ = [
    "FlightRecorder", "FLIGHT", "flight_active", "enable_flight",
    "disable_flight", "dump", "dump_on_error", "instant",
]

_PID = os.getpid()


def _env_int(name: str, default: int) -> int:
    try:
        return max(1, int(os.environ.get(name, "") or default))
    except ValueError:
        return default


class FlightRecorder:
    """Fixed-size ring of recent events (see module docstring).  Appends
    are single ``deque.append`` calls — atomic under the GIL, no lock on
    the hot path."""

    def __init__(self, capacity: int = 2048, retrace_keys: int = 64):
        self._ring = collections.deque(maxlen=capacity)
        self._retraces = collections.deque(maxlen=retrace_keys)
        self._dump_lock = threading.Lock()
        self.dumps = 0

    # -- feeds (hot paths) -------------------------------------------------

    def record_span(self, name, t0, t1, depth, args) -> None:
        self._ring.append(("X", name, t0, t1, depth,
                           dict(args) if args else None,
                           threading.get_ident()))

    def record_instant(self, name, args) -> None:
        self._ring.append(("i", name, time.perf_counter(), None, 0,
                           dict(args) if args else None,
                           threading.get_ident()))

    def record_metric(self, kind, name, labels, value) -> None:
        self._ring.append(("C", name, time.perf_counter(), None, 0,
                           {"kind": kind, "labels": labels,
                            "value": float(value)},
                           threading.get_ident()))

    def record_retrace(self, site, shapes) -> None:
        self._retraces.append((time.perf_counter(), site, shapes))

    def clear(self) -> None:
        self._ring.clear()
        self._retraces.clear()

    def __len__(self) -> int:
        return len(self._ring)

    # -- dump --------------------------------------------------------------

    def _snapshot(self):
        # list(deque) copies under the GIL; concurrent appends may retry
        for _ in range(4):
            try:
                return list(self._ring), list(self._retraces)
            except RuntimeError:
                continue
        return [], list(self._retraces)

    def to_chrome(self, *, exc=None, note: str = "") -> dict:
        events, retraces = self._snapshot()
        t00 = min((e[2] for e in events), default=0.0)
        out = []
        for ph, name, t0, t1, depth, args, tid in events:
            ev = {"name": name, "ph": ph, "ts": (t0 - t00) * 1e6,
                  "pid": _PID, "tid": tid & 0xFFFF}
            if ph == "X":
                ev["dur"] = (t1 - t0) * 1e6
                ev["args"] = {"depth": depth, **(args or {})}
            elif ph == "i":
                ev["s"] = "t"
                ev["args"] = dict(args or {})
            else:                                # "C": metric delta
                ev["args"] = {"value": args["value"]}
                lbl = args.get("labels") or {}
                if lbl:
                    ev["name"] = name + "{" + ",".join(
                        f"{k}={v}" for k, v in sorted(lbl.items())) + "}"
            out.append(ev)
        other = {
            "producer": "repro.obs.flight",
            "note": note,
            "ts_epoch": time.time(),
            "ring_capacity": self._ring.maxlen,
            "retrace_keys": [
                {"age_s": round(max(0.0, time.perf_counter() - t), 3),
                 "site": site, "shapes": shapes}
                for t, site, shapes in retraces],
        }
        if exc is not None:
            other["exception"] = {
                "type": type(exc).__name__,
                "message": str(exc),
                "traceback": "".join(traceback.format_exception(
                    type(exc), exc, exc.__traceback__)),
            }
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": other}

    def dump(self, path: str | None = None, *, exc=None,
             note: str = "") -> str:
        """Write the ring as Chrome-trace JSON; returns the path."""
        import json
        if path is None:
            d = os.environ.get("PATHSIG_FLIGHT_DIR", "").strip() or "runs"
            ts = time.strftime("%Y%m%d-%H%M%S")
            path = os.path.join(d, f"flight_{ts}_{_PID}.json")
        doc = self.to_chrome(exc=exc, note=note)
        with self._dump_lock:
            dirn = os.path.dirname(path)
            if dirn:
                os.makedirs(dirn, exist_ok=True)
            with open(path, "w") as f:
                json.dump(doc, f)
                f.write("\n")
            self.dumps += 1
        _metrics.counter("pathsig_flight_dumps_total",
                         "flight-recorder dumps written").inc()
        return path


# ---------------------------------------------------------------------------
# the process-wide recorder + hook wiring
# ---------------------------------------------------------------------------

FLIGHT = FlightRecorder(capacity=_env_int("PATHSIG_FLIGHT_EVENTS", 2048))

_SIG_INSTALLED = False
_PREV_SIGUSR2 = None


def flight_active() -> bool:
    return _trace.TRACER._flight is not None


def enable_flight(recorder: FlightRecorder | None = None) -> None:
    """Wire the recorder into the span tracer, the metrics write path, and
    the retrace counter (idempotent)."""
    fl = FLIGHT if recorder is None else recorder
    _trace.TRACER.set_flight(fl)
    _metrics.set_flight_sink(fl.record_metric)
    _compile.set_retrace_sink(fl.record_retrace)
    _install_sigusr2()


def disable_flight() -> None:
    _trace.TRACER.set_flight(None)
    _metrics.set_flight_sink(None)
    _compile.set_retrace_sink(None)


def instant(name: str, **args) -> None:
    """Record an instant straight to the flight ring (works even when the
    trace file tracer is inactive)."""
    fl = _trace.TRACER._flight
    if fl is not None:
        fl.record_instant(name, args)


def dump(path: str | None = None, *, exc=None, note: str = "") -> str:
    return FLIGHT.dump(path, exc=exc, note=note)


@contextlib.contextmanager
def dump_on_error(site: str):
    """Boundary guard: re-raises everything, dumping the flight ring once
    per exception (nested boundaries see the marker and skip)."""
    try:
        yield
    except BaseException as e:
        if flight_active() and not getattr(e, "_pathsig_flight_dumped",
                                           False):
            try:
                e._pathsig_flight_dumped = True
            except (AttributeError, TypeError):
                pass
            try:
                path = FLIGHT.dump(exc=e, note=site)
                print(f"# flight recorder: {site} failed "
                      f"({type(e).__name__}), ring dumped to {path}",
                      flush=True)
            except Exception:
                pass              # never mask the original failure
        raise


def _sigusr2(signum, frame) -> None:
    try:
        FLIGHT.dump(note="SIGUSR2")
    except Exception:
        pass
    prev = _PREV_SIGUSR2
    if callable(prev) and prev not in (signal.SIG_IGN, signal.SIG_DFL):
        prev(signum, frame)


def _install_sigusr2() -> None:
    global _SIG_INSTALLED, _PREV_SIGUSR2
    if _SIG_INSTALLED or not hasattr(signal, "SIGUSR2"):
        return
    if threading.current_thread() is not threading.main_thread():
        return
    try:
        _PREV_SIGUSR2 = signal.signal(signal.SIGUSR2, _sigusr2)
        _SIG_INSTALLED = True
    except (ValueError, OSError):
        pass


if os.environ.get("PATHSIG_FLIGHT", "").strip().lower() not in \
        ("0", "off", "false", "no"):
    enable_flight()
