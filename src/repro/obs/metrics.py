"""Process-wide metrics registry: counters / gauges / histograms with label
sets, near-zero overhead when disabled.

The registry is the single place every layer of the stack reports through —
kernel dispatch counts and jit retraces (:mod:`repro.kernels.ops`),
plan-cache and autotune hit/miss accounting, serve-layer queue depth /
padding waste / staleness (:mod:`repro.serve`), and train-loop step timing
(:mod:`repro.train.trainer`).  Design rules:

- **Disabled is the default and costs one attribute check.**  Every
  instrument method (`inc` / `set` / `observe`) returns immediately when the
  owning registry is disabled, so instrumenting a hot path is free until
  someone turns observability on (``PATHSIG_METRICS`` env,
  :func:`enable`, or the :func:`enabled_scope` context manager).
- **Instruments are cheap, snapshots do the work.**  Counters and gauges are
  dicts keyed by label-value tuples; histograms bucket-count on a fixed
  log-spaced ladder.  Percentiles, Prometheus text, and JSON snapshots are
  computed only when :func:`snapshot` / :func:`to_prometheus` run.
- **Pull collectors.**  Sources that already keep their own counters (the
  plan caches of :mod:`repro.kernels.ops`) register a collector callback via
  :func:`register_collector`; collectors run at snapshot time and publish
  gauges, so the hot path never mirrors increments.

Environment:

``PATHSIG_METRICS``
    unset / ``""`` / ``0`` / ``off`` — disabled (the default).
    ``1`` / ``on`` / ``true``        — enabled.
    any other value                  — enabled, treated as a file path: a
    JSON snapshot is written there at interpreter exit.

Exports: ``json`` snapshots (:func:`write_snapshot`, one file), JSONL
append (:func:`append_jsonl`, one line per call — run logs), and
Prometheus text exposition (:func:`to_prometheus`).
"""
from __future__ import annotations

import atexit
import json
import math
import os
import threading
import time
import warnings

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "counter", "gauge", "histogram", "enable", "disable", "enabled",
    "enabled_scope", "reset", "snapshot", "to_prometheus", "write_snapshot",
    "append_jsonl", "register_collector", "jsonl_sink", "set_flight_sink",
    "DEFAULT_BUCKETS", "DEFAULT_MAX_LABEL_SETS",
]

# label-cardinality cap per metric: retrace shape keys and similar
# open-ended labels must not grow the registry without bound
DEFAULT_MAX_LABEL_SETS = 1000

CARDINALITY_DROP_COUNTER = "pathsig_metric_labelsets_dropped_total"

# repro.obs.flight mirror: (kind, name, labels, value) per metric write
# when the registry is enabled — installed via set_flight_sink()
_FLIGHT_SINK = None


def set_flight_sink(fn) -> None:
    global _FLIGHT_SINK
    _FLIGHT_SINK = fn

# log-spaced seconds ladder (~half-decade steps): instrument latencies from
# 10 µs to ~5 min land in distinct buckets
DEFAULT_BUCKETS = (
    1e-5, 3.16e-5, 1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2,
    0.1, 0.316, 1.0, 3.16, 10.0, 31.6, 100.0, 316.0,
)


def _label_key(names: tuple, labels: dict) -> tuple:
    try:
        return tuple(str(labels[n]) for n in names)
    except KeyError:
        missing = [n for n in names if n not in labels]
        raise ValueError(
            f"metric expects labels {names}, got {sorted(labels)} "
            f"(missing {missing})") from None


class _Metric:
    """Shared plumbing: name/help/labelnames + the owning registry's enabled
    flag (checked on every instrument call — the disabled fast path)."""

    kind = "untyped"

    def __init__(self, registry: "Registry", name: str, help: str,
                 labelnames: tuple):
        self._reg = registry
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._card_warned = False

    def _admit(self, key: tuple) -> bool:
        """Cardinality guard — called with the registry lock held for a
        label set not seen before.  Over the cap: warn once, tick the drop
        counter (itself exempt), refuse the write."""
        if len(self._values) < self._reg.max_label_sets \
                or self.name == CARDINALITY_DROP_COUNTER:
            return True
        if not self._card_warned:
            self._card_warned = True
            warnings.warn(
                f"metric {self.name!r} hit the label-cardinality cap "
                f"({self._reg.max_label_sets} label sets); further new "
                f"label sets are dropped (see {CARDINALITY_DROP_COUNTER})",
                stacklevel=4)
        self._reg.counter(
            CARDINALITY_DROP_COUNTER,
            "metric writes dropped by the per-metric label-cardinality "
            "cap", ("metric",)).inc(metric=self.name)
        return False

    def _values_list(self):
        raise NotImplementedError


class Counter(_Metric):
    """Monotone counter with label sets: ``c.inc(3, op="signature")``."""

    kind = "counter"

    def __init__(self, registry, name, help, labelnames):
        super().__init__(registry, name, help, labelnames)
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self._reg._enabled:
            return
        key = _label_key(self.labelnames, labels)
        with self._reg._lock:
            if key not in self._values and not self._admit(key):
                return
            self._values[key] = self._values.get(key, 0.0) + amount
        fs = _FLIGHT_SINK
        if fs is not None:
            fs("counter", self.name, labels, amount)

    def value(self, **labels) -> float:
        """Current value for one label set (0.0 if never incremented)."""
        return self._values.get(_label_key(self.labelnames, labels), 0.0)

    def total(self) -> float:
        """Sum over every label set."""
        return sum(self._values.values())

    def _values_list(self):
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._values.items())]


class Gauge(_Metric):
    """Last-write-wins gauge: ``g.set(0.82, pool="sessions")`` (plus
    ``add`` for up/down accounting like queue depth)."""

    kind = "gauge"

    def __init__(self, registry, name, help, labelnames):
        super().__init__(registry, name, help, labelnames)
        self._values: dict[tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        if not self._reg._enabled:
            return
        key = _label_key(self.labelnames, labels)
        with self._reg._lock:
            if key not in self._values and not self._admit(key):
                return
            self._values[key] = float(value)
        fs = _FLIGHT_SINK
        if fs is not None:
            fs("gauge", self.name, labels, value)

    def add(self, amount: float = 1.0, **labels) -> None:
        if not self._reg._enabled:
            return
        key = _label_key(self.labelnames, labels)
        with self._reg._lock:
            if key not in self._values and not self._admit(key):
                return
            self._values[key] = self._values.get(key, 0.0) + amount
        fs = _FLIGHT_SINK
        if fs is not None:
            fs("gauge", self.name, labels, amount)

    def value(self, **labels) -> float:
        return self._values.get(_label_key(self.labelnames, labels), 0.0)

    def _values_list(self):
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._values.items())]


class _HistState:
    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Fixed-bucket histogram (log-spaced seconds ladder by default) with
    count/sum/min/max and snapshot-time percentile estimates."""

    kind = "histogram"

    def __init__(self, registry, name, help, labelnames,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(registry, name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        self._values: dict[tuple, _HistState] = {}

    def observe(self, value: float, **labels) -> None:
        if not self._reg._enabled:
            return
        value = float(value)
        key = _label_key(self.labelnames, labels)
        with self._reg._lock:
            st = self._values.get(key)
            if st is None:
                if not self._admit(key):
                    return
                st = self._values[key] = _HistState(len(self.buckets))
            i = 0
            for b in self.buckets:          # tiny fixed ladder: linear scan
                if value <= b:
                    break
                i += 1
            st.counts[i] += 1
            st.count += 1
            st.sum += value
            if value < st.min:
                st.min = value
            if value > st.max:
                st.max = value
        fs = _FLIGHT_SINK
        if fs is not None:
            fs("histogram", self.name, labels, value)

    def percentile(self, q: float, **labels) -> float:
        """Bucket-interpolated q-th percentile (q in [0, 100]); 0.0 when the
        label set has no observations — never NaN."""
        st = self._values.get(_label_key(self.labelnames, labels))
        return self._percentile_of(st, q)

    def _percentile_of(self, st, q: float) -> float:
        if st is None or st.count == 0:
            return 0.0
        target = (q / 100.0) * st.count
        cum = 0
        lo = 0.0
        for i, c in enumerate(st.counts):
            hi = self.buckets[i] if i < len(self.buckets) else st.max
            if cum + c >= target and c > 0:
                frac = (target - cum) / c
                hi = min(hi, st.max)
                lo = max(lo, st.min if cum == 0 else lo)
                return lo + max(0.0, min(1.0, frac)) * max(0.0, hi - lo)
            cum += c
            lo = hi
        return st.max

    def count(self, **labels) -> int:
        st = self._values.get(_label_key(self.labelnames, labels))
        return 0 if st is None else st.count

    def _values_list(self):
        out = []
        for k, st in sorted(self._values.items()):
            out.append({
                "labels": dict(zip(self.labelnames, k)),
                "count": st.count, "sum": st.sum,
                "min": st.min if st.count else 0.0,
                "max": st.max if st.count else 0.0,
                "p50": self._percentile_of(st, 50),
                "p99": self._percentile_of(st, 99),
                "buckets": {str(b): st.counts[i]
                            for i, b in enumerate(self.buckets)} |
                           {"+Inf": st.counts[-1]},
            })
        return out


class Registry:
    """A namespace of metrics with one shared enabled flag (see module
    docstring).  Most code uses the process-wide :data:`REGISTRY` through
    the module-level convenience functions."""

    def __init__(self, enabled: bool = False,
                 max_label_sets: int = DEFAULT_MAX_LABEL_SETS):
        self._enabled = bool(enabled)
        self.max_label_sets = int(max_label_sets)
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list = []
        self._lock = threading.RLock()

    # -- lifecycle ---------------------------------------------------------

    @property
    def is_enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        """Zero every instrument (the instruments themselves survive, so
        cached references held by instrumented modules stay valid)."""
        with self._lock:
            for m in self._metrics.values():
                m._values.clear()
                m._card_warned = False

    # -- instrument factories (get-or-create, type-checked) ----------------

    def _get(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(self, name, help, tuple(labelnames), **kw)
                self._metrics[name] = m
                return m
            if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} with "
                    f"labels {m.labelnames}; asked for {cls.kind} with "
                    f"{tuple(labelnames)}")
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "", labelnames: tuple = (),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def get(self, name: str):
        """The registered metric (None when absent) — for tests/exporters."""
        return self._metrics.get(name)

    # -- collectors --------------------------------------------------------

    def register_collector(self, fn) -> None:
        """``fn(registry)`` runs at every snapshot/exposition — the pull
        path for sources that keep their own counters (plan caches)."""
        with self._lock:
            if fn not in self._collectors:
                self._collectors.append(fn)

    def _collect(self) -> None:
        if not self._enabled:
            return
        for fn in list(self._collectors):
            try:
                fn(self)
            except Exception as e:       # a broken collector must not take
                warnings.warn(            # down the exporter
                    f"metrics collector {fn!r} failed: {e}", stacklevel=2)

    # -- exporters ---------------------------------------------------------

    def snapshot(self) -> dict:
        """One JSON-serialisable view of every metric (collectors run
        first)."""
        self._collect()
        with self._lock:
            return {
                "ts": time.time(),
                "enabled": self._enabled,
                "metrics": {
                    name: {"type": m.kind, "help": m.help,
                           "values": m._values_list()}
                    for name, m in sorted(self._metrics.items())
                },
            }

    def write_snapshot(self, path: str) -> str:
        snap = self.snapshot()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def append_jsonl(self, path: str, extra: dict | None = None) -> str:
        """Append one snapshot line (run logs / time series)."""
        snap = self.snapshot()
        if extra:
            snap.update(extra)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(snap, sort_keys=True) + "\n")
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        self._collect()
        lines: list[str] = []

        def fmt_labels(d: dict, extra: dict | None = None) -> str:
            items = dict(d)
            if extra:
                items.update(extra)
            if not items:
                return ""
            body = ",".join(
                f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
                for k, v in items.items())
            return "{" + body + "}"

        with self._lock:
            for name, m in sorted(self._metrics.items()):
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                if isinstance(m, Histogram):
                    for row in m._values_list():
                        labels = row["labels"]
                        cum = 0
                        for b, c in row["buckets"].items():
                            cum += c
                            lines.append(
                                f"{name}_bucket"
                                f"{fmt_labels(labels, {'le': b})} {cum}")
                        lines.append(
                            f"{name}_sum{fmt_labels(labels)} {row['sum']}")
                        lines.append(
                            f"{name}_count{fmt_labels(labels)} "
                            f"{row['count']}")
                else:
                    for row in m._values_list():
                        lines.append(f"{name}{fmt_labels(row['labels'])} "
                                     f"{row['value']}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# the process-wide registry + module-level conveniences
# ---------------------------------------------------------------------------

def _env_config() -> tuple[bool, str | None]:
    """PATHSIG_METRICS -> (enabled, snapshot-path-or-None)."""
    raw = os.environ.get("PATHSIG_METRICS", "").strip()
    if raw.lower() in ("", "0", "off", "false", "no"):
        return False, None
    if raw.lower() in ("1", "on", "true", "yes"):
        return True, None
    return True, raw


_ENV_ENABLED, _ENV_SNAPSHOT_PATH = _env_config()

REGISTRY = Registry(enabled=_ENV_ENABLED)

if _ENV_SNAPSHOT_PATH:
    atexit.register(lambda: REGISTRY.write_snapshot(_ENV_SNAPSHOT_PATH))


def counter(name: str, help: str = "", labelnames: tuple = ()) -> Counter:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name: str, help: str = "", labelnames: tuple = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(name: str, help: str = "", labelnames: tuple = (),
              buckets=DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help, labelnames, buckets=buckets)


def enable() -> None:
    REGISTRY.enable()


def disable() -> None:
    REGISTRY.disable()


def enabled() -> bool:
    return REGISTRY._enabled


class enabled_scope:
    """``with obs.enabled_scope():`` — enable metrics for a block (tests,
    benchmark suites) and restore the previous state after."""

    def __init__(self, registry: Registry | None = None, on: bool = True):
        self._reg = REGISTRY if registry is None else registry
        self._on = on
        self._prev = None

    def __enter__(self):
        self._prev = self._reg._enabled
        self._reg._enabled = self._on
        return self._reg

    def __exit__(self, *exc):
        self._reg._enabled = self._prev
        return False


def reset() -> None:
    REGISTRY.reset()


def snapshot() -> dict:
    return REGISTRY.snapshot()


def to_prometheus() -> str:
    return REGISTRY.to_prometheus()


def write_snapshot(path: str) -> str:
    return REGISTRY.write_snapshot(path)


def append_jsonl(path: str, extra: dict | None = None) -> str:
    return REGISTRY.append_jsonl(path, extra)


def register_collector(fn) -> None:
    REGISTRY.register_collector(fn)


def jsonl_sink(path: str):
    """-> ``sink(step, metrics_dict)`` appending one JSON line per call —
    the default ``on_metrics`` of :func:`repro.train.trainer.train_loop`.
    Unwritable paths degrade to a one-time warning, never an exception."""
    state = {"broken": False}

    def sink(step: int, m: dict) -> None:
        if state["broken"]:
            return
        try:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "a") as f:
                f.write(json.dumps({"step": step, **m}, sort_keys=True,
                                   default=str) + "\n")
        except OSError as e:
            state["broken"] = True
            warnings.warn(f"metrics sink cannot write {path}: {e}",
                          stacklevel=2)

    sink.path = path
    return sink
