"""repro.obs — process-wide observability: metrics, tracing, and
compile/retrace accounting.

Three modules, one import surface::

    from repro import obs

    obs.enable()                                  # or PATHSIG_METRICS=1
    obs.counter("my_events_total").inc()
    with obs.span("my.phase", n=3):               # PATHSIG_TRACE=t.json
        ...
    print(obs.to_prometheus())

- :mod:`repro.obs.metrics` — counters / gauges / histograms with label
  sets; JSON snapshot, JSONL append, Prometheus text exporters; pull
  collectors.  Near-zero overhead when disabled (one flag check).
- :mod:`repro.obs.trace` — span tracer exporting Chrome-trace/Perfetto
  JSON; null-span fast path when inactive; optional ``jax.profiler``
  bridge.
- :mod:`repro.obs.compile` — jit compile/retrace counters labelled with
  offending shape keys (:func:`instrument_jit`, :func:`count_trace`),
  lowered-cost and HLO-collective recording.

This package imports nothing from the rest of ``repro`` — every layer
(kernels, distributed, serve, train, benchmarks) imports *it*.
"""
from .compile import (TRACE_COUNTER_NAME, count_trace, instrument_jit,
                      record_collectives, record_cost, shape_key)
from .metrics import (DEFAULT_BUCKETS, REGISTRY, Counter, Gauge, Histogram,
                      Registry, append_jsonl, counter, disable, enable,
                      enabled, enabled_scope, gauge, histogram, jsonl_sink,
                      register_collector, reset, snapshot, to_prometheus,
                      write_snapshot)
from .trace import (TRACER, Tracer, instant, span, span_blocked, start_trace,
                    stop_trace, trace_active, trace_scope)

__all__ = [
    # metrics
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "DEFAULT_BUCKETS", "counter", "gauge", "histogram", "enable", "disable",
    "enabled", "enabled_scope", "reset", "snapshot", "to_prometheus",
    "write_snapshot", "append_jsonl", "register_collector", "jsonl_sink",
    # trace
    "Tracer", "TRACER", "span", "span_blocked", "instant", "start_trace",
    "stop_trace", "trace_active", "trace_scope",
    # compile accounting
    "TRACE_COUNTER_NAME", "shape_key", "count_trace", "instrument_jit",
    "record_cost", "record_collectives",
]
