"""repro.obs — process-wide observability: metrics, tracing, and
compile/retrace accounting.

Three modules, one import surface::

    from repro import obs

    obs.enable()                                  # or PATHSIG_METRICS=1
    obs.counter("my_events_total").inc()
    with obs.span("my.phase", n=3):               # PATHSIG_TRACE=t.json
        ...
    print(obs.to_prometheus())

- :mod:`repro.obs.metrics` — counters / gauges / histograms with label
  sets; JSON snapshot, JSONL append, Prometheus text exporters; pull
  collectors.  Near-zero overhead when disabled (one flag check).
- :mod:`repro.obs.trace` — span tracer exporting Chrome-trace/Perfetto
  JSON; null-span fast path when inactive; optional ``jax.profiler``
  bridge.
- :mod:`repro.obs.compile` — jit compile/retrace counters labelled with
  offending shape keys (:func:`instrument_jit`, :func:`count_trace`),
  lowered-cost and HLO-collective recording.
- :mod:`repro.obs.baseline` — flat benchmark record schema, committed
  baseline store, and the median/MAD statistical regression gate behind
  ``benchmarks/run.py --compare``.
- :mod:`repro.obs.slo` — declarative SLOs over snapshots / value dicts /
  JSONL run logs; backs ``SessionStore.health()``,
  ``DynamicBatcher.health()`` and the train loop's ``slo_callback``.
- :mod:`repro.obs.flight` — always-on crash flight recorder: a bounded
  ring of recent spans/instants/metric deltas + last-N retrace keys,
  dumped as Chrome-trace JSON on boundary exceptions, SIGUSR2, or
  :func:`flight.dump` (``PATHSIG_FLIGHT=off`` disables).

This package imports nothing from the rest of ``repro`` — every layer
(kernels, distributed, serve, train, benchmarks) imports *it*.
"""
from . import baseline, slo
from .compile import (TRACE_COUNTER_NAME, count_trace, instrument_jit,
                      record_collectives, record_cost, set_retrace_sink,
                      shape_key)
from .flight import (FLIGHT, FlightRecorder, disable_flight, dump_on_error,
                     enable_flight, flight_active)
from .metrics import (DEFAULT_BUCKETS, DEFAULT_MAX_LABEL_SETS, REGISTRY,
                      Counter, Gauge, Histogram, Registry, append_jsonl,
                      counter, disable, enable, enabled, enabled_scope,
                      gauge, histogram, jsonl_sink, register_collector,
                      reset, set_flight_sink, snapshot, to_prometheus,
                      write_snapshot)
from .slo import (Slo, SloBreach, SloResult, batcher_slos, default_slos,
                  evaluate_log, evaluate_snapshot, evaluate_values,
                  session_slos, train_slos)
from .trace import (TRACER, Tracer, instant, span, span_blocked, start_trace,
                    stop_trace, trace_active, trace_scope)

__all__ = [
    # metrics
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY",
    "DEFAULT_BUCKETS", "DEFAULT_MAX_LABEL_SETS", "counter", "gauge",
    "histogram", "enable", "disable", "enabled", "enabled_scope", "reset",
    "snapshot", "to_prometheus", "write_snapshot", "append_jsonl",
    "register_collector", "jsonl_sink", "set_flight_sink",
    # trace
    "Tracer", "TRACER", "span", "span_blocked", "instant", "start_trace",
    "stop_trace", "trace_active", "trace_scope",
    # compile accounting
    "TRACE_COUNTER_NAME", "shape_key", "count_trace", "instrument_jit",
    "record_cost", "record_collectives", "set_retrace_sink",
    # decision layer (PR 9)
    "baseline", "slo", "Slo", "SloResult", "SloBreach", "evaluate_values",
    "evaluate_snapshot", "evaluate_log", "default_slos", "session_slos",
    "batcher_slos", "train_slos",
    # flight recorder
    "FLIGHT", "FlightRecorder", "enable_flight", "disable_flight",
    "flight_active", "dump_on_error",
]
