"""Multi-tenant session serving demo: pooled streams end to end.

One `repro.serve.SessionStore` holds every tenant's running window
signature as a row of a single struct-of-arrays device pool.  This demo
walks the full serving lifecycle:

1. bursty multi-tenant ingest (`repro.data.session_tick_stream` traffic:
   heavy-tailed per-session rates + arrival/churn) delivered through
   continuous-batching `flush()` rounds — a bounded set of compiled shapes
   no matter what the traffic does;
2. scoring live sessions against cached references (gather a block of
   session signatures, one Gram call);
3. checkpoint -> "restart" (a fresh process would do the same) ->
   restore -> resume: the pool comes back bit-identical and the replayed
   traffic continues as if the restart never happened.

Run:  PYTHONPATH=src python examples/sessions_serving.py
"""
from __future__ import annotations

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import tensor_ops as tops
from repro.data import session_tick_stream
from repro.kernels import ops
from repro.serve import SessionStore
from repro.sigkernel import word_weights

D, DEPTH = 3, 3


def main() -> None:
    # 1) pooled ingest: sessions auto-admitted on first tick ---------------
    store = SessionStore(D, DEPTH, initial_sessions=16, ttl=50.0)
    traffic = session_tick_stream(40, D, seed=0, arrival_rate=1.5,
                                  churn_prob=0.02)
    for _ in range(6):
        r = next(traffic)
        store.ingest_many(r["sids"], r["counts"], r["ticks"],
                          auto_create=True)
        store.flush()
        for sid in r["departures"]:
            if sid in store:
                store.evict(sid)
    st = store.stats()
    print(f"pool: {st['sessions']} live sessions in {st['pool_size']} slots "
          f"(occupancy {st['occupancy']:.2f}), {st['updates']} ticks "
          f"applied in {st['flushes']} flushes")
    print(f"   compiled shapes: {st['compiled_shapes']} "
          f"(flush rungs {st['flush_shapes']}), "
          f"p99 staleness {st['p99_staleness_s']*1e3:.2f} ms, "
          f"evictions {st['evictions']}")

    # 2) score a block of live sessions against cached references ----------
    refs = np.cumsum(np.random.default_rng(7).standard_normal(
        (6, 33, D)).astype(np.float32) * 0.18, axis=1)
    ref_sigs = ops.signature(tops.path_increments(jnp.asarray(refs)), DEPTH,
                             backend="jax")
    w = jnp.asarray(word_weights(D, DEPTH))
    some = list(store._ids)[:5]
    K = ops.gram(store.block_features(some), ref_sigs, w, backend="jax")
    print(f"scored {len(some)} sessions x {refs.shape[0]} references: "
          f"nearest = {np.asarray(jnp.argmax(K, axis=-1)).tolist()}")

    # 3) checkpoint -> restart -> resume -----------------------------------
    ckpt_dir = tempfile.mkdtemp()
    ck = Checkpointer(ckpt_dir, async_save=False)
    store.checkpoint(ck, step=1)
    resume_state = traffic.state()           # data pipeline state rides along

    restored = SessionStore.restore(ck)      # ... in a fresh process
    replay = session_tick_stream(40, D, seed=0, arrival_rate=1.5,
                                 churn_prob=0.02)
    replay.restore(resume_state)
    same = all(np.array_equal(np.asarray(store.features(s)),
                              np.asarray(restored.features(s)))
               for s in store._ids)
    print(f"restored {len(restored)} sessions bit-identical: {same}")

    for src, st_ in ((traffic, store), (replay, restored)):
        r = next(src)
        live = [s for s in r["sids"] if s in st_]
        keep = [i for i, s in enumerate(r["sids"]) if s in st_]
        chunks = np.split(r["ticks"], np.cumsum(r["counts"])[:-1])
        if live:
            st_.ingest_many(live, r["counts"][keep],
                            np.concatenate([chunks[i] for i in keep]))
            st_.flush()
    same = all(np.array_equal(np.asarray(store.features(s)),
                              np.asarray(restored.features(s)))
               for s in store._ids)
    print(f"resumed both sides with the replayed round; still identical: "
          f"{same}")
    print("\nsessions serving OK — see benchmarks/session_throughput.py "
          "for pooled vs per-object numbers, and examples/ragged_serving.py "
          "for the per-request (stateless) serving path")


if __name__ == "__main__":
    main()
