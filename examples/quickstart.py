"""pathsig-in-JAX quickstart: the paper's API surface in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (anisotropic_words, dag_words, lead_lag,
                        logsignature, logsignature_projected, lyndon_words,
                        make_plan, projected_signature, sig_dim, signature,
                        sliding_windows, windowed_signature)
from repro.core import tensor_ops as tops
from repro.kernels import ops as K

rng = np.random.default_rng(0)


def section(title):
    print(f"\n--- {title} " + "-" * max(0, 60 - len(title)))


# 1. Truncated signatures -------------------------------------------------
section("1. truncated signature")
B, M, d, N = 4, 50, 3, 4
path = jnp.asarray(np.cumsum(rng.standard_normal((B, M + 1, d)), axis=1),
                   jnp.float32) * 0.1
sig = signature(path, depth=N)                    # (B, D_sig)
print(f"path (B={B}, M+1={M+1}, d={d})  ->  signature {sig.shape}"
      f"  (D_sig = {sig_dim(d, N)})")

# Chen's relation: sig(path) == sig(first half) ⊗ sig(second half)
from repro.core import signature_combine
h = M // 2
s1, s2 = signature(path[:, :h + 1], N), signature(path[:, h:], N)
chen = signature_combine(s1, s2, d, N)
print(f"Chen identity max|err| = {jnp.max(jnp.abs(chen - sig)):.2e}")

# 2. Gradients flow through (O(B*D_sig) memory, paper §4) ------------------
section("2. backprop through the signature")
grad = jax.grad(lambda p: jnp.sum(signature(p, N) ** 2))(path)
print(f"d(loss)/d(path): {grad.shape}, finite: {bool(jnp.all(jnp.isfinite(grad)))}")

# 3. Word projections (paper §7.1) ----------------------------------------
section("3. projected signatures: arbitrary word sets")
words = [(0,), (1,), (0, 1), (1, 0), (0, 1, 2)]   # pick any coefficients
proj = projected_signature(path, words, d)
print(f"pi_I(S) for I={words}: {proj.shape}")
full = signature(path, 3)
from repro.core import flat_index
idx = [flat_index(w, d) for w in words]
print(f"matches truncated coefficients: "
      f"{jnp.max(jnp.abs(proj - full[:, idx])):.2e}")

# 4. Anisotropic truncation (paper §7.2) -----------------------------------
section("4. anisotropic signature")
gamma = (1.0, 1.0, 2.0)      # channel 2 is 'rougher': fewer high-order terms
aw = anisotropic_words(gamma, r=3.0)
print(f"|W^gamma_(<=3)| = {len(aw)} vs |W_(<=3)| = {sig_dim(d, 3)}")
aniso = projected_signature(path, aw, d)
print(f"anisotropic signature: {aniso.shape}")

# 5. DAG-constrained word sets (paper §7.1) --------------------------------
section("5. DAG word sets")
edges = [(0, 1), (1, 2), (2, 2)]                  # channel interaction graph
dw = dag_words(edges, d, 3)
print(f"W_(<=3)(G) for chain graph: {len(dw)} words -> "
      f"{projected_signature(path, dw, d).shape}")

# 6. Log-signatures in the Lyndon basis (paper §3.3) -----------------------
section("6. log-signature (Lyndon basis)")
ls = logsignature(path, N)
lsp = logsignature_projected(path, N)             # never materialises full level N
print(f"logsig dim = {ls.shape[-1]} (= #Lyndon words = "
      f"{len(lyndon_words(d, N))}); dense vs projected max|err| = "
      f"{jnp.max(jnp.abs(ls - lsp)):.2e}")

# 7. Windowed signatures in one call (paper §5) ----------------------------
section("7. windowed signatures")
wins = sliding_windows(M, length=10, stride=5)
ws = windowed_signature(path, wins, depth=3)
print(f"{wins.shape[0]} windows in one call -> {ws.shape}")

# 8. Lead-lag + quadratic variation (paper §8) -----------------------------
section("8. lead-lag transform")
ll = lead_lag(path)                               # (B, 2M+1, 2d)
area = signature(ll, 2)
print(f"lead-lag path: {ll.shape}; level-2 signature encodes the "
      f"discrete quadratic variation")

# 9. Pallas TPU kernels (validated on CPU in interpret mode) ---------------
section("9. Pallas kernels (interpret mode on CPU)")
incs = tops.path_increments(path)
k_out = K.signature(incs, N, backend="pallas_interpret", batch_tile=8)
print(f"cone kernel vs oracle max|err| = "
      f"{jnp.max(jnp.abs(k_out - sig)):.2e}")
kp = K.projected(incs, words, backend="pallas_interpret", batch_tile=8)
print(f"word-tile kernel vs oracle max|err| = "
      f"{jnp.max(jnp.abs(kp - proj)):.2e}")

print("\nquickstart OK")
