"""Data-parallel signature training on a (fake) 8-device mesh.

One context manager makes the whole stack SPMD: a `sharding_ctx(mesh)`
installed around the training loop

- splits every signature/Gram batch over the mesh's "batch" logical axis
  (`repro.kernels.ops` wraps each dispatch cell in `shard_map`),
- runs the signature-MMD Gram legs through the cross-device `ppermute`
  ring (O(B·D_sig) communication, no replicated Gram-sized intermediate),
- and turns `train_loop` data-parallel (params replicated, batches placed
  with `batch_specs`, gradient mean = XLA's all-reduce).

The demo fits a tiny path-generator to a drifted random-walk distribution
by gradient descent on the unbiased signature-MMD², then shows the same
context serving ragged traffic through a mesh-placed DynamicBatcher.

Run:  PYTHONPATH=src python examples/distributed_training.py
(8 host devices are forced below — no accelerator needed.)
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.distributed import sharding_ctx                    # noqa: E402
from repro.launch.mesh import make_sig_mesh                   # noqa: E402
from repro.optim import adamw                                 # noqa: E402
from repro.sigkernel import sig_mmd                           # noqa: E402

DEPTH, D_CH, M_STEPS, BATCH = 3, 2, 24, 16


def target_paths(n, seed):
    """The distribution to match: drifted, anisotropic random walks."""
    rng = np.random.default_rng(seed)
    steps = rng.normal(size=(n, M_STEPS, D_CH)) * (0.2, 0.35) + (0.08, 0.0)
    return jnp.asarray(np.concatenate(
        [np.zeros((n, 1, D_CH)), np.cumsum(steps, 1)], 1).astype(np.float32))


def generate(params, noise):
    """Tiny generator: per-channel scale + drift applied to white noise."""
    steps = noise * params["scale"] + params["drift"]
    return jnp.concatenate([jnp.zeros_like(steps[:, :1]),
                            jnp.cumsum(steps, axis=1)], axis=1)


def main():
    mesh = make_sig_mesh()                 # all (8 forced) devices, 1 axis
    print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")
    params = {"scale": jnp.ones((D_CH,)) * 0.1, "drift": jnp.zeros((D_CH,))}

    norm = float(np.sqrt(M_STEPS))         # sqrt-length path normalisation

    def loss_fn(params, noise, ref):
        fake = generate(params, noise)
        return sig_mmd(fake / norm, ref / norm, DEPTH, backend="auto")

    opt = adamw(lr=2e-2)
    opt_state = opt.init(params)

    with sharding_ctx(mesh):               # <- the only multi-device line
        step = jax.jit(jax.value_and_grad(loss_fn))
        rng = np.random.default_rng(0)
        for it in range(120):
            noise = jnp.asarray(rng.normal(
                size=(BATCH, M_STEPS, D_CH)).astype(np.float32))
            ref = target_paths(BATCH, seed=1000 + it)
            mmd, g = step(params, noise, ref)
            updates, opt_state = opt.update(g, opt_state, params)
            params = jax.tree.map(jnp.add, params, updates)
            if it % 30 == 0 or it == 119:
                print(f"  it={it:3d}  sig-MMD²={float(mmd):+.5f}  "
                      f"scale={np.round(np.asarray(params['scale']), 3)}  "
                      f"drift={np.round(np.asarray(params['drift']), 3)}")

    print("target  |scale|≈[0.2, 0.35] (sign unidentifiable from white "
          "noise), drift≈[0.08, 0.0]; MMD²≈0 means matched")

    # --- the same mesh serving ragged traffic ---------------------------
    from repro.serve import DynamicBatcher
    db = DynamicBatcher.signature_service(D_CH, DEPTH, max_len=64,
                                          backend="auto", min_bucket=8,
                                          mesh=mesh)
    rng = np.random.default_rng(7)
    reqs = [np.cumsum(rng.normal(size=(L + 1, D_CH)).astype(np.float32), 0)
            for L in rng.integers(2, 64, size=25)]
    tickets = [db.submit(r) for r in reqs]
    feats = db.flush()
    st = db.stats()
    print(f"served {len(feats)} requests over {st['devices']} devices: "
          f"{st['compiled_shapes']} compiled shapes, "
          f"{st['rows_per_device']} rows/device, "
          f"occupancy {st['occupancy']:.0%}")


if __name__ == "__main__":
    main()
