"""Batched serving example: KV-cache decode across architecture families.

Serves three reduced architectures — a GQA transformer (qwen3 family), an
attention-free RWKV6, and the hybrid Mamba2+shared-attention zamba2 — with
the same ServeEngine, demonstrating that the cache abstraction covers
KV caches, recurrent states, and mixed state types.

Run:  PYTHONPATH=src python examples/serve_lm.py

Variable-length signature traffic is served by a different layer: see
examples/ragged_serving.py for the `repro.serve.DynamicBatcher` demo
(length-bucketed micro-batching over `repro.ragged` containers).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

import repro.models as M
from repro.configs import get_config, reduce_config
from repro.serve import ServeEngine


def demo(arch: str, n_new: int = 24) -> None:
    cfg = reduce_config(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    engine = ServeEngine(cfg, params, max_len=64, temperature=0.8)
    prompts = jnp.asarray(
        [[1, 5, 9, 2], [3, 3, 7, 1], [2, 8, 4, 6], [9, 1, 1, 5]],
        jnp.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, n_new, rng=jax.random.PRNGKey(42))
    dt = time.perf_counter() - t0
    toks = out.shape[0] * n_new
    print(f"{arch:<22} family={cfg.family:<8} batch={out.shape[0]} "
          f"generated={n_new}/seq  {toks/dt:7.1f} tok/s")
    print(f"   sample: {out[0].tolist()}")


def main() -> None:
    for arch in ("qwen3-4b", "rwkv6-1.6b", "zamba2-7b"):
        demo(arch)
    print("\nserve OK (reduced configs; production decode is the same "
          "serve_step the decode_32k/long_500k dry-run cells lower)")


if __name__ == "__main__":
    main()
