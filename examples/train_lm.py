"""End-to-end LM training driver with checkpoint/restart.

Trains a small decoder LM (same code path as every assigned architecture)
on the synthetic token stream, demonstrating the production substrate:
config-driven model build, AdamW + warmup-cosine schedule, remat policy,
checkpoint save + mid-run restart (fault tolerance), and the optional
signature pooling head.

Run:  PYTHONPATH=src python examples/train_lm.py                # ~4M params
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp

import repro.models as M
from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config, reduce_config
from repro.data.pipeline import TokenStream
from repro.optim import adamw, linear_warmup_cosine
from repro.train import TrainLoopConfig, train_loop

PRESETS = {
    # name: (d_model, n_layers, n_heads, n_kv, d_ff, vocab, batch, seq)
    "nano": (256, 4, 8, 4, 768, 2048, 4, 128),       # ~4M params, CPU-fast
    "100m": (768, 12, 12, 4, 2304, 16384, 8, 512),   # ~100M params
}


def build_cfg(preset: str):
    d, L, H, KV, FF, V, B, S = PRESETS[preset]
    base = reduce_config(get_config("qwen3-4b"))     # GQA + qk_norm family
    cfg = dataclasses.replace(base, name=f"lm-{preset}", n_layers=L,
                              d_model=d, n_heads=H, n_kv_heads=KV, d_ff=FF,
                              vocab_size=V, head_dim=d // H)
    return cfg, B, S


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="nano", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    ap.add_argument("--no-restart-demo", action="store_true")
    args = ap.parse_args(argv)

    cfg, B, S = build_cfg(args.preset)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M  "
          f"batch={B}x{S}")
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw(lr=linear_warmup_cosine(3e-4, args.steps // 10, args.steps))
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    stream = TokenStream(cfg.vocab_size, B, S, seed=0)

    def log(step, m):
        print(f"  step {m['step']:>4}  loss {m['loss']:.4f}  "
              f"|g| {m['grad_norm']:.3f}  {m['sec']*1e3:.0f} ms")

    half = args.steps // 2
    print(f"\nphase 1: train to step {half}, checkpoint every 10")
    loop1 = TrainLoopConfig(steps=half, log_every=10, ckpt_every=10,
                            ckpt_dir=args.ckpt_dir)
    params, _, hist1 = train_loop(cfg, params, opt, iter(stream), loop1,
                                  checkpointer=ckpt, on_metrics=log)
    ckpt.wait()

    if not args.no_restart_demo:
        print(f"\nphase 2: simulate preemption -> restart from latest "
              f"checkpoint (step {latest_step(args.ckpt_dir)})")
        # fresh process state: rebuild params/opt shapes, restore from disk
        params2 = M.init_params(jax.random.PRNGKey(1), cfg, jnp.float32)
        opt_state2 = opt.init(params2)
        step0 = latest_step(args.ckpt_dir)
        params2, opt_state2, extra = ckpt.restore(params2, opt_state2, step0)
        stream2 = TokenStream(cfg.vocab_size, B, S, seed=0)
        stream2.restore({"step": step0, "seed": 0})   # resume the data stream
        loop2 = TrainLoopConfig(steps=args.steps, log_every=10,
                                ckpt_every=0, ckpt_dir=args.ckpt_dir)
        step_fn = jax.jit(
            __import__("repro.train", fromlist=["make_train_step"])
            .make_train_step(cfg, opt))
        for step in range(step0, args.steps):
            batch = next(stream2)
            params2, opt_state2, m = step_fn(params2, opt_state2, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"  step {step:>4}  loss {float(m['loss']):.4f}")
        final_loss = float(m["loss"])
    else:
        final_loss = hist1[-1]["loss"]

    first_loss = hist1[0]["loss"]
    print(f"\nloss {first_loss:.3f} -> {final_loss:.3f} "
          f"({'improved' if final_loss < first_loss else 'NO IMPROVEMENT'})")
    ckpt.wait()


if __name__ == "__main__":
    main()
