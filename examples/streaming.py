"""Streaming signatures end to end: per-step outputs, window routes, and the
online SignatureStream / SigStreamEngine state.

Run:  PYTHONPATH=src python examples/streaming.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SignatureStream, signature, signature_from_increments,
                        signature_stream_init, select_route, sig_dim,
                        sliding_windows, stream_emit_steps,
                        windowed_signature)
from repro.core import tensor_ops as tops
from repro.kernels import ops as K
from repro.serve import SigStreamEngine

rng = np.random.default_rng(0)


def section(title):
    print(f"\n--- {title} " + "-" * max(0, 60 - len(title)))


B, M, d, N = 4, 64, 3, 3
path = jnp.asarray(np.cumsum(rng.standard_normal((B, M + 1, d)), axis=1),
                   jnp.float32) * 0.1
incs = tops.path_increments(path)

# 1. Streamed forward: all prefix signatures in one pass -------------------
section("1. streamed signatures (stream=True)")
stream = signature(path, N, stream=True)                 # (B, M, D_sig)
strided = signature(path, N, stream=True, stream_stride=8)
print(f"full stream {stream.shape}; stride 8 -> {strided.shape} "
      f"(steps {[int(s) for s in stream_emit_steps(M, 8)][:4]}..., "
      f"terminal always kept)")
print(f"last step == terminal signature: "
      f"{jnp.max(jnp.abs(stream[:, -1] - signature(path, N))):.2e}")

# 2. Same axis on the Pallas kernels (interpret mode on CPU) ---------------
section("2. streamed Pallas kernel + streamed backward")
k_stream = K.signature(incs, N, backend="pallas_interpret", batch_tile=8,
                       stream=True, stream_stride=8)
print(f"kernel stream vs jax scan max|err| = "
      f"{jnp.max(jnp.abs(k_stream - strided)):.2e}")
g = jax.grad(lambda z: jnp.sum(K.signature(
    z, N, backend="pallas_interpret", batch_tile=8, stream=True) ** 2))(incs)
print(f"grad through streamed kernel (one generalised §4.2 reverse scan): "
      f"{g.shape}, finite={bool(jnp.all(jnp.isfinite(g)))}")

# 3. Window routes: fold vs chen over the streamed forward -----------------
section("3. windowed signatures: route='auto'")
wins = sliding_windows(M, length=32, stride=2)           # heavy overlap
print(f"{wins.shape[0]} overlapping windows; cost model picks "
      f"route={select_route('auto', wins, M)!r}")
a = windowed_signature(path, wins, N, route="fold")
b = windowed_signature(path, wins, N, route="chen")
print(f"fold vs chen max|err| = {jnp.max(jnp.abs(a - b)):.2e}")

# 4. Online updates: SignatureStream ---------------------------------------
section("4. SignatureStream: extend + rolling_drop")
st = signature_stream_init(B, d, N, capacity=32)
st = st.extend(incs[:, :20]).extend(incs[:, 20:32])
st = st.rolling_drop(8)                                  # slide left edge
fresh = signature_from_increments(incs[:, 8:32], N)
print(f"extend+drop vs fresh window max|err| = "
      f"{jnp.max(jnp.abs(st.sig - fresh)):.2e} (window length {st.length})")

# 5. Batched serving: SigStreamEngine --------------------------------------
section("5. SigStreamEngine: hopping-window features")
eng = SigStreamEngine(d=d, depth=N, batch=B, window=24, backend="jax")
for k in range(8):                                       # chunks of 8 steps
    feats = eng.push(incs[:, 8 * k:8 * (k + 1)])
print(f"per-chunk features {feats.shape}; window signature "
      f"{eng.features.shape} over the last {eng.state.length} steps")

print("\nstreaming example OK")
