"""End-to-end observability walkthrough: trace + meter every layer.

One run exercises all four instrumented layers of the stack and leaves two
artefacts behind:

- a Chrome-trace JSON (open in ``chrome://tracing`` / ui.perfetto.dev)
  containing spans from **kernel dispatch** (``kernels.signature``),
  the **gram ring** under an 8-device mesh (``kernels.gram_ring``),
  a **serve flush** (``serve.batcher.flush``, ``serve.sessions.flush``),
  and **train steps** (``train.step``);
- a metrics snapshot (JSON) with nonzero jit compile/retrace counts,
  plan-cache accounting, and autotune hit/miss/sweep outcomes.

Run:  PYTHONPATH=src python examples/observability.py
      PATHSIG_TRACE=trace.json PYTHONPATH=src python examples/observability.py
      PYTHONPATH=src python examples/observability.py --check   # CI smoke

Defaults land under ``runs/`` (gitignored); ``PATHSIG_TRACE`` /
``PATHSIG_METRICS`` override the artefact paths.  ``--check`` asserts the
acceptance conditions (spans from all four layers, nonzero compile /
plan-cache / autotune counters, retrace counts within bound) and exits
nonzero on violation.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# a throwaway autotune cache so the walkthrough shows sweep -> hit without
# touching (or depending on) the repo-level .pathsig_autotune.json
os.environ["PATHSIG_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="pathsig_obs_"), "autotune.json")
os.environ["PATHSIG_AUTOTUNE"] = "sweep"

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro import obs                                         # noqa: E402
from repro.distributed import sharding_ctx                    # noqa: E402
from repro.distributed.hlo import collective_stats            # noqa: E402
from repro.kernels import ops                                 # noqa: E402
from repro.launch.mesh import make_sig_mesh                   # noqa: E402

TRACE_PATH = os.environ.get("PATHSIG_TRACE", "runs/observability_trace.json")
SNAP_PATH = os.environ.get("PATHSIG_METRICS", "")
if SNAP_PATH.lower() in ("", "0", "1", "on", "off", "true", "false", "yes",
                         "no"):
    SNAP_PATH = "runs/observability_metrics.json"


def kernel_layer(rng) -> None:
    """Dispatch cells + autotune + compile accounting."""
    print("== kernel dispatch ==")
    x = jnp.asarray(rng.normal(size=(8, 12, 2)).astype(np.float32) * 0.1)
    # 1st call in sweep mode: autotune measures the cell (outcome="sweep"),
    # 2nd call: outcome="hit"; the kernel itself compiles exactly once.
    for _ in range(2):
        ops.signature(x, 3, backend="pallas_interpret").block_until_ready()
    # a second shape — a genuine retrace, labelled with its shape key
    ops.signature(x[:, :7], 3, backend="pallas_interpret").block_until_ready()
    cost = obs.record_cost(
        "signature", lambda a: ops.signature(a, 3, backend="pallas_interpret"),
        x)
    print(f"  lowered cost: {cost['flops']:.0f} flops, "
          f"{cost['bytes']:.0f} bytes")


def ring_layer(rng, mesh) -> None:
    """The gram ppermute ring under the mesh + HLO collective accounting."""
    print("== gram ring (8-device mesh) ==")
    Sx = jnp.asarray(rng.normal(size=(16, 15)).astype(np.float32))
    w = jnp.ones(15, np.float32)
    with sharding_ctx(mesh):
        G = ops.gram(Sx, Sx, w, backend="jax")
        G.block_until_ready()
        compiled = jax.jit(
            lambda a, b, ww: ops.gram(a, b, ww, backend="jax")
        ).lower(Sx, Sx, w).compile()
    stats = collective_stats(compiled.as_text(),
                             default_group=len(mesh.devices.flat))
    obs.record_collectives("gram_ring", stats)
    print(f"  ring G shape {G.shape}; HLO collectives: "
          f"{ {k: v[0] for k, v in stats.by_kind.items()} }")


def serve_layer(rng) -> None:
    """A batcher flush and a session-pool flush."""
    print("== serve ==")
    from repro.serve import DynamicBatcher
    from repro.serve.sessions import SessionStore
    db = DynamicBatcher.signature_service(2, 3, max_len=32, backend="jax",
                                          min_bucket=8)
    for L in (3, 9, 17, 5, 30):
        db.submit(np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32),
                            axis=0))
    res = db.flush()
    st = db.stats()
    print(f"  batcher: {len(res)} requests, {st['compiled_shapes']} shapes, "
          f"occupancy {st['occupancy']:.0%}")

    store = SessionStore(2, 3, initial_sessions=8, backend="jax")
    handles = [store.create() for _ in range(5)]
    for h in handles:
        store.ingest(h, rng.normal(size=(4, 2)).astype(np.float32))
    store.flush()
    store.evict(handles[0])
    ss = store.stats()
    print(f"  sessions: {ss['sessions']} live, "
          f"p50 staleness {ss['p50_staleness_s'] * 1e3:.2f} ms, "
          f"evictions {ss['evictions']}")


def train_layer() -> None:
    """A traced mini train loop (sig-MMD loss through the dispatch)."""
    print("== train ==")
    import dataclasses
    import repro.models as M
    from repro.configs import get_config, reduce_config
    from repro.models.sig_head import SigHeadConfig
    from repro.optim import adamw
    from repro.train import TrainLoopConfig, train_loop

    cfg = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(cfg, sig_head=SigHeadConfig(
        depth=3, channels=2, backend="jax"))
    loop = TrainLoopConfig(steps=3, log_every=1, loss="sig_mmd",
                           run_name="observability",
                           straggler_deadline_s=60.0)

    def make_iter(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            yield {"tokens": jnp.asarray(rng.integers(
                       1, cfg.vocab_size, (8, 16)), jnp.int32),
                   "paths": jnp.asarray(np.cumsum(rng.normal(
                       size=(8, 17, 2)).astype(np.float32), 1) * 0.3)}

    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    _, _, hist = train_loop(cfg, params, adamw(lr=1e-3), make_iter(), loop)
    print(f"  {len(hist)} logged steps; loss {hist[-1]['loss']:.4f}; "
          f"run log under runs/observability.jsonl")


LAYER_SPANS = {
    "kernel dispatch": ("kernels.signature",),
    "gram ring": ("kernels.gram_ring",),
    "serve flush": ("serve.batcher.flush", "serve.sessions.flush"),
    "train step": ("train.step",),
}


def check(trace_path: str, snap_path: str) -> int:
    """CI smoke assertions over the two artefacts; returns an exit code."""
    doc = json.load(open(trace_path))
    names = {e["name"] for e in doc["traceEvents"]}
    failures = []
    for layer, spans in LAYER_SPANS.items():
        if not any(s in names for s in spans):
            failures.append(f"no {layer} span ({spans}) in {trace_path}")
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X" and not ({"name", "ts", "dur", "pid", "tid"}
                                    <= set(ev)):
            failures.append(f"malformed trace event {ev}")
            break

    snap = json.load(open(snap_path))
    mets = snap["metrics"]

    def total(name, pred=lambda v: True):
        return sum(row["value"] for row in mets.get(
            name, {"values": []})["values"] if pred(row))

    if total("pathsig_jit_traces_total") <= 0:
        failures.append("zero jit compile/retrace count")
    # retrace bound: the mini run must not retrace any one site more than
    # 8 compiled variants (a storm means shape keys leak into the cells)
    for row in mets.get("pathsig_jit_traces_total", {"values": []})["values"]:
        if row["value"] > 8:
            failures.append(f"retrace storm: {row}")
    if total("pathsig_plan_cache",
             lambda r: r["labels"]["stat"] in ("hits", "misses")) <= 0:
        failures.append("zero plan-cache hit/miss accounting")
    if total("pathsig_autotune_lookups_total",
             lambda r: r["labels"]["outcome"] in ("hit", "miss", "sweep")) \
            <= 0:
        failures.append("zero autotune hit/miss/sweep outcomes")
    if total("pathsig_ring_ppermute_total") <= 0:
        failures.append("zero gram-ring ppermute count")
    for f in failures:
        print(f"CHECK FAIL: {f}", file=sys.stderr)
    print("check:", "FAIL" if failures else "OK")
    return 1 if failures else 0


def main() -> int:
    obs.enable()
    if not obs.trace_active():          # PATHSIG_TRACE already started one
        obs.start_trace(TRACE_PATH)
    rng = np.random.default_rng(0)
    mesh = make_sig_mesh()
    kernel_layer(rng)
    ring_layer(rng, mesh)
    serve_layer(rng)
    train_layer()
    trace_path = obs.stop_trace(TRACE_PATH) or TRACE_PATH
    snap_path = obs.write_snapshot(SNAP_PATH)
    print(f"trace  -> {trace_path}\nmetrics -> {snap_path}")
    n_traces = sum(
        row["value"] for row in obs.snapshot()["metrics"]
        ["pathsig_jit_traces_total"]["values"])
    print(f"total jit traces (compiles) this run: {n_traces:.0f}")
    if "--check" in sys.argv:
        return check(trace_path, snap_path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
