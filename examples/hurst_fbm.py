"""Paper §8: Hurst-parameter estimation on multivariate fBM with a sparse
lead-lag signature projection.

Deep-signature model (cf. Bonnier et al. [19]): a learnable per-channel
scaling phi_theta of the lead-lag path, a signature feature map, and a small
MLP head.  Three feature maps are compared, as in the paper's Figure 4:

- ``fnn``       : flattened raw path -> MLP (no signature),
- ``truncated`` : full truncated lead-lag signature W_{<=N},
- ``sparse``    : the paper's sparse lead-lag word projection
                  W^sparse_{<=N} = {u_1∘…∘u_p : u_j in G}, exploiting
                  component independence (Section 8).

Claims reproduced: the sparse projection reaches equal-or-lower validation
MSE with a several-fold smaller feature dimension and faster training.

Run:  PYTHONPATH=src python examples/hurst_fbm.py [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (generated_words, lead_lag, make_plan, sig_dim,
                        sparse_leadlag_generators)
from repro.core.projection import projected_signature_from_increments
from repro.core.signature import signature_from_increments
from repro.core import tensor_ops as tops
from repro.data.pipeline import hurst_dataset


def init_mlp(key, sizes, out_bias: float = 0.5):
    ps = []
    for k, (a, b) in zip(jax.random.split(key, len(sizes) - 1),
                         zip(sizes[:-1], sizes[1:])):
        ps.append({"w": jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / a),
                   "b": jnp.zeros((b,))})
    # start at the prior mean of H ~ U(0.25, 0.75)
    ps[-1]["b"] = ps[-1]["b"] + out_bias
    return ps


def mlp(ps, x):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1:
            x = jax.nn.gelu(x)
    return x[..., 0]


def make_model(kind: str, d: int, depth: int, M: int, key, sample):
    """Returns (params, apply(params, paths)->H_hat, feature_dim).

    Signature coefficients at different levels live on very different
    scales, so features are whitened with statistics taken at init on a
    reference batch (frozen thereafter) — standard deep-signature practice.
    """
    k1, k2 = jax.random.split(key)
    if kind == "fnn":
        feat_dim = (M + 1) * d
        raw = lambda params, paths: paths.reshape(paths.shape[0], -1)
        params = {"mlp": init_mlp(k2, [feat_dim, 256, 64, 1])}
    else:
        plan = None
        if kind == "sparse":
            words = generated_words(sparse_leadlag_generators(d), depth)
            plan = make_plan(words, 2 * d)
            feat_dim = len(words)
        else:
            feat_dim = sig_dim(2 * d, depth)
        params = {"scale": jnp.ones((d,)),      # phi_theta: per-channel scale
                  "mlp": init_mlp(k2, [feat_dim, 128, 64, 1])}

        def raw(params, paths):
            x = paths * params["scale"][None, None, :]
            ll = lead_lag(x)                     # (B, 2M+1, 2d)
            incs = tops.path_increments(ll)
            if plan is not None:
                f = projected_signature_from_increments(incs, plan)
            else:
                f = signature_from_increments(incs, depth)
            # signature coefficients span decades (level-n terms scale like
            # |X|^n); the signed-log map makes them MLP-friendly
            return jnp.sign(f) * jnp.log1p(jnp.abs(f))

    f0 = jax.jit(raw)(params, sample)            # init-time whitening stats
    mu = jnp.mean(f0, axis=0)
    sd = jnp.std(f0, axis=0) + 1e-6

    def apply(params, paths):
        return mlp(params["mlp"], (raw(params, paths) - mu) / sd)

    return params, apply, feat_dim


def train(kind, Xtr, Htr, Xva, Hva, *, depth, epochs, batch, lr, seed=0):
    d, M = Xtr.shape[-1], Xtr.shape[1] - 1
    params, apply, feat_dim = make_model(kind, d, depth, M,
                                         jax.random.PRNGKey(seed), Xtr[:256])

    def loss_fn(params, x, y):
        pred = apply(params, x)
        return jnp.mean((pred - y) ** 2)

    # Adam
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t, x, y):
        loss, g = jax.value_and_grad(loss_fn)(params, x, y)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
        return params, m, v, loss

    val_loss = jax.jit(loss_fn)
    n = Xtr.shape[0]
    rng = np.random.default_rng(seed)
    curve, t0, t_step = [], time.time(), 1
    for ep in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            params, m, v, _ = step(params, m, v, jnp.float32(t_step),
                                   Xtr[idx], Htr[idx])
            t_step += 1
        vl = float(val_loss(params, Xva, Hva))
        curve.append(vl)
    return {"kind": kind, "feat_dim": feat_dim, "curve": curve,
            "val_mse": curve[-1], "train_s": time.time() - t0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 8000 paths of length 250")
    ap.add_argument("--epochs", type=int, default=0)
    args = ap.parse_args(argv)
    if args.full:
        n_tr, n_va, M, epochs = 8000, 2000, 250, 30
    else:
        n_tr, n_va, M, epochs = 1500, 400, 80, 25
    epochs = args.epochs or epochs
    d, depth, batch, lr = 5, 3, 128, 1e-2

    print(f"generating {n_tr + n_va} fBM paths (d={d}, M={M}, "
          f"H ~ U(0.25, 0.75)) ...")
    X, H = hurst_dataset(seed=0, n_paths=n_tr + n_va, n_steps=M, d=d)
    X = jnp.asarray(X)
    H = jnp.asarray(H)
    Xtr, Htr, Xva, Hva = X[:n_tr], H[:n_tr], X[n_tr:], H[n_tr:]
    var_H = float(jnp.var(Hva))
    print(f"predict-the-mean MSE (floor reference): {var_H:.5f}\n")

    results = [train(k, Xtr, Htr, Xva, Hva, depth=depth, epochs=epochs,
                     batch=batch, lr=lr) for k in ("fnn", "truncated",
                                                   "sparse")]
    print(f"{'model':<12} {'features':>9} {'val MSE':>10} {'train s':>9}")
    for r in results:
        print(f"{r['kind']:<12} {r['feat_dim']:>9} {r['val_mse']:>10.5f} "
              f"{r['train_s']:>9.1f}")
    tr = next(r for r in results if r["kind"] == "truncated")
    sp = next(r for r in results if r["kind"] == "sparse")
    print(f"\nsparse vs truncated: {tr['feat_dim'] / sp['feat_dim']:.2f}x "
          f"fewer features, {tr['train_s'] / sp['train_s']:.2f}x faster "
          f"training, val MSE {sp['val_mse']:.5f} vs {tr['val_mse']:.5f}")
    print("validation curves (per epoch):")
    for r in results:
        print(f"  {r['kind']:<10}", " ".join(f"{x:.4f}" for x in r["curve"]))


if __name__ == "__main__":
    main()
