"""Kernel methods on path signatures: the repro.sigkernel subsystem end to end.

Four demos, CPU-sized:

1. Weighted/projected Gram matrices — the truncated signature kernel with
   anisotropic channel weights, tiled so the (B_x, B_y, D_sig) intermediate
   never exists.
2. Two-sample testing — the unbiased signature-MMD with a permutation test
   separating drifted from driftless random walks.
3. Kernel ridge regression — predict a path functional from the Gram, plus
   the low-rank Nyström features that scale it linearly in batch.
4. Streaming retrieval — SigScoreEngine scoring live streams against a
   cached reference Gram from SignatureStream terminal states.

Run:  PYTHONPATH=src python examples/kernel_methods.py
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import tensor_ops as tops
from repro.serve import SigScoreEngine
from repro.sigkernel import (fit_sig_krr, nystrom_features, sig_gram,
                             sig_mmd)

DEPTH = 3


def walks(n, M, d, drift=0.0, scale=0.25, seed=0):
    rng = np.random.default_rng(seed)
    steps = rng.normal(size=(n, M, d)) * scale + drift
    path = np.concatenate([np.zeros((n, 1, d)), np.cumsum(steps, axis=1)],
                          axis=1)
    return jnp.asarray(path.astype(np.float32))


def demo_gram():
    print("\n# 1. weighted signature Gram (anisotropic channels)")
    x, y = walks(6, 32, 3, seed=0), walks(4, 32, 3, seed=1)
    K = sig_gram(x, y, DEPTH, gamma=(0.5, 1.0, 2.0))
    K_oracle = sig_gram(x, y, DEPTH, gamma=(0.5, 1.0, 2.0), route="oracle")
    err = float(jnp.max(jnp.abs(K - K_oracle)))
    print(f"  K shape {K.shape}, tiled-vs-oracle max err {err:.2e}")


def demo_mmd():
    print("\n# 2. two-sample test: signature MMD + permutation null")
    x = walks(24, 32, 2, drift=+0.06, seed=2)
    y = walks(24, 32, 2, drift=-0.06, seed=3)
    stat = float(sig_mmd(x, y, DEPTH))
    pooled = jnp.concatenate([x, y], axis=0)
    rng = np.random.default_rng(0)
    null = []
    for _ in range(30):
        perm = rng.permutation(pooled.shape[0])
        null.append(float(sig_mmd(pooled[perm[:24]], pooled[perm[24:]],
                                  DEPTH)))
    p = (1 + sum(n >= stat for n in null)) / (1 + len(null))
    print(f"  MMD^2 = {stat:.4f}, permutation p ~ {p:.3f} "
          f"(null 95% ~ {np.quantile(null, 0.95):.4f})")


def demo_krr():
    print("\n# 3. kernel ridge regression + Nystrom features")
    train, test = walks(48, 24, 2, seed=4), walks(12, 24, 2, seed=5)

    def target(paths):  # a nonlinear path functional: signed area-ish
        inc = np.asarray(tops.path_increments(paths))
        x1, x2 = np.cumsum(inc[..., 0], -1), inc[..., 1]
        return jnp.asarray((x1[:, :-1] * x2[:, 1:]).sum(-1).astype(np.float32))

    model = fit_sig_krr(train, target(train), DEPTH, reg=1e-4)
    pred = model.predict(test)
    rmse = float(jnp.sqrt(jnp.mean((pred - target(test)) ** 2)))
    base = float(jnp.std(target(test)))
    print(f"  KRR rmse {rmse:.4f} vs target std {base:.4f}")
    ny = nystrom_features(train[:16], DEPTH)
    phi_tr, phi_te = ny(train), ny(test)
    w, *_ = jnp.linalg.lstsq(phi_tr, target(train), rcond=None)
    rmse_ny = float(jnp.sqrt(jnp.mean((phi_te @ w - target(test)) ** 2)))
    print(f"  Nystrom({ny.n_features} features) linear rmse {rmse_ny:.4f}")


def demo_streaming():
    print("\n# 4. streaming retrieval against a cached reference Gram")
    refs = walks(6, 40, 2, seed=6)
    eng = SigScoreEngine(d=2, depth=DEPTH, batch=6, references=refs,
                         backend="auto")
    incs = tops.path_increments(refs)   # stream the references themselves
    for chunk in jnp.split(incs, 4, axis=1):
        scores = eng.push(chunk)
    hits = int((eng.nearest() == jnp.arange(6)).sum())
    print(f"  after 4 chunks: {hits}/6 streams retrieve their own reference; "
          f"scores diag ~ {float(jnp.diag(scores).mean()):.3f}")


if __name__ == "__main__":
    demo_gram()
    demo_mmd()
    demo_krr()
    demo_streaming()
