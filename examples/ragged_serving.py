"""Ragged serving demo: variable-length paths end to end.

Shows the three layers of `repro.ragged`:

1. exact variable-length signatures from one padded batch (`RaggedPaths` +
   `lengths=` through the engine dispatch — zero-masked padding is the
   identity, so the answers match per-example unpadded calls to the bit);
2. micro-batched serving with `repro.serve.DynamicBatcher`: mixed-length
   requests packed into a bounded ladder of compiled shapes;
3. kernel scoring of ragged traffic against cached references
   (`DynamicBatcher.scoring_service` over a `SigScoreEngine`).

Run:  PYTHONPATH=src python examples/ragged_serving.py
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import signature
from repro.data import geometric_lengths
from repro.ragged import RaggedPaths
from repro.serve import DynamicBatcher, SigScoreEngine

D, DEPTH, MAX_LEN = 3, 4, 256


def make_requests(n: int, seed: int = 0) -> list[np.ndarray]:
    lengths = geometric_lengths(seed, n, MAX_LEN, min_steps=2)
    rng = np.random.default_rng(seed)
    out = []
    for L in lengths:
        steps = rng.standard_normal((int(L), D)).astype(np.float32)
        steps /= np.sqrt(max(int(L), 1))
        out.append(np.concatenate([np.zeros((1, D), np.float32),
                                   np.cumsum(steps, axis=0)], axis=0))
    return out


def main() -> None:
    reqs = make_requests(48)
    print(f"{len(reqs)} requests, lengths "
          f"{sorted(p.shape[0] - 1 for p in reqs)[:6]} ... "
          f"{max(p.shape[0] - 1 for p in reqs)}")

    # 1) one padded batch == per-example unpadded signatures, exactly
    rp = RaggedPaths.from_list(reqs)
    sig = signature(rp, DEPTH)                       # (B, D_sig)
    ref = signature(jnp.asarray(reqs[0])[None], DEPTH)[0]
    print(f"ragged batch: {tuple(sig.shape)}; max |err| vs unpadded call: "
          f"{float(np.max(np.abs(np.asarray(sig[0]) - np.asarray(ref)))):.1e}")

    # 2) dynamic batching: a bounded set of compiled shapes serves any mix
    db = DynamicBatcher.signature_service(D, DEPTH, max_len=MAX_LEN,
                                          backend="jax", min_bucket=32)
    t0 = time.perf_counter()
    tickets = [db.submit(p) for p in reqs]
    res = db.flush()
    dt = time.perf_counter() - t0
    st = db.stats()
    print(f"DynamicBatcher: {len(res)} requests in {dt*1e3:.0f} ms "
          f"(cold, incl. compiles) using {st['compiled_shapes']} compiled "
          f"shapes (ladder {st['ladder']}), padding overhead "
          f"{st['padding_overhead']:.2f}x")
    err = max(float(np.max(np.abs(np.asarray(res[t]) - np.asarray(sig[i]))))
              for i, t in enumerate(tickets))
    print(f"   max |err| vs the ragged batch: {err:.1e}")

    # 3) kernel scoring of ragged traffic against cached references
    refs = np.cumsum(np.random.default_rng(7).standard_normal(
        (8, 33, D)).astype(np.float32) * 0.18, axis=1)
    engine = SigScoreEngine(d=D, depth=DEPTH, batch=4,
                            references=jnp.asarray(refs), backend="jax")
    sb = DynamicBatcher.scoring_service(engine, max_len=MAX_LEN,
                                        mode="nearest", min_bucket=32)
    t2 = [sb.submit(p) for p in reqs[:8]]
    nearest = sb.flush()
    print(f"scoring_service(nearest): "
          f"{[int(nearest[t]) for t in t2]} (reference indices)")
    print("\nragged serving OK — see benchmarks/ragged_throughput.py for "
          "bucketed vs pad-to-max vs per-request numbers, and "
          "examples/sessions_serving.py for the STATEFUL serving path "
          "(pooled multi-tenant sessions with checkpoint/restore)")


if __name__ == "__main__":
    main()
