"""Ragged serving throughput: bucketed DynamicBatcher vs the two naive plans.

The serving question the ragged subsystem answers: mixed-length signature
requests arrive continuously (geometric-ish lengths, max/median >= 4 — the
``repro.data.geometric_lengths`` traffic model) and must be served by a
compiled runtime.  Three physical plans compute the SAME exact per-request
answers (zero-masked padding is the identity):

- ``per_request``  — one jitted call per request at its exact length:
  batch=1 utilisation and one compiled executable per distinct length.
- ``pad_to_max``   — every flush round padded to the global max length:
  one big batch, but every row pays M_max scan steps.
- ``bucketed``     — :class:`repro.serve.DynamicBatcher`: lengths rounded
  up a geometric bucket ladder, batch rows rounded up a power-of-two rung;
  work ∝ Σ bucket-padded lengths, compiled shapes bounded by
  ladder × batch-rungs regardless of traffic.

Per strategy this bench reports cold wall-clock (first epoch, compiles
included), warm wall-clock (steady state), compiled-shape count and padded
scan-step totals, plus an explicit ``comparison`` block recording whether
the bucketed plan beats pad-to-max (wall-clock and/or shape count) — the
acceptance gate.  Results land in ``BENCH_ragged.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import geometric_lengths
from repro.kernels import ops
from repro.serve import DynamicBatcher
from .common import header, row

BACKEND = os.environ.get("PATHSIG_BACKEND", "jax")
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_ragged.json")


def make_workload(seed: int, n_requests: int, max_len: int, d: int,
                  n_rounds: int):
    """Mixed-length request paths, split into flush rounds (arrival windows).

    Lengths come from the shared ``repro.data`` pipeline so trainer,
    benchmark and example traffic agree on the distribution.
    """
    lengths = geometric_lengths(seed, n_requests, max_len, min_steps=2)
    rng = np.random.default_rng((seed, 1))
    reqs = []
    for L in lengths:
        steps = rng.standard_normal((int(L), d)).astype(np.float32)
        steps /= np.sqrt(max(int(L), 1))
        reqs.append(np.concatenate([np.zeros((1, d), np.float32),
                                    np.cumsum(steps, axis=0)], axis=0))
    bounds = np.linspace(0, n_requests, n_rounds + 1).astype(int)
    rounds = [reqs[bounds[i]:bounds[i + 1]] for i in range(n_rounds)]
    return rounds, lengths


def _sync(x):
    jax.block_until_ready(x)
    return x


def make_per_request(depth):
    """State built ONCE: the jit cache persists across epochs, so the warm
    epoch measures steady-state serving, not re-tracing."""
    fn = jax.jit(lambda a: ops.signature(a, depth, backend=BACKEND))

    def epoch(rounds):
        out, shapes, steps = [], set(), 0
        for rnd in rounds:
            for p in rnd:
                incs = jnp.asarray(p[1:] - p[:-1])[None]
                shapes.add(incs.shape[1:])
                steps += incs.shape[1]
                out.append(_sync(fn(incs))[0])
        return out, {"compiled_shapes": len(shapes), "padded_steps": steps}

    return epoch


def make_pad_to_max(depth, max_len, max_batch):
    """Every flush round padded to the global max; the batch axis rides the
    same power-of-two rung ladder as the bucketed plan (so the comparison
    isolates LENGTH padding, the axis this benchmark is about)."""
    from repro.ragged import RaggedPaths, batch_rung, pad_batch
    fn = jax.jit(lambda rp: ops.signature(
        rp.values[:, 1:] - rp.values[:, :-1], depth, backend=BACKEND,
        lengths=rp.lengths))

    def epoch(rounds):
        out, shapes, steps = [], set(), 0
        for rnd in rounds:
            for off in range(0, len(rnd), max_batch):
                part = rnd[off:off + max_batch]
                rp = RaggedPaths.from_list(part, pad_to=max_len)
                B_pad = batch_rung(len(part), max_batch)
                rp = pad_batch(rp, B_pad)
                shapes.add((max_len, B_pad))
                steps += max_len * B_pad
                res = _sync(fn(rp))
                out.extend(res[i] for i in range(len(part)))
        return out, {"compiled_shapes": len(shapes), "padded_steps": steps}

    return epoch


def make_bucketed(d, depth, max_len, max_batch, min_bucket):
    db = DynamicBatcher.signature_service(
        d, depth, max_len=max_len, backend=BACKEND,
        max_batch=max_batch, min_bucket=min_bucket)

    def epoch(rounds):
        out = {}
        for rnd in rounds:
            tickets = [db.submit(p) for p in rnd]
            res = db.flush()
            jax.block_until_ready(list(res.values()))
            out.update({t: res[t] for t in tickets})
        stats = db.stats()
        return [out[t] for t in sorted(out)], \
            {"compiled_shapes": stats["compiled_shapes"],
             "padded_steps": stats["padded_steps"],
             "padding_overhead": stats["padding_overhead"],
             "ladder": stats["ladder"]}

    return epoch


def _epoch(fn):
    t0 = time.perf_counter()
    out, stats = fn()
    return out, stats, time.perf_counter() - t0


def bench(seed, n_requests, max_len, d, depth, n_rounds, max_batch,
          min_bucket):
    rounds, lengths = make_workload(seed, n_requests, max_len, d, n_rounds)
    tag = (f"n={n_requests};max_len={max_len};d={d};N={depth};"
           f"backend={BACKEND}")
    med = float(np.median(lengths))
    row("ragged/lengths", f"max={lengths.max()};median={med:.0f}",
        "steps", f"{tag};max_over_median={lengths.max()/med:.2f}")

    strategies = {
        "per_request": make_per_request(depth),
        "pad_to_max": make_pad_to_max(depth, max_len, max_batch),
        "bucketed": make_bucketed(d, depth, max_len, max_batch, min_bucket),
    }
    results, records = {}, {}
    for name, fn in strategies.items():
        out_cold, stats, t_cold = _epoch(lambda: fn(rounds))  # + compiles
        out_warm, _, t_warm = _epoch(lambda: fn(rounds))      # steady state
        results[name] = out_warm
        records[name] = dict(stats, cold_s=t_cold, warm_s=t_warm,
                             req_per_s_warm=n_requests / t_warm)
        row(f"ragged/{name}_warm", f"{t_warm*1e3:.1f}", "ms",
            f"{tag};shapes={stats['compiled_shapes']}")
        row(f"ragged/{name}_cold", f"{t_cold*1e3:.1f}", "ms", tag)

    # exactness: all three plans must agree to float tolerance
    ref = np.stack([np.asarray(x) for x in results["per_request"]])
    for name in ("pad_to_max", "bucketed"):
        got = np.stack([np.asarray(x) for x in results[name]])
        err = float(np.max(np.abs(got - ref)))
        records[name]["max_abs_err_vs_per_request"] = err
        row(f"ragged/{name}_err", f"{err:.2e}", "", tag)

    b, p = records["bucketed"], records["pad_to_max"]
    comparison = {
        "workload": {"n_requests": n_requests, "max_len": max_len, "d": d,
                     "depth": depth, "n_rounds": n_rounds,
                     "length_median": med, "length_max": int(lengths.max()),
                     "max_over_median": float(lengths.max() / med)},
        "bucketed_vs_pad_to_max_speedup_warm": p["warm_s"] / b["warm_s"],
        "bucketed_vs_pad_to_max_speedup_cold": p["cold_s"] / b["cold_s"],
        "bucketed_padded_steps_vs_pad_to_max":
            b["padded_steps"] / p["padded_steps"],
        "bucketed_beats_pad_to_max_wallclock": b["warm_s"] < p["warm_s"],
        "bucketed_beats_pad_to_max_shapes":
            b["compiled_shapes"] < p["compiled_shapes"],
        "bucketed_beats_pad_to_max":
            b["warm_s"] < p["warm_s"]
            or b["compiled_shapes"] < p["compiled_shapes"],
        "bucketed_vs_per_request_speedup_warm":
            records["per_request"]["warm_s"] / b["warm_s"],
    }
    row("ragged/bucketed_vs_pad_speedup",
        f"{comparison['bucketed_vs_pad_to_max_speedup_warm']:.2f}", "x", tag)
    row("ragged/bucketed_vs_per_request_speedup",
        f"{comparison['bucketed_vs_per_request_speedup_warm']:.2f}", "x", tag)
    return {"strategies": records, "comparison": comparison}


def run(quick: bool = True) -> None:
    header("ragged: dynamic-batching serving throughput (repro.serve)")
    cfg = dict(seed=0, n_requests=96 if quick else 384,
               max_len=384 if quick else 1024, d=4, depth=4,
               n_rounds=2 if quick else 4, max_batch=64, min_bucket=48)
    rec = bench(**cfg)
    out = {"benchmark": "ragged_throughput", "backend": BACKEND,
           "quick": quick, **rec}
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    row("ragged/json", JSON_PATH, "path", "")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (the default; kept explicit for CI logs)")
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    args = ap.parse_args()
    run(quick=not args.full)
