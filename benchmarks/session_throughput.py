"""Multi-tenant session serving throughput: pooled SessionStore vs
one-SignatureStream-per-session.

The serving question the session subsystem answers: N live tenants (1e4 →
1e6) each hold a running window signature, and every ingest round a bursty,
heavy-tailed subset of them ticks (``repro.data.SessionTickStream``
traffic).  Two physical plans compute the SAME per-session signatures:

- ``per_object`` — the pre-pool design: a dict of per-session
  :class:`repro.core.stream.SignatureStream` carries (batch=1), one
  dispatch call per ticking session per round.  Python object count and
  dispatch count scale with the *ticking set*; device utilisation is
  batch=1.
- ``pooled``     — :class:`repro.serve.SessionStore`: every tenant is a row
  of one struct-of-arrays pool; a round is queued with ``ingest_many`` and
  delivered by ``flush()`` as a handful of tick-rung × row-rung bucketed
  gather → extend → scatter calls, with compiled shapes bounded by
  (tick rungs × row rungs × pool sizes) regardless of traffic.

Per plan and session count this bench reports cold/warm wall-clock, warm
updates/sec, p99 ingest staleness (pooled), compiled-shape counts, and an
explicit ``comparison`` block with the acceptance gate: at >= 1e5 sessions
the pooled plan must clear **5x** per-object throughput with a bounded
compiled-shape count.  Results land in ``BENCH_sessions.json``.

Wall-clock here is CPU wall-clock (see benchmarks.common); the pooled win
is a dispatch-count and batching argument, which is exactly what survives
the change of hardware.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.stream import signature_stream_init
from repro.data import session_tick_stream
from repro.serve import SessionStore
from .common import header, row

BACKEND = os.environ.get("PATHSIG_BACKEND", "jax")
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_sessions.json")

# mean of the traffic model's Pareto(1.2)+1 activity multiplier, used to
# aim the expected ticking-set size at k_per_round
_RATE_MEAN = 6.0


def make_rounds(seed: int, n_sessions: int, d: int, n_rounds: int,
                k_per_round: int, max_ticks: int):
    """Pre-generated ingest rounds from the shared traffic model (workload
    generation is excluded from the timed region)."""
    stream = session_tick_stream(
        n_sessions, d, seed=seed, max_ticks=max_ticks,
        tick_prob=min(1.0, k_per_round / (_RATE_MEAN * n_sessions)))
    rounds = []
    for _ in range(n_rounds):
        r = next(stream)
        rounds.append((r["sids"], r["counts"], r["ticks"]))
    return rounds


def run_pooled(d, depth, n_sessions, rounds):
    store = SessionStore(d, depth, initial_sessions=n_sessions,
                         backend=BACKEND)

    def epoch():
        for sids, counts, ticks in rounds:
            store.ingest_many(sids, counts, ticks, auto_create=True)
            store.flush()
        jax.block_until_ready(store.pool.sig)

    t0 = time.perf_counter()
    epoch()
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    epoch()
    warm = time.perf_counter() - t0
    return store, cold, warm


def run_per_object(d, depth, rounds):
    streams = {}

    def epoch():
        out = []
        for sids, counts, ticks in rounds:
            bounds = np.cumsum(counts)[:-1]
            for sid, chunk in zip(sids, np.split(ticks, bounds)):
                st = streams.get(sid)
                if st is None:
                    st = signature_stream_init(1, d, depth)
                streams[sid] = st.extend(chunk[None], backend=BACKEND)
            out.append(streams[sids[-1]].sig if sids else None)
        jax.block_until_ready([x for x in out if x is not None])

    t0 = time.perf_counter()
    epoch()
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    epoch()
    warm = time.perf_counter() - t0
    return streams, cold, warm


def bench(seed, n_sessions, d, depth, n_rounds, k_per_round, max_ticks,
          check_per_object=True):
    rounds = make_rounds(seed, n_sessions, d, n_rounds, k_per_round,
                         max_ticks)
    ticks_per_epoch = int(sum(int(c.sum()) for _, c, _ in rounds))
    touched = len({s for sids, _, _ in rounds for s in sids})
    tag = (f"n={n_sessions};d={d};N={depth};rounds={n_rounds};"
           f"backend={BACKEND}")
    row("sessions/workload", f"{ticks_per_epoch}",
        "ticks", f"{tag};touched={touched}")

    store, p_cold, p_warm = run_pooled(d, depth, n_sessions, rounds)
    stats = store.stats()
    rec = {"n_sessions": n_sessions, "ticks_per_epoch": ticks_per_epoch,
           "touched_sessions": touched,
           "pooled": {"cold_s": p_cold, "warm_s": p_warm,
                      "updates_per_s_warm": ticks_per_epoch / p_warm,
                      "p99_staleness_s": stats["p99_staleness_s"],
                      "p50_staleness_s": stats["p50_staleness_s"],
                      "compiled_shapes": stats["compiled_shapes"],
                      "flush_shapes": [list(s) for s in
                                       stats["flush_shapes"]],
                      "pool_size": stats["pool_size"],
                      "occupancy": stats["occupancy"]}}
    row("sessions/pooled_warm", f"{p_warm*1e3:.1f}", "ms",
        f"{tag};shapes={stats['compiled_shapes']}")
    row("sessions/pooled_updates_per_s",
        f"{ticks_per_epoch / p_warm:.0f}", "1/s", tag)
    row("sessions/pooled_p99_staleness", f"{stats['p99_staleness_s']*1e3:.2f}",
        "ms", tag)

    # the compiled-shape bound the pool design guarantees: tick rungs x
    # row rungs x pool sizes (plus one admission scatter per pool size)
    n_tick_rungs = int(np.log2(store.max_ticks)) + 1
    n_row_rungs = int(np.log2(store.max_rows)) + 1
    shape_bound = n_tick_rungs * n_row_rungs * len(stats["pool_sizes"])
    rec["pooled"]["compiled_shape_bound"] = shape_bound
    rec["pooled"]["shapes_bounded"] = \
        stats["compiled_shapes"] <= shape_bound

    if check_per_object:
        streams, o_cold, o_warm = run_per_object(d, depth, rounds)
        rec["per_object"] = {"cold_s": o_cold, "warm_s": o_warm,
                             "updates_per_s_warm": ticks_per_epoch / o_warm,
                             "live_objects": len(streams)}
        row("sessions/per_object_warm", f"{o_warm*1e3:.1f}", "ms", tag)
        # exactness: both plans saw every round twice -> identical state
        worst = 0.0
        for sid in list(streams)[:8]:
            got = np.asarray(store.features(sid))
            want = np.asarray(streams[sid].sig[0])
            worst = max(worst, float(np.max(np.abs(got - want))))
        rec["max_abs_err_pooled_vs_per_object"] = worst
        row("sessions/pooled_err", f"{worst:.2e}", "", tag)
        speedup = o_warm / p_warm
        rec["pooled_vs_per_object_speedup_warm"] = speedup
        row("sessions/pooled_vs_per_object_speedup", f"{speedup:.2f}", "x",
            tag)
    return rec


def run(quick: bool = True) -> None:
    header("sessions: pooled multi-tenant serving throughput (repro.serve)")
    sweep = [10_000, 100_000] if quick else [10_000, 100_000, 1_000_000]
    cfg = dict(seed=0, d=3, depth=3, n_rounds=2 if quick else 4,
               k_per_round=256 if quick else 512, max_ticks=32)
    points = []
    for n in sweep:
        points.append(bench(n_sessions=n, **cfg))

    gate_points = [p for p in points if p["n_sessions"] >= 100_000
                   and "per_object" in p]
    comparison = {
        "speedup_at_1e5_plus": [p["pooled_vs_per_object_speedup_warm"]
                                for p in gate_points],
        "pooled_beats_per_object_5x": all(
            p["pooled_vs_per_object_speedup_warm"] >= 5.0
            for p in gate_points),
        "shapes_bounded": all(p["pooled"]["shapes_bounded"]
                              for p in points),
    }
    out = {"benchmark": "session_throughput", "backend": BACKEND,
           "quick": quick, "points": points, "comparison": comparison}
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    row("sessions/json", JSON_PATH, "path", "")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (the default; kept explicit for CI logs)")
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    args = ap.parse_args()
    run(quick=not args.full)
