"""Signature-kernel Gram scaling: oracle vs tiled vs Pallas routes.

The kernel subsystem's perf claim is a *memory law*, not just wall-clock:
the tiled route computes G = S_x diag(ω) S_yᵀ blocked over the word axis, so
peak live intermediates are O(B_x·B_y + B·block_words) — never the
(B_x, B_y, D_sig) tensor of the textbook elementwise formula.  This bench
reports, per (B, M, d, N) cell:

- wall-clock of the oracle route, the tiled jax route and the tiled route on
  ``PATHSIG_BACKEND`` (CPU numbers here; the *ratios* are the claim);
- XLA temp bytes of the tiled Gram across a block-size sweep, against the
  would-be full intermediate B_x·B_y·D_sig·4 (the Table-2-style law);
- the MMD-loss gradient cross-check between ``backend="jax"`` and
  ``backend="pallas_interpret"`` (the subsystem's acceptance gate).

Every record lands in ``BENCH_gram.json`` (cwd), matching the convention of
``fig3_windows.py``, so CI tracks the trajectory per PR.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.words import sig_dim
from repro.kernels import ops
from repro.sigkernel import sig_gram, sig_mmd, signature_features, \
    word_weights
from .common import header, make_paths, row, temp_bytes, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_gram.json")

CELLS_QUICK = [  # (B, M, d, N)
    (32, 64, 3, 4),
    (48, 64, 4, 4),
]
CELLS_FULL = CELLS_QUICK + [
    (128, 128, 4, 5),
    (256, 128, 5, 4),
]


def _grad_relerr(g, g_ref):
    denom = float(np.max(np.abs(np.asarray(g_ref)))) + 1e-12
    return float(np.max(np.abs(np.asarray(g) - np.asarray(g_ref)))) / denom


def _bench_cell(B, M, d, N, iters):
    X = make_paths(B, M, d, seed=0)
    Y = make_paths(B, M, d, seed=1)
    D = sig_dim(d, N)
    gamma = tuple(0.5 + 1.5 * k / max(d - 1, 1) for k in range(d))
    tag = f"B={B};M={M};d={d};N={N};D={D};backend={BACKEND}"
    rec = {"B": B, "M": M, "d": d, "depth": N, "D_sig": D,
           "backend": BACKEND, "gamma": gamma}

    def run_route(route, backend):
        return jax.jit(lambda a, b: sig_gram(
            a, b, N, gamma=gamma, route=route, backend=backend))

    t_oracle = time_fn(run_route("oracle", "jax"), X, Y, warmup=1,
                       iters=iters)
    t_tiled = time_fn(run_route("tiled", "jax"), X, Y, warmup=1, iters=iters)
    t_back = time_fn(run_route("tiled", BACKEND), X, Y, warmup=1,
                     iters=iters)
    rec.update(oracle_ms=t_oracle * 1e3, tiled_jax_ms=t_tiled * 1e3,
               tiled_backend_ms=t_back * 1e3)
    row("gram/oracle", f"{t_oracle*1e3:.3f}", "ms", tag)
    row("gram/tiled_jax", f"{t_tiled*1e3:.3f}", "ms", tag)
    row(f"gram/tiled_{BACKEND}", f"{t_back*1e3:.3f}", "ms", tag)

    a = np.asarray(run_route("oracle", "jax")(X, Y))
    b = np.asarray(run_route("tiled", BACKEND)(X, Y))
    err = float(np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-12))
    rec["tiled_vs_oracle_relerr"] = err
    row("gram/tiled_vs_oracle_relerr", f"{err:.2e}", "", tag)

    # memory law: tiled temp bytes across a block sweep vs the would-be
    # (B_x, B_y, D_sig) intermediate
    Sx = signature_features(X, N)
    Sy = signature_features(Y, N)
    w = jnp.asarray(word_weights(d, N, gamma=gamma))
    full = B * B * D * 4
    rec["full_intermediate_bytes"] = full
    sweeps = []
    for block in (128, 512, 2048):
        tb = temp_bytes(lambda sx, sy, ww, blk=block: ops.gram(
            sx, sy, ww, backend="jax", block_words=blk), Sx, Sy, w)
        sweeps.append({"block_words": block, "temp_bytes": tb,
                       "vs_full": tb / full})
        row("gram/tiled_temp_bytes", tb, "bytes",
            f"{tag};block={block};full_intermediate={full}")
    rec["block_sweep"] = sweeps
    return rec


def _mmd_grad_check():
    """jax.grad of the MMD loss: backend='jax' vs 'pallas_interpret'."""
    X = make_paths(6, 24, 3, seed=2)
    Y = make_paths(5, 24, 3, seed=3)

    def loss(backend):
        return jax.grad(lambda a: sig_mmd(a, Y, 3, backend=backend))(X)

    return _grad_relerr(loss("pallas_interpret"), loss("jax"))


def run(quick: bool = True) -> None:
    header("gram: signature-kernel Gram scaling (repro.sigkernel)")
    iters = 3 if quick else 10
    records = [_bench_cell(*cell, iters)
               for cell in (CELLS_QUICK if quick else CELLS_FULL)]
    err = _mmd_grad_check()
    row("gram/mmd_grad_jax_vs_pallas_relerr", f"{err:.2e}", "", "")
    out = {"benchmark": "gram_scaling", "backend": BACKEND,
           "mmd_grad_jax_vs_pallas_relerr": err, "records": records}
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    row("gram/json", JSON_PATH, "path", "")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (the default; kept explicit for CI logs)")
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    args = ap.parse_args()
    run(quick=not args.full)
