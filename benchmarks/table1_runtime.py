"""Paper Table 1 / Figure 1: truncated-signature runtime scaling.

Compares three in-repo engines on identical workloads:

- ``pathsig``    — the engine dispatch (repro.kernels.ops): the resolved
                   backend's forward (Pallas kernel on TPU, levelwise Horner
                   scan elsewhere) + inverse-reconstruction VJP, i.e. the
                   paper's algorithm exactly as the training path runs it.
- ``exp_chen``   — materialise exp(ΔX_j), Chen-multiply (the textbook
                   recursion the paper replaces; iisignature/esig shape).
- ``cumulative`` — keras_sig-style: keep ALL prefix signatures S_{0,t_j}
                   and autodiff through them (O(B·M·D) memory/time shape).

``PATHSIG_BACKEND`` (env; default ``auto``) pins the dispatch backend, so
``PATHSIG_BACKEND=pallas_interpret`` exercises the kernel forward with the
§4.2 backward even on CPU (slow: interpret mode).

The paper's claims validated here (as CPU ratios, not H200 wall-clock):
speedup grows with depth N; pathsig advantage shrinks with M (it does not
parallelise the time axis) but holds; training (fwd+bwd) gap persists.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import tensor_ops as tops
from repro.kernels import ops
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_table1.json")

ENGINES = {
    "pathsig": lambda incs, depth: ops.signature(
        incs, depth, backend=BACKEND, backward="inverse"),
    "exp_chen": lambda incs, depth: tops.signature_exp_chen(incs, depth),
    "cumulative": lambda incs, depth: tops.signature_cumulative(
        incs, depth)[-1],
}


def _train_fn(engine, depth):
    fn = ENGINES[engine]

    def loss(incs):
        return jnp.sum(fn(incs, depth) ** 2)

    return jax.jit(jax.value_and_grad(loss))


def _fwd_fn(engine, depth):
    fn = ENGINES[engine]
    return jax.jit(lambda incs: fn(incs, depth))


# (B, M, d, N) sweeps mirroring the paper's Table 1 sections
SWEEP_DEPTH = [(32, 100, 6, n) for n in (2, 3, 4, 5)]
SWEEP_SEQLEN = [(64, m, 4, 5) for m in (50, 100, 200, 500)]
SWEEP_BATCH = [(b, 200, 10, 3) for b in (1, 16, 64, 128)]


def run(quick: bool = True) -> None:
    header(f"table1: truncated signature runtime (paper Table 1 / Fig 1); "
           f"pathsig backend={BACKEND}")
    cells = SWEEP_DEPTH + SWEEP_SEQLEN + SWEEP_BATCH
    iters = 3 if quick else 10
    for B, M, d, N in cells:
        incs = tops.path_increments(make_paths(B, M, d))
        times = {}
        for mode in ("fwd", "train"):
            for eng in ENGINES:
                fn = _fwd_fn(eng, N) if mode == "fwd" else _train_fn(eng, N)
                t = time_fn(fn, incs, warmup=1, iters=iters)
                times[(mode, eng)] = t
                row(f"table1/{mode}/{eng}", f"{t*1e3:.3f}", "ms",
                    f"B={B};M={M};d={d};N={N}")
        for mode in ("fwd", "train"):
            base = times[(mode, "pathsig")]
            for eng in ("exp_chen", "cumulative"):
                row(f"table1/{mode}/speedup_vs_{eng}",
                    f"{times[(mode, eng)] / base:.2f}", "x",
                    f"B={B};M={M};d={d};N={N}")


# ---------------------------------------------------------------------------
# Optimisation-lever before/after blocks (-> BENCH_table1.json)
# ---------------------------------------------------------------------------
#
# Each block times ONE optimisation lever of the dispatch layer on a paper-
# grid cell, before vs after, on this host:
#
# - fused_transform: transform="time_augment+lead_lag" materialised up front
#   (the (B, M', 2d+1) intermediate + plain sweep) vs fused into the sweep.
# - autotune: dispatch defaults (batch_tile=128) vs the per-cell winner of
#   ``repro.kernels.autotune`` (interpret mode pays real work for batch
#   padding, so tile = bucket(B) is a pure win at small B).
# - bf16: precision="fp32" vs "bf16_fp32" — records the per-level relative
#   error against the fp32 oracle alongside the times (the lever's claim is
#   the memory halving + bounded error; wall-clock parity is acceptable).
# - combined: all three levers off vs all three on, same cell.

_LEVER_CELL_JAX = dict(B=32, M=100, d=6, N=2)       # overhead-dominated
_LEVER_CELL_PALLAS = dict(B=32, M=100, d=3, N=3)    # padding-dominated


def _level_relerr(got, ref, d: int, depth: int):
    """Per-level ||got - ref|| / ||ref|| of flat (B, D_sig) signatures."""
    errs, off = [], 0
    for n in range(1, depth + 1):
        w = d ** n
        g, r = got[:, off:off + w], ref[:, off:off + w]
        errs.append(float(jnp.linalg.norm(g - r) /
                          jnp.maximum(jnp.linalg.norm(r), 1e-30)))
        off += w
    return errs


def _time_pair(before_fn, after_fn, incs, iters):
    t0 = time_fn(jax.jit(before_fn), incs, warmup=2, iters=iters)
    t1 = time_fn(jax.jit(after_fn), incs, warmup=2, iters=iters)
    return t0 * 1e3, t1 * 1e3


def run_levers(quick: bool = True) -> list[dict]:
    from repro.core.transforms import (as_transform, augment_increments,
                                       transform_dim)
    from repro.kernels import autotune
    header("table1-levers: fused transform / autotune / bf16 before-after")
    iters = 3 if quick else 10
    records = []
    tname = "time_augment+lead_lag"
    spec = as_transform(tname)

    # -- lever 1: fused transform (jax engine, overhead-dominated cell) ----
    B, M, d, N = (_LEVER_CELL_JAX[k] for k in "BMdN")
    incs = tops.path_increments(make_paths(B, M, d))

    def mat(x):
        e = augment_increments(x, spec)
        return ops.signature(e, N, backend="jax")

    def fused(x):
        return ops.signature(x, N, backend="jax", transform=spec)

    b_ms, a_ms = _time_pair(mat, fused, incs, iters)
    rec = dict(name="fused_transform", backend="jax", transform=tname,
               B=B, M=M, d=d, N=N, before_ms=b_ms, after_ms=a_ms,
               speedup=b_ms / a_ms)
    records.append(rec)
    row("table1/lever/fused_transform", f"{rec['speedup']:.2f}", "x",
        f"B={B};M={M};d={d};N={N};backend=jax")

    # -- lever 2: autotuned tiles (interpret mode, padding-dominated) ------
    B, M, d, N = (_LEVER_CELL_PALLAS[k] for k in "BMdN")
    incs = tops.path_increments(make_paths(B, M, d))
    tuned = autotune.sweep_cell("sig_trunc", dict(
        engine="pallas_interpret", d=d, depth=N, M=M, B=B, precision="fp32"),
        repeats=iters)
    tile, split = tuned.get("batch_tile", 128), tuned.get("split")

    def default_tiles(x):
        return ops.signature(x, N, backend="pallas_interpret", batch_tile=128)

    def tuned_tiles(x):
        return ops.signature(x, N, backend="pallas_interpret",
                             batch_tile=tile, split=split)

    b_ms, a_ms = _time_pair(default_tiles, tuned_tiles, incs, iters)
    rec = dict(name="autotune", backend="pallas_interpret", B=B, M=M, d=d,
               N=N, batch_tile=tile, split=split, before_ms=b_ms,
               after_ms=a_ms, speedup=b_ms / a_ms)
    records.append(rec)
    row("table1/lever/autotune", f"{rec['speedup']:.2f}", "x",
        f"B={B};M={M};d={d};N={N};tile={tile};split={split}")

    # -- lever 3: bf16 storage (same cell, error bound recorded) -----------
    ref = jax.jit(lambda x: ops.signature(
        x, N, backend="pallas_interpret", batch_tile=tile, split=split))(incs)
    bf = jax.jit(lambda x: ops.signature(
        x, N, backend="pallas_interpret", batch_tile=tile, split=split,
        precision="bf16_fp32"))(incs)
    relerr = _level_relerr(bf, ref, d, N)
    b_ms, a_ms = _time_pair(
        lambda x: ops.signature(x, N, backend="pallas_interpret",
                                batch_tile=tile, split=split),
        lambda x: ops.signature(x, N, backend="pallas_interpret",
                                batch_tile=tile, split=split,
                                precision="bf16_fp32"),
        incs, iters)
    rec = dict(name="bf16", backend="pallas_interpret", B=B, M=M, d=d, N=N,
               before_ms=b_ms, after_ms=a_ms, speedup=b_ms / a_ms,
               level_relerr=relerr,
               relerr_bound=[n * 2.0 ** -8 for n in range(1, N + 1)])
    records.append(rec)
    row("table1/lever/bf16_max_relerr", f"{max(relerr):.2e}", "rel",
        f"B={B};M={M};d={d};N={N}")

    # -- combined: all levers off vs all on (fused + tuned + bf16) ---------
    def before_all(x):
        e = augment_increments(x, spec)
        return ops.signature(e, N, backend="pallas_interpret", batch_tile=128)

    d_eff = transform_dim(spec, d)
    tuned_c = autotune.sweep_cell("sig_trunc", dict(
        engine="pallas_interpret", d=d_eff, depth=N, M=2 * M, B=B,
        precision="bf16_fp32"), repeats=iters)
    tile_c, split_c = tuned_c.get("batch_tile", 128), tuned_c.get("split")

    def after_all(x):
        return ops.signature(x, N, backend="pallas_interpret",
                             transform=spec, batch_tile=tile_c,
                             split=split_c, precision="bf16_fp32")

    b_ms, a_ms = _time_pair(before_all, after_all, incs, iters)
    rec = dict(name="combined", backend="pallas_interpret", transform=tname,
               B=B, M=M, d=d, N=N, batch_tile=tile_c, split=split_c,
               precision="bf16_fp32", before_ms=b_ms, after_ms=a_ms,
               speedup=b_ms / a_ms)
    records.append(rec)
    row("table1/lever/combined", f"{rec['speedup']:.2f}", "x",
        f"B={B};M={M};d={d};N={N};backend=pallas_interpret")

    with open(JSON_PATH, "w") as f:
        json.dump({"levers": records}, f, indent=2)
    row("table1/json", JSON_PATH, "path", "")
    return records


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--levers-only", action="store_true",
                    help="only the before/after lever blocks + JSON")
    ap.add_argument("--skip-levers", action="store_true")
    args = ap.parse_args()
    if not args.levers_only:
        run(quick=args.quick)
    if not args.skip_levers:
        run_levers(quick=args.quick)
