"""Paper Table 1 / Figure 1: truncated-signature runtime scaling.

Compares three in-repo engines on identical workloads:

- ``pathsig``    — the engine dispatch (repro.kernels.ops): the resolved
                   backend's forward (Pallas kernel on TPU, levelwise Horner
                   scan elsewhere) + inverse-reconstruction VJP, i.e. the
                   paper's algorithm exactly as the training path runs it.
- ``exp_chen``   — materialise exp(ΔX_j), Chen-multiply (the textbook
                   recursion the paper replaces; iisignature/esig shape).
- ``cumulative`` — keras_sig-style: keep ALL prefix signatures S_{0,t_j}
                   and autodiff through them (O(B·M·D) memory/time shape).

``PATHSIG_BACKEND`` (env; default ``auto``) pins the dispatch backend, so
``PATHSIG_BACKEND=pallas_interpret`` exercises the kernel forward with the
§4.2 backward even on CPU (slow: interpret mode).

The paper's claims validated here (as CPU ratios, not H200 wall-clock):
speedup grows with depth N; pathsig advantage shrinks with M (it does not
parallelise the time axis) but holds; training (fwd+bwd) gap persists.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import tensor_ops as tops
from repro.kernels import ops
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")

ENGINES = {
    "pathsig": lambda incs, depth: ops.signature(
        incs, depth, backend=BACKEND, backward="inverse"),
    "exp_chen": lambda incs, depth: tops.signature_exp_chen(incs, depth),
    "cumulative": lambda incs, depth: tops.signature_cumulative(
        incs, depth)[-1],
}


def _train_fn(engine, depth):
    fn = ENGINES[engine]

    def loss(incs):
        return jnp.sum(fn(incs, depth) ** 2)

    return jax.jit(jax.value_and_grad(loss))


def _fwd_fn(engine, depth):
    fn = ENGINES[engine]
    return jax.jit(lambda incs: fn(incs, depth))


# (B, M, d, N) sweeps mirroring the paper's Table 1 sections
SWEEP_DEPTH = [(32, 100, 6, n) for n in (2, 3, 4, 5)]
SWEEP_SEQLEN = [(64, m, 4, 5) for m in (50, 100, 200, 500)]
SWEEP_BATCH = [(b, 200, 10, 3) for b in (1, 16, 64, 128)]


def run(quick: bool = True) -> None:
    header(f"table1: truncated signature runtime (paper Table 1 / Fig 1); "
           f"pathsig backend={BACKEND}")
    cells = SWEEP_DEPTH + SWEEP_SEQLEN + SWEEP_BATCH
    iters = 3 if quick else 10
    for B, M, d, N in cells:
        incs = tops.path_increments(make_paths(B, M, d))
        times = {}
        for mode in ("fwd", "train"):
            for eng in ENGINES:
                fn = _fwd_fn(eng, N) if mode == "fwd" else _train_fn(eng, N)
                t = time_fn(fn, incs, warmup=1, iters=iters)
                times[(mode, eng)] = t
                row(f"table1/{mode}/{eng}", f"{t*1e3:.3f}", "ms",
                    f"B={B};M={M};d={d};N={N}")
        for mode in ("fwd", "train"):
            base = times[(mode, "pathsig")]
            for eng in ("exp_chen", "cumulative"):
                row(f"table1/{mode}/speedup_vs_{eng}",
                    f"{times[(mode, eng)] / base:.2f}", "x",
                    f"B={B};M={M};d={d};N={N}")


if __name__ == "__main__":
    run()
