"""Benchmark suite entry point: one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick (CI) sizes
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sweeps
    PYTHONPATH=src python -m benchmarks.run --only table1,table2
    PYTHONPATH=src python -m benchmarks.run --emit-metrics

``--emit-metrics`` enables the :mod:`repro.obs` registry for the run and
writes one metrics snapshot per suite (``BENCH_<suite>_obs.json``, next to
that suite's ``BENCH_*.json``) — so perf numbers always land with their
compile/retrace, plan-cache, and autotune counters attached.

The roofline harness (EXPERIMENTS.md §Roofline, needs 512 placeholder
devices) is separate: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import time

SUITES = ("table1", "table2", "table3", "fig3", "proj", "gram", "ragged",
          "sessions", "shard")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow on CPU)")
    ap.add_argument("--only", default="",
                    help=f"comma list from {SUITES}")
    ap.add_argument("--emit-metrics", action="store_true",
                    help="enable repro.obs and write BENCH_<suite>_obs.json "
                         "snapshots (per-suite deltas: the registry resets "
                         "between suites)")
    args = ap.parse_args(argv)
    only = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES

    from . import fig3_windows, gram_scaling, proj_sparse, \
        ragged_throughput, session_throughput, shard_scaling, \
        table1_runtime, table2_memory, table3_logsig
    mods = {"table1": table1_runtime, "table2": table2_memory,
            "table3": table3_logsig, "fig3": fig3_windows,
            "proj": proj_sparse, "gram": gram_scaling,
            "ragged": ragged_throughput, "sessions": session_throughput,
            "shard": shard_scaling}
    if args.emit_metrics:
        from repro import obs
        obs.enable()
    t0 = time.time()
    for name in only:
        if args.emit_metrics:
            obs.reset()   # per-suite deltas, not run-cumulative soup
        mods[name].run(quick=not args.full)
        if args.emit_metrics:
            path = obs.write_snapshot(f"BENCH_{name}_obs.json")
            print(f"# {name}: metrics snapshot -> {path}", flush=True)
    print(f"\n# benchmarks done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
