"""Benchmark suite entry point: one module per paper table/figure, plus
the statistical regression gate.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick (CI) sizes
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale sweeps
    PYTHONPATH=src python -m benchmarks.run --only table1,table2
    PYTHONPATH=src python -m benchmarks.run --emit-metrics
    PYTHONPATH=src python -m benchmarks.run --reruns 3 --compare
    PYTHONPATH=src python -m benchmarks.run --reruns 3 --update-baselines

``--emit-metrics`` enables the :mod:`repro.obs` registry for the run and
writes one metrics snapshot per suite (``BENCH_<suite>_obs.json``, next to
that suite's ``BENCH_*.json``) — so perf numbers always land with their
compile/retrace, plan-cache, and autotune counters attached.

The regression gate (:mod:`repro.obs.baseline`) flattens each suite's
``BENCH_*.json`` into the canonical record schema after every rerun,
aggregates reruns (median value, MAD-widened noise floor), and either
refreshes the committed baselines (``--update-baselines``) or compares
against them (``--compare``), printing a verdict table.  Every suite runs
inside a crash guard, so one broken suite neither hides the others nor
masks a regression verdict.

Exit status is a bitmask CI can split: bit 1 (=1) at least one suite
crashed, bit 2 (=2) at least one metric regressed.

The roofline harness (EXPERIMENTS.md §Roofline, needs 512 placeholder
devices) is separate: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

SUITES = ("table1", "table2", "table3", "fig3", "proj", "gram", "ragged",
          "sessions", "shard")

EXIT_CRASH = 1
EXIT_REGRESSED = 2

_BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines")


def _load_suite_json(mod, t_before: float):
    """The suite's freshly (re)written BENCH json, or None when the suite
    emits no JSON (table2/proj) or didn't write this rerun."""
    path = getattr(mod, "JSON_PATH", None)
    if not path or not os.path.exists(path):
        return None
    if os.path.getmtime(path) < t_before:
        return None             # stale file from an earlier run
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"# warning: cannot read {path}: {e}", flush=True)
        return None


def _run_once(name, mod, quick: bool, gating: bool) -> None:
    mod.run(quick=quick)
    if gating and name == "table1":
        # the perf-trajectory metrics for table1 are the lever
        # before/afters, written by run_levers, not the engine sweep
        mod.run_levers(quick)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow on CPU)")
    ap.add_argument("--only", default="",
                    help=f"comma list from {SUITES} (+ 'fixture')")
    ap.add_argument("--emit-metrics", action="store_true",
                    help="enable repro.obs and write BENCH_<suite>_obs.json "
                         "snapshots (per-suite deltas: the registry resets "
                         "between suites)")
    ap.add_argument("--reruns", type=int, default=1, metavar="K",
                    help="run each suite K times; the gate takes the "
                         "median and derives per-metric noise floors from "
                         "the MAD across reruns")
    ap.add_argument("--compare", action="store_true",
                    help="compare against committed baselines; print a "
                         "verdict table; exit nonzero on any regression")
    ap.add_argument("--update-baselines", action="store_true",
                    help="rewrite benchmarks/baselines/<suite>.json from "
                         "this run")
    ap.add_argument("--baseline-dir", default=_BASELINE_DIR,
                    help="baseline directory (default: "
                         "benchmarks/baselines)")
    ap.add_argument("--verdicts-out", default="",
                    help="also write the verdict rows as JSON (CI "
                         "artifact)")
    args = ap.parse_args(argv)
    only = [s.strip() for s in args.only.split(",") if s.strip()] or SUITES
    gating = args.compare or args.update_baselines
    reruns = max(1, args.reruns)

    from repro.obs import baseline

    from . import fig3_windows, fixture_suite, gram_scaling, proj_sparse, \
        ragged_throughput, session_throughput, shard_scaling, \
        table1_runtime, table2_memory, table3_logsig
    mods = {"table1": table1_runtime, "table2": table2_memory,
            "table3": table3_logsig, "fig3": fig3_windows,
            "proj": proj_sparse, "gram": gram_scaling,
            "ragged": ragged_throughput, "sessions": session_throughput,
            "shard": shard_scaling, "fixture": fixture_suite}
    unknown = [s for s in only if s not in mods]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {sorted(mods)}")
    if args.emit_metrics:
        from repro import obs
        obs.enable()

    t0 = time.time()
    status: dict[str, str | None] = {}          # suite -> error or None
    collected: dict[str, list] = {}             # suite -> [records per rerun]
    for name in only:
        err = None
        runs = []
        for k in range(reruns):
            if args.emit_metrics:
                obs.reset()   # per-suite deltas, not run-cumulative soup
            t_before = time.time()
            try:
                _run_once(name, mods[name], quick=not args.full,
                          gating=gating)
            except KeyboardInterrupt:
                raise
            except Exception as e:
                err = f"{type(e).__name__}: {e}"
                print(f"# suite {name} rerun {k + 1}/{reruns} CRASHED:",
                      flush=True)
                traceback.print_exc()
                break
            if gating:
                doc = _load_suite_json(mods[name], t_before)
                if doc is not None:
                    recs = baseline.extract_records(name, doc)
                    if recs:
                        runs.append(recs)
        if args.emit_metrics and err is None:
            path = obs.write_snapshot(f"BENCH_{name}_obs.json")
            print(f"# {name}: metrics snapshot -> {path}", flush=True)
        status[name] = err
        if runs:
            collected[name] = runs

    exit_code = 0
    crashed = [n for n, e in status.items() if e]
    if crashed:
        exit_code |= EXIT_CRASH

    current = {name: baseline.aggregate(runs)
               for name, runs in collected.items()}
    if args.update_baselines:
        for name, recs in current.items():
            path = baseline.write_baseline(args.baseline_dir, name, recs,
                                           reruns=reruns)
            print(f"# baseline updated: {path} ({len(recs)} metrics)",
                  flush=True)
    if args.compare:
        baselines = baseline.load_baseline_dir(args.baseline_dir)
        verdicts = baseline.compare(current, baselines)
        print("\n# regression gate "
              f"(reruns={reruns}, baselines: {args.baseline_dir})")
        print(baseline.verdict_table(verdicts))
        if args.verdicts_out:
            with open(args.verdicts_out, "w") as f:
                json.dump({"reruns": reruns, "crashed": crashed,
                           "verdicts": [vars(v) for v in verdicts]},
                          f, indent=1, sort_keys=True)
            print(f"# verdicts -> {args.verdicts_out}", flush=True)
        if baseline.regressions(verdicts):
            exit_code |= EXIT_REGRESSED

    print("\n# suites: " + ", ".join(
        f"{n} {'FAIL' if status[n] else 'ok'}" for n in only), flush=True)
    for n in crashed:
        print(f"#   {n}: {status[n]}", flush=True)
    print(f"# benchmarks done in {time.time() - t0:.0f}s "
          f"(exit {exit_code})", flush=True)
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
