"""Per-op FLOP attribution from lowered HLO text (hillclimb profiler).

XLA's cost_analysis gives one total; to find WHERE the FLOPs are we parse
every `dot` op, compute 2*M*N*K from its shapes, and bucket by the JAX
op_name metadata (which names the source einsum/layer).

Usage: PYTHONPATH=src python -m benchmarks.hlo_flops --arch deepseek-v2-lite-16b \
           --shape train_4k --layers 2 [--top 25]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_SCAN_UNROLL", "1")

import argparse  # noqa: E402
import re  # noqa: E402
from collections import defaultdict  # noqa: E402


_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _dims(s):
    m = _SHAPE.search(s)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def dot_flops_by_op(hlo: str, top: int = 25):
    """Returns [(op_name, flops, count)] sorted by flops desc."""
    buckets = defaultdict(lambda: [0.0, 0])
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\S+) dot\((.+?)\)", ls)
        if not m:
            continue
        out_dims = _dims(m.group(1))
        # contraction size: product of lhs_contracting dims of first operand
        ops = re.findall(r"%[\w.\-]+", m.group(2))
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ls)
        lhs_shape_m = re.search(r"dot\((\S+?) %", ls)
        # robust route: find operand shapes inline e.g. dot(bf16[..] %a, ..)
        operand_shapes = re.findall(r"(\w+\[[\d,]*\])\s*%", ls)
        K = 1
        if cm and operand_shapes:
            lhs = _dims(operand_shapes[0])
            for i in [int(x) for x in cm.group(1).split(",") if x]:
                if i < len(lhs):
                    K *= lhs[i]
        numel = 1
        for d in out_dims:
            numel *= d
        fl = 2.0 * numel * K
        name = "?"
        nm = re.search(r'op_name="([^"]+)"', ls)
        if nm:
            name = nm.group(1)
            name = re.sub(r"\[.*?\]", "", name)
        b = buckets[name]
        b[0] += fl
        b[1] += 1
    rows = sorted(((k, v[0], v[1]) for k, v in buckets.items()),
                  key=lambda r: -r[1])
    return rows[:top]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    from benchmarks.roofline import probe_cfg, _patched_config
    from repro.configs import get_config
    from repro.launch.dryrun import lower_cell

    cfg = probe_cfg(get_config(args.arch), args.layers)
    import repro.launch.dryrun as DR
    with _patched_config(args.arch, cfg):
        # re-lower and keep the HLO: call the internals directly
        import repro.launch.specs as SP
        from repro.launch.mesh import make_production_mesh
        res = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                         keep_hlo=True)
    hlo = res["hlo_text"]
    total = sum(f for _, f, _ in dot_flops_by_op(hlo, top=10 ** 6))
    print(f"total dot FLOPs/device (L={args.layers} probe): {total:.3e}")
    print(f"{'FLOPs':>12}  {'%':>5}  {'n':>4}  op")
    for name, fl, n in dot_flops_by_op(hlo, args.top):
        print(f"{fl:12.3e}  {100*fl/total:5.1f}  {n:>4}  {name[:110]}")


if __name__ == "__main__":
    main()
