"""Assemble the EXPERIMENTS.md roofline markdown table from per-cell JSONs.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--dir runs/roofline_opt]
"""
import argparse
import glob
import json
import os

ARCHS = ["command-r-35b", "llama3-405b", "qwen1.5-32b", "qwen3-4b",
         "qwen2-vl-2b", "deepseek-v2-lite-16b", "phi3.5-moe-42b-a6.6b",
         "zamba2-7b", "rwkv6-1.6b", "whisper-large-v3"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/roofline_opt")
    args = ap.parse_args(argv)
    print("| arch | shape | t_comp | t_mem | t_coll | dominant | useful | roofline |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            path = os.path.join(args.dir, f"{arch}__{shape}.json")
            if not os.path.exists(path):
                continue
            r = json.load(open(path))
            if r.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | skipped | — | — |")
                continue
            if "error" in r:
                print(f"| {arch} | {shape} | ERROR {r['error'][:60]} |")
                continue
            print(f"| {arch} | {shape} | {r['t_compute_s']:.3g} | "
                  f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
                  f"{r['dominant'].replace('_s','')} | "
                  f"{r['useful_flops_ratio']:.2f} | "
                  f"{r['roofline_fraction']*100:.0f}% |")


if __name__ == "__main__":
    main()
