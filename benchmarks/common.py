"""Shared benchmark utilities.

Wall-clock numbers in this container are CPU numbers — the paper's H200
wall-clock cannot be reproduced here.  What IS reproducible (and what the
paper's tables actually claim) are the *ratios and scaling laws*:

- Table 1: the word-basis Horner engine beats exp-materialising and
  cumulative (keras_sig-style) engines, with the gap growing in depth.
- Table 2: peak training memory is O(B·D_sig) for pathsig vs O(B·M·D_sig)
  for the cumulative engine — measured here from XLA's compiled
  memory_analysis() (temp bytes), which is exact, not sampled.
- Table 3: projected log-signatures avoid materialising the full top level.
- Fig 3: one batched windowed call vs a per-window loop.

Each benchmark prints CSV rows ``name,value,unit,detail`` so the whole suite
is machine-parseable from bench_output.txt.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds of fn(*args) (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def temp_bytes(fn: Callable, *args) -> int:
    """XLA temp-buffer bytes of the compiled fn — the peak-memory proxy.

    Exact (from the compiled buffer assignment), not a sampled RSS: this is
    the number the paper's Table 2 memory law governs.
    """
    compiled = jax.jit(fn).lower(*args).compile()
    mem = compiled.memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0))


def row(name: str, value, unit: str, detail: str = "") -> None:
    print(f"{name},{value},{unit},{detail}", flush=True)


def header(title: str) -> None:
    print(f"\n# === {title} ===", flush=True)
    print("name,value,unit,detail", flush=True)


def make_paths(B: int, M: int, d: int, seed: int = 0) -> jax.Array:
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    steps = rng.standard_normal((B, M, d)).astype(np.float32) / np.sqrt(M)
    path = np.concatenate([np.zeros((B, 1, d), np.float32),
                           np.cumsum(steps, axis=1)], axis=1)
    return jnp.asarray(path)
