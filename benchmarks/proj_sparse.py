"""Paper §7-§8: projection economics — sparse word sets vs full truncation.

Reports, for the paper's sparse lead-lag construction (§8) and a DAG
(banded-interaction) projection (§7.1), the feature-dimension reduction and
the end-to-end runtime ratio vs the full truncated signature on the same
path.  The paper's §8 example achieves 6.25x feature reduction and 2.24x
training-time reduction for the lead-lag set; exact dims are reproduced
here (they are combinatorial facts, device-independent).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import (dag_words, generated_words, lead_lag, make_plan,
                        sig_dim, sparse_leadlag_generators)
from repro.core import tensor_ops as tops
from repro.kernels import ops
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")


def run(quick: bool = True) -> None:
    header("proj: sparse projections vs truncation (paper §7-§8)")
    iters = 3 if quick else 10

    # --- paper §8: sparse lead-lag set, d=5 components, depth 4 -------------
    d, N, B, M = 5, 4, 32, 64
    path = lead_lag(make_paths(B, M, d))          # (B, 2M+1, 2d)
    incs = tops.path_increments(path)
    words = generated_words(sparse_leadlag_generators(d), N)
    plan = make_plan(words, 2 * d)
    full_dim = sig_dim(2 * d, N)
    tag = f"d=5(ll=10);N={N};B={B};M={M}"
    row("proj/leadlag/full_dim", full_dim, "coeffs", tag)
    row("proj/leadlag/sparse_dim", len(words), "coeffs", tag)
    row("proj/leadlag/dim_reduction", f"{full_dim/len(words):.2f}", "x", tag)
    row("proj/leadlag/closure_size", plan.closure_size, "coeffs",
        f"{tag};computed coefficients incl. prefix closure")

    # both routes go through the engine dispatch (repro.kernels.ops): the
    # forward is the resolved backend's kernel, the backward the §4.2
    # inverse reconstruction — forward benchmark == trained path.
    full = jax.jit(lambda x: ops.signature(x, N, backend=BACKEND))
    sparse = jax.jit(lambda x: ops.projected(x, plan, backend=BACKEND))
    t_full = time_fn(full, incs, warmup=1, iters=iters)
    t_sparse = time_fn(sparse, incs, warmup=1, iters=iters)
    row("proj/leadlag/full", f"{t_full*1e3:.3f}", "ms", tag)
    row("proj/leadlag/sparse", f"{t_sparse*1e3:.3f}", "ms", tag)
    row("proj/leadlag/speedup", f"{t_full/t_sparse:.2f}", "x", tag)

    g_full = jax.jit(jax.grad(
        lambda x: jnp.sum(ops.signature(x, N, backend=BACKEND) ** 2)))
    g_sparse = jax.jit(jax.grad(
        lambda x: jnp.sum(ops.projected(x, plan, backend=BACKEND) ** 2)))
    tg_full = time_fn(g_full, incs, warmup=1, iters=iters)
    tg_sparse = time_fn(g_sparse, incs, warmup=1, iters=iters)
    row("proj/leadlag/train_speedup", f"{tg_full/tg_sparse:.2f}", "x", tag)

    # --- §7.1 DAG projection: banded channel interactions -------------------
    d2, N2 = 8, 4
    edges = [(i, j) for i in range(d2) for j in range(d2) if abs(i - j) <= 1]
    words2 = dag_words(edges, d2, N2)
    plan2 = make_plan(words2, d2)
    incs2 = tops.path_increments(make_paths(16, 64, d2))
    tag2 = f"d={d2};N={N2};band=1"
    row("proj/dag/full_dim", sig_dim(d2, N2), "coeffs", tag2)
    row("proj/dag/dag_dim", len(words2), "coeffs", tag2)
    full2 = jax.jit(lambda x: ops.signature(x, N2, backend=BACKEND))
    dag = jax.jit(lambda x: ops.projected(x, plan2, backend=BACKEND))
    t_f2 = time_fn(full2, incs2, warmup=1, iters=iters)
    t_d2 = time_fn(dag, incs2, warmup=1, iters=iters)
    row("proj/dag/speedup", f"{t_f2/t_d2:.2f}", "x", tag2)


if __name__ == "__main__":
    run()
