"""Paper Table 2: peak training memory — the O(B·D_sig) law.

Measured from XLA's compiled buffer assignment (``memory_analysis().temp
bytes``) of the full train step (value_and_grad through the signature):

- ``pathsig``    (inverse-reconstruction VJP): temp bytes flat in M.
- ``checkpoint`` (sqrt-M VJP, beyond paper):   temp bytes ~ sqrt(M).
- ``autodiff``   (scan autodiff = keras_sig law): temp bytes linear in M.

Also reports Mem_out = 4·B·D_sig (the paper's theoretical floor) and each
engine's peak/Mem_out multiple — the paper's pathsig stays near ~2×.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sig_dim
from repro.core import tensor_ops as tops
from repro.core.signature import signature_from_increments
from .common import header, make_paths, row, temp_bytes

MODES = ("inverse", "checkpoint", "autodiff")


def _grad_fn(mode: str, depth: int):
    def loss(incs):
        out = signature_from_increments(incs, depth, backward=mode)
        return jnp.sum(out ** 2)

    return jax.grad(loss)


def run(quick: bool = True) -> None:
    header("table2: peak train memory vs sequence length (paper Table 2)")
    B, d, N = 32, 5, 4
    mem_out = 4 * B * sig_dim(d, N)
    row("table2/mem_out", mem_out, "bytes", f"B={B};d={d};N={N}")
    seqs = (50, 100, 200, 400) if quick else (50, 100, 200, 400, 800, 1600)
    series: dict[str, list[tuple[int, int]]] = {m: [] for m in MODES}
    for M in seqs:
        incs = tops.path_increments(make_paths(B, M, d))
        for mode in MODES:
            tb = temp_bytes(_grad_fn(mode, N), incs)
            series[mode].append((M, tb))
            row(f"table2/temp_bytes/{mode}", tb, "bytes",
                f"B={B};M={M};d={d};N={N};x_mem_out={tb/mem_out:.1f}")
    # scaling law: fit temp ~ M^alpha between first and last points
    import math
    for mode in MODES:
        (m0, b0), (m1, b1) = series[mode][0], series[mode][-1]
        alpha = math.log(max(b1, 1) / max(b0, 1)) / math.log(m1 / m0)
        row(f"table2/scaling_exponent/{mode}", f"{alpha:.2f}",
            "alpha(temp~M^a)", f"expect inverse~0, checkpoint~0.5, autodiff~1")
    # reduction factor at the largest M (paper's "Reduction (x)" column)
    b_inv = series["inverse"][-1][1]
    b_auto = series["autodiff"][-1][1]
    row("table2/reduction_at_maxM", f"{b_auto / max(b_inv, 1):.1f}", "x",
        f"autodiff/inverse at M={seqs[-1]}")


if __name__ == "__main__":
    run()
