"""Mesh-sharding benchmarks: weak scaling + Gram ring vs replicated.

Three claims from the mesh-aware dispatch (see the mesh note in
``repro.kernels.ops``) are tracked per PR:

1. *Weak scaling*: with a fixed per-device batch, wall-clock of the
   signature forward+grad under ``sharding_ctx(make_sig_mesh(P))`` should be
   ~flat in P.  Inputs are committed to the mesh with ``jax.device_put``
   BEFORE timing — an uncommitted host array is re-scattered on every call,
   which measures the transfer, not the compute (that resharding was the
   bulk of the historical P=8 cliff).  On CPU the 8 "devices" share the
   same cores, so the CPU numbers measure dispatch overhead, not speedup —
   the *trajectory* (and the TPU run of the same file) is the claim.
2. *Retrace-free dispatch*: the sweep calls each sharded entry point
   repeatedly per P; the ``pathsig_jit_traces_total`` counters snapshotted
   into the JSON must show one compile per (site, shape) — the jit-cache
   test in ``tests/test_shard.py`` enforces it, the bench records it.
3. *Ring communication law*: the cross-device Gram moves O(B·D_sig) bytes
   over collective-permutes — measured from lowered HLO via
   ``repro.distributed.hlo.collective_stats`` and compared against the
   would-be replicated spellings (all-gather of Y: B·D_sig result bytes;
   elementwise blow-up: B_x·B_y·D_sig).  A ring-vs-oracle *crossover
   curve* over B shows where the double-buffered ring overtakes the
   replicated oracle.

Every record lands in ``BENCH_shard.json`` (cwd), matching the other
suites, so CI uploads it with the rest.  The module re-executes itself in a
subprocess with 8 forced host devices (XLA locks the device count at first
init, so the in-process ``run()`` entry point cannot force it).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEV = 8
_FLAGS = f"--xla_force_host_platform_device_count={N_DEV}"
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON_SHARD", "BENCH_shard.json")


def run(quick: bool = True) -> None:
    """benchmarks.run entry point: re-exec with forced host devices."""
    env = dict(os.environ, XLA_FLAGS=_FLAGS)
    cmd = [sys.executable, "-m", "benchmarks.shard_scaling", "--inner"]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env)
    if r.returncode:
        raise RuntimeError(f"shard_scaling subprocess failed ({r.returncode})")


def _bench(quick: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    from repro import obs
    from repro.core.words import sig_dim
    from repro.distributed import collective_stats, sharding_ctx
    from repro.kernels import ops
    from repro.launch.mesh import make_sig_mesh
    from repro.sigkernel import sig_gram, word_weights

    from .common import header, make_paths, row, time_fn

    assert len(jax.devices()) == N_DEV, jax.devices()
    # forced host "devices" timeslice the machine's physical cores: the
    # ideal weak-scaling time at P shards is P·t1·min(1, cores/P) — on a
    # box with >= 8 cores that reduces to the classic flat-t1 ideal, on a
    # 1-core box to the serial bound P·t1.  Efficiency is measured against
    # that ideal so the number isolates the dispatch/resharding overhead
    # the PR controls (a real TPU run of this file has cores >= P and
    # reduces to the textbook definition).
    n_cores = os.cpu_count() or 1
    out = {"devices": N_DEV, "host_cores": n_cores,
           "weak_scaling": [], "gram_ring": {}}

    # --- 1. weak scaling: fixed per-device batch -------------------------
    header("shard weak scaling (per-device batch fixed, committed inputs)")
    b_dev, M, d, depth = (32, 128, 3, 4) if quick else (32, 256, 4, 4)
    iters = 3 if quick else 5
    obs.enable()
    obs.reset()
    t1 = None
    for P in (1, 2, 4, 8):
        mesh = make_sig_mesh(P)
        x = make_paths(b_dev * P, M, d, seed=0)
        # commit the batch-sharded increments to the mesh BEFORE timing:
        # an uncommitted array is host-scattered again on every call
        incs = jax.device_put(
            jnp.diff(x, axis=1),
            NamedSharding(mesh, PartitionSpec("data", None, None)))

        def fwd_bwd(a):
            return jax.grad(lambda z: ops.signature(
                z, depth, backend="auto").sum())(a)

        with sharding_ctx(mesh):
            t = time_fn(jax.jit(fwd_bwd), incs, warmup=2, iters=iters)
        t1 = t if t1 is None else t1
        ideal = t1 * max(1.0, P / n_cores)   # timesliced-host ideal
        eff = ideal / t if t > 0 else 0.0
        eff_raw = t1 / t if t > 0 else 0.0
        tag = f"P={P};B={b_dev * P};M={M};d={d};N={depth}"
        row("shard/weak_fwdbwd", f"{t * 1e3:.3f}", "ms",
            f"{tag};eff={eff:.3f}")
        out["weak_scaling"].append({"P": P, "B": b_dev * P, "M": M, "d": d,
                                    "depth": depth, "ms": t * 1e3,
                                    "ideal_ms": ideal * 1e3,
                                    "efficiency_vs_P1": eff,
                                    "efficiency_raw_t1_over_t": eff_raw})
    # compile-per-shape accounting over the whole sweep (claim 2): every
    # (site, shapes) label pair should sit at 1 — recorded, and enforced by
    # tests/test_shard.py
    snap = obs.snapshot()["metrics"].get("pathsig_jit_traces_total", {})
    out["jit_traces"] = snap.get("values", [])

    # --- 2. Gram ring vs replicated --------------------------------------
    header("gram ring vs replicated (8-device mesh)")
    B, gd, gN = (64, 3, 4) if quick else (256, 4, 4)
    D = sig_dim(gd, gN)
    w = jnp.asarray(word_weights(gd, gN))
    mesh = make_sig_mesh(N_DEV)

    def ring(a):
        return sig_gram(a, None, gN, route="tiled", backend="jax")

    def oracle(a):
        return sig_gram(a, None, gN, route="oracle", backend="jax")

    # crossover curve: ring (O(B·D) wire, P partial tiles) vs replicated
    # oracle over growing B — the ring's per-step latency is amortised once
    # the per-shard tiles are large enough to hide the permutes
    curve = []
    bs = (16, 32, 64, 128) if quick else (64, 128, 256, 512)
    with sharding_ctx(mesh):
        for Bc in bs:
            Xc = make_paths(Bc, M, gd, seed=1)
            tr = time_fn(jax.jit(ring), Xc, warmup=1, iters=iters)
            to = time_fn(jax.jit(oracle), Xc, warmup=1, iters=iters)
            row("shard/gram_crossover", f"{tr * 1e3:.3f}", "ms",
                f"B={Bc};D={D};oracle={to * 1e3:.3f}ms")
            curve.append({"B": Bc, "D_sig": D, "ring_ms": tr * 1e3,
                          "oracle_ms": to * 1e3,
                          "ring_over_oracle": tr / to if to > 0 else 0.0})

        X = make_paths(B, M, gd, seed=1)
        t_ring = time_fn(jax.jit(ring), X, warmup=1, iters=iters)
        t_oracle = time_fn(jax.jit(oracle), X, warmup=1, iters=iters)
        a = np.asarray(jax.jit(ring)(X))
        b = np.asarray(jax.jit(oracle)(X))
        err = float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-12))

        Sx = jnp.asarray(np.random.default_rng(0).standard_normal(
            (B, D)).astype(np.float32))
        txt = jax.jit(lambda s: ops.gram(s, s, w, backend="jax")
                      ).lower(Sx).compile().as_text()
    st = collective_stats(txt, default_group=N_DEV)
    permute_wire = st.by_kind.get("collective-permute", (0, 0, 0.0))[2]
    ag_result = st.by_kind.get("all-gather", (0, 0.0, 0.0))[1]
    replicated_y = B * D * 4                    # all-gather-of-Y spelling
    blowup = B * B * D * 4                      # elementwise spelling
    row("shard/ring_ms", f"{t_ring * 1e3:.3f}", "ms", f"B={B};D={D}")
    row("shard/oracle_ms", f"{t_oracle * 1e3:.3f}", "ms", f"B={B};D={D}")
    row("shard/ring_vs_oracle_relerr", f"{err:.2e}", "rel", "")
    row("shard/permute_wire", f"{permute_wire / 2**20:.3f}", "MiB/dev",
        f"replicated_y={replicated_y / 2**20:.3f}MiB;"
        f"blowup={blowup / 2**20:.1f}MiB")
    assert err < 1e-5, err
    assert ag_result < blowup, (ag_result, blowup)
    out["gram_ring"] = {"B": B, "D_sig": D, "ring_ms": t_ring * 1e3,
                        "oracle_ms": t_oracle * 1e3, "relerr": err,
                        "permute_wire_bytes_per_dev": permute_wire,
                        "allgather_result_bytes": ag_result,
                        "replicated_y_bytes": replicated_y,
                        "elementwise_blowup_bytes": blowup,
                        "crossover": curve,
                        "collectives": {k: list(v)
                                        for k, v in st.by_kind.items()}}

    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\n# wrote {JSON_PATH}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--inner", action="store_true",
                    help="already re-executed with forced host devices")
    args = ap.parse_args(argv)
    if args.inner:
        _bench(args.quick)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
