"""Paper Figure 3: windowed signature computation.

The paper's claim: evaluating an entire collection of K windows in ONE call
costs roughly one kernel launch + saturates the device, vs per-window calls
that pay fixed overhead K times.  Compared engines:

- ``batched``   — windowed_signature: one call, windows folded into batch.
- ``per_window``— one signature call per window (a Python loop of jit'd
                  calls; the "limited native support" behaviour of other
                  libraries the paper contrasts with).
- ``chen``      — Signatory-style S_{0,l}^{-1} ⊗ S_{0,r} from the expanding
                  stream (the paper notes: cheaper only for heavy overlap,
                  numerically delicate; shown for completeness).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (sliding_windows, windowed_signature,
                        windowed_signature_chen)
from repro.core.signature import signature_from_increments
from repro.core import tensor_ops as tops
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")


@jax.jit
def _increments(path):
    return tops.path_increments(path)


def _make_per_window(depth):
    # jitted ONCE; the loop then pays only per-call dispatch — the honest
    # analogue of issuing K separate kernel launches.
    sig = jax.jit(lambda x: signature_from_increments(x, depth))

    def per_window(path, windows):
        incs = _increments(path)
        return [sig(incs[:, l:r]) for l, r in windows]  # noqa: E741

    return per_window


def run(quick: bool = True) -> None:
    header("fig3: windowed signatures, one call vs per-window (paper Fig 3)")
    B, d, N, wlen = 16, 4, 3, 16
    iters = 3 if quick else 10
    counts = (4, 16, 64) if quick else (4, 16, 64, 256, 1024)
    for K in counts:
        M = wlen * K // 2 + wlen  # stride wlen/2: 50% overlap
        path = make_paths(B, M, d)
        windows = sliding_windows(M, wlen, stride=wlen // 2)[:K]
        assert windows.shape[0] == K, (windows.shape, K)

        # one call through the engine dispatch: windows folded into batch
        batched = jax.jit(lambda p: windowed_signature(p, windows, N,
                                                       backend=BACKEND))
        t_b = time_fn(batched, path, warmup=1, iters=iters)
        # training path: kernel forward + inverse-reconstruction backward
        # through the same dispatch, per window
        train = jax.jit(jax.grad(lambda p: jnp.sum(
            windowed_signature(p, windows, N, backend=BACKEND,
                               backward="inverse") ** 2)))
        t_t = time_fn(train, path, warmup=1, iters=iters)
        chen = jax.jit(lambda p: windowed_signature_chen(p, windows, N))
        t_c = time_fn(chen, path, warmup=1, iters=iters)
        per_window = _make_per_window(N)
        t_p = time_fn(lambda p: per_window(p, windows), path,
                      warmup=1, iters=max(1, iters - 1))

        tag = f"B={B};K={K};wlen={wlen};d={d};N={N}"
        row("fig3/batched", f"{t_b*1e3:.3f}", "ms", tag)
        row("fig3/batched_train", f"{t_t*1e3:.3f}", "ms", tag)
        row("fig3/per_window", f"{t_p*1e3:.3f}", "ms", tag)
        row("fig3/chen_stream", f"{t_c*1e3:.3f}", "ms", tag)
        row("fig3/speedup_vs_per_window", f"{t_p/t_b:.1f}", "x", tag)
        row("fig3/speedup_vs_chen", f"{t_c/t_b:.2f}", "x", tag)

        # correctness cross-check while we're here (batched vs chen)
        a = np.asarray(batched(path))
        c = np.asarray(chen(path))
        err = float(np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-12))
        row("fig3/batched_vs_chen_relerr", f"{err:.2e}", "", tag)


if __name__ == "__main__":
    run()
