"""Paper Figure 3: windowed signature computation, fold vs chen-stream routes.

The paper's claim: evaluating an entire collection of K windows in ONE call
costs roughly one kernel launch + saturates the device, vs per-window calls
that pay fixed overhead K times.  This benchmark additionally pits the two
physical routes of the unified ``windowed_signature`` against each other:

- ``fold``      — per-window increment slices folded into the batch axis
                  (work ∝ K · L_max padded scan steps).
- ``chen``      — S_{l,r} = S_{0,l}^{-1} ⊗ S_{0,r} over ONE streamed forward
                  (work ∝ M + c·K) — the O(M + K) route for heavily
                  overlapping sliding windows.
- ``auto``      — the host-side cost model's pick.
- ``per_window``— one signature call per window (the "limited native
                  support" behaviour the paper contrasts with).

Besides the CSV rows, every record lands in ``BENCH_fig3.json`` (cwd) so the
perf trajectory is machine-readable: per-config wall-clocks, the
chen-vs-fold speedup, and the gradient cross-checks (route="auto" and the
streamed Pallas forward, both against the pure-JAX autodiff oracle).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sliding_windows, windowed_signature, select_route
from repro.core.signature import signature_from_increments
from repro.core import tensor_ops as tops
from repro.kernels import ops
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "auto")
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_fig3.json")


@jax.jit
def _increments(path):
    return tops.path_increments(path)


def _make_per_window(depth):
    # jitted ONCE; the loop then pays only per-call dispatch — the honest
    # analogue of issuing K separate kernel launches.
    sig = jax.jit(lambda x: signature_from_increments(x, depth))

    def per_window(path, windows):
        incs = _increments(path)
        return [sig(incs[:, l:r]) for l, r in windows]  # noqa: E741

    return per_window


def _route_fn(windows, N, route):
    return jax.jit(lambda p: windowed_signature(p, windows, N, route=route,
                                                backend=BACKEND))


def _grad_relerr(g, g_ref):
    denom = float(np.max(np.abs(np.asarray(g_ref)))) + 1e-12
    return float(np.max(np.abs(np.asarray(g) - np.asarray(g_ref)))) / denom


def _bench_config(B, M, d, N, wlen, stride, iters, *, per_window=True,
                  grads=True):
    path = make_paths(B, M, d)
    windows = sliding_windows(M, wlen, stride=stride)
    K = windows.shape[0]
    tag = f"B={B};M={M};K={K};wlen={wlen};stride={stride};d={d};N={N}"
    rec = {"B": B, "M": M, "K": int(K), "wlen": wlen, "stride": stride,
           "d": d, "depth": N, "backend": BACKEND,
           "auto_route": select_route("auto", windows, M)}

    t_fold = time_fn(_route_fn(windows, N, "fold"), path, warmup=2,
                     iters=iters)
    t_chen = time_fn(_route_fn(windows, N, "chen"), path, warmup=2,
                     iters=iters)
    t_auto = time_fn(_route_fn(windows, N, "auto"), path, warmup=2,
                     iters=iters)
    rec.update(fold_ms=t_fold * 1e3, chen_ms=t_chen * 1e3,
               auto_ms=t_auto * 1e3, chen_speedup_vs_fold=t_fold / t_chen)
    row("fig3/fold", f"{t_fold*1e3:.3f}", "ms", tag)
    row("fig3/chen_stream", f"{t_chen*1e3:.3f}", "ms", tag)
    row("fig3/auto", f"{t_auto*1e3:.3f}", "ms", tag)
    row("fig3/chen_speedup_vs_fold", f"{t_fold/t_chen:.2f}", "x", tag)
    row("fig3/auto_route", rec["auto_route"], "", tag)

    if per_window:
        pw = _make_per_window(N)
        t_p = time_fn(lambda p: pw(p, windows), path, warmup=2,
                      iters=max(1, iters - 1))
        rec["per_window_ms"] = t_p * 1e3
        row("fig3/per_window", f"{t_p*1e3:.3f}", "ms", tag)
        row("fig3/speedup_vs_per_window", f"{t_p/min(t_fold, t_chen):.1f}",
            "x", tag)

    # correctness cross-check while we're here (fold vs chen values)
    a = np.asarray(_route_fn(windows, N, "fold")(path))
    c = np.asarray(_route_fn(windows, N, "chen")(path))
    err = float(np.max(np.abs(a - c)) / (np.max(np.abs(a)) + 1e-12))
    rec["fold_vs_chen_relerr"] = err
    row("fig3/fold_vs_chen_relerr", f"{err:.2e}", "", tag)

    if grads:
        # gradient cross-check: auto route vs the pure-JAX autodiff oracle
        def loss(route, backward, backend):
            return jax.jit(jax.grad(lambda p: jnp.sum(windowed_signature(
                p, windows, N, route=route, backward=backward,
                backend=backend) ** 2)))
        g_oracle = loss("fold", "autodiff", "jax")(path)
        g_auto = loss("auto", "inverse", BACKEND)(path)
        rec["grad_auto_vs_oracle_relerr"] = _grad_relerr(g_auto, g_oracle)
        row("fig3/grad_auto_vs_oracle_relerr",
            f"{rec['grad_auto_vs_oracle_relerr']:.2e}", "", tag)
    return rec


def _streamed_pallas_grad_check():
    """grad through the streamed Pallas forward vs the pure-JAX oracle."""
    x = jnp.asarray(np.random.default_rng(7).normal(
        size=(4, 24, 3)).astype(np.float32) * 0.3)
    g_pal = jax.grad(lambda z: jnp.sum(ops.signature(
        z, 3, backend="pallas_interpret", batch_tile=8, stream=True) ** 2))(x)
    g_jax = jax.grad(lambda z: jnp.sum(signature_from_increments(
        z, 3, stream=True, backward="autodiff") ** 2))(x)
    return _grad_relerr(g_pal, g_jax)


def run(quick: bool = True) -> None:
    header("fig3: windowed signatures — routes, one call vs per-window")
    iters = 3 if quick else 10
    records = []
    # sweep: growing window counts at 50% overlap (the paper's fig3 shape)
    for K in (4, 16, 64) if quick else (4, 16, 64, 256, 1024):
        wlen = 16
        M = wlen * K // 2 + wlen
        records.append(_bench_config(16, M, 4, 3, wlen, wlen // 2, iters))
    # the heavy-overlap acceptance config: sliding windows, stride << length,
    # where the chen-stream route's O(M + K) beats the fold route's O(K·L)
    records.append(_bench_config(32, 2048, 4, 4, 256, 8,
                                 iters=max(2, iters - 1), per_window=False))
    err = _streamed_pallas_grad_check()
    row("fig3/grad_streamed_pallas_vs_oracle_relerr", f"{err:.2e}", "", "")
    out = {"benchmark": "fig3_windows", "backend": BACKEND,
           "grad_streamed_pallas_vs_oracle_relerr": err, "records": records}
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    row("fig3/json", JSON_PATH, "path", "")


if __name__ == "__main__":
    run()
