import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_SCAN_UNROLL"] = "1"   # probes must see every layer

"""Roofline harness (EXPERIMENTS.md §Roofline).

Methodology: XLA's HloCostAnalysis counts `while` (scan) bodies ONCE, so a
scanned L-layer model under-reports FLOPs/bytes/collectives by ~L×.  We
therefore lower two fully-unrolled reduced-depth probes (L1, L2) per cell,
fit the affine law  cost(L) = a + b·L  (exact: layers are homogeneous), and
extrapolate to the full depth.  Peak-memory and compile-feasibility numbers
still come from the full scanned compile in launch/dryrun.py.

Usage:  PYTHONPATH=src python -m benchmarks.roofline --arch all --shape all
Writes runs/roofline/<arch>__<shape>.json + a markdown table to stdout.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch.dryrun import (ICI_BW, HBM_BW, PEAK_FLOPS, lower_cell,  # noqa: E402
                                 rules_for)
from repro.launch.mesh import make_production_mesh  # noqa: E402

PROBE_LAYERS = (2, 4)


def probe_cfg(cfg, L):
    upd = {"n_layers": L}
    if cfg.family == "encdec":
        upd["n_encoder_layers"] = L
    if cfg.family == "hybrid":
        # keep one shared-attn application per `hybrid_attn_every` layers
        upd["hybrid_attn_every"] = max(1, cfg.hybrid_attn_every // 2)
        upd["n_layers"] = L * 2
    if cfg.moe and cfg.moe_layer_start:
        upd["moe_layer_start"] = 1
    return dataclasses.replace(cfg, **upd)


def effective_layers(cfg, L_probe):
    if cfg.family == "hybrid":
        return L_probe * 2
    return L_probe


def measure(arch: str, shape: str, *, multi_pod=False, opt_name="adafactor",
            remat="dots", rule_overrides=None, mesh=None):
    """Probe-extrapolated roofline terms for one cell."""
    cfg = get_config(arch)
    ok, why = SP.cell_is_runnable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": why}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)

    import repro.launch.dryrun as DR
    samples = []
    for L in PROBE_LAYERS:
        pc = probe_cfg(cfg, L)
        with _patched_config(arch, pc):
            r = lower_cell(arch, shape, multi_pod=multi_pod, opt_name=opt_name,
                           remat=remat, rule_overrides=rule_overrides,
                           mesh=mesh)
        if "error" in r:
            return {"arch": arch, "shape": shape, "error": r["error"]}
        samples.append((effective_layers(cfg, L), r))

    (L1, r1), (L2, r2) = samples
    Lf = cfg.n_layers

    def affine(key):
        y1, y2 = r1[key], r2[key]
        b = (y2 - y1) / (L2 - L1)
        a = y1 - b * L1
        return max(0.0, a + b * Lf)

    flops = affine("hlo_flops_per_dev")
    bytes_ = affine("hlo_bytes_per_dev")
    wire = affine("collective_wire_bytes_per_dev")
    n_dev = mesh.devices.size
    t_c, t_m, t_x = flops / PEAK_FLOPS, bytes_ / HBM_BW, wire / ICI_BW
    # fusion-aware memory term: raw HLO bytes count every unfused op and
    # overstate DRAM traffic 1-2 orders of magnitude (see EXPERIMENTS.md)
    adj_bytes = SP.hbm_bytes_estimate(cfg, shape, n_dev)
    t_m_adj = adj_bytes / HBM_BW
    terms = {"compute_s": t_c, "memory_s": t_m_adj, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    model_flops = SP.flops_estimate(cfg, shape)
    t_total = max(terms.values())
    mfu_bound = (model_flops / n_dev / PEAK_FLOPS) / max(t_total, 1e-30)
    return {
        "arch": arch, "shape": shape, "kind": SP.SHAPES[shape]["kind"],
        "mesh": "x".join(map(str, mesh.devices.shape)), "devices": n_dev,
        "hlo_flops_per_dev": flops, "hlo_bytes_per_dev": bytes_,
        "hbm_bytes_adj_per_dev": adj_bytes,
        "collective_wire_bytes_per_dev": wire,
        "t_compute_s": t_c, "t_memory_hlo_s": t_m, "t_memory_s": t_m_adj,
        "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(flops * n_dev, 1.0),
        "roofline_fraction": min(1.0, mfu_bound),
        "opt": opt_name, "remat": remat,
        "rules": {k: str(v) for k, v in (rule_overrides or {}).items()},
        "probes": {str(L): {k: r[k] for k in
                            ("hlo_flops_per_dev", "hlo_bytes_per_dev",
                             "collective_wire_bytes_per_dev", "compile_s")}
                   for L, r in samples},
    }


class _patched_config:
    """Temporarily route get_config(arch) to a probe config."""
    def __init__(self, arch, cfg):
        self.arch, self.cfg = arch, cfg

    def __enter__(self):
        import repro.launch.dryrun as DR
        self._orig = DR.get_config
        DR.get_config = lambda a: self.cfg if a == self.arch else self._orig(a)

    def __exit__(self, *exc):
        import repro.launch.dryrun as DR
        DR.get_config = self._orig


def fmt_row(r):
    if r.get("skipped"):
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | — | skipped |"
    return (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.0f}% |")


# ---------------------------------------------------------------------------
# --fused-sweep: achieved-vs-peak FLOP/s of the fused-transform sweep
# ---------------------------------------------------------------------------

def _sweep_flops(B, M_aug, d_eff, depth):
    """Analytic FLOPs of one levelwise-Horner signature sweep: per step and
    level n the Horner update (S_{n-1} + acc) ⊗ dx / n is ~3·d_eff^n
    multiply/adds per batch row (XLA cost_analysis counts scan bodies once,
    so the analytic law is the honest roofline numerator here)."""
    return 3.0 * B * M_aug * sum(d_eff ** n for n in range(1, depth + 1))


def fused_sweep(argv_out="runs/roofline"):
    """Achieved FLOP/s of the fused-transform sweep vs (a) this host and
    (b) the paper's reference-chip bf16 peak, before/after fusion."""
    import time

    import jax.numpy as jnp
    import numpy as np

    from repro.core import tensor_ops as tops
    from repro.core.transforms import (as_transform, augment_increments,
                                       transform_dim, transform_steps)
    from repro.kernels import ops

    def timed(fn, x, iters=5):
        fn_j = jax.jit(fn)
        jax.block_until_ready(fn_j(x))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_j(x))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    spec = as_transform("time_augment+lead_lag")
    rows = []
    print("| cell | mode | ms | achieved GFLOP/s | % ref-chip peak |")
    print("|---|---|---|---|---|")
    for B, M, d, N in [(32, 100, 6, 2), (32, 200, 3, 3), (64, 500, 4, 4)]:
        rng = np.random.default_rng(0)
        path = jnp.asarray(np.cumsum(
            rng.standard_normal((B, M + 1, d), np.float32) * 0.1, axis=1))
        incs = tops.path_increments(path)
        d_eff = transform_dim(spec, d)
        M_aug = transform_steps(spec, M)
        fl = _sweep_flops(B, M_aug, d_eff, N)
        t_mat = timed(lambda x: ops.signature(
            jnp.asarray(augment_increments(x, spec)), N, backend="jax"), incs)
        t_fused = timed(lambda x: ops.signature(
            x, N, backend="jax", transform=spec), incs)
        for mode, t in (("materialised", t_mat), ("fused", t_fused)):
            gf = fl / t / 1e9
            frac = fl / t / PEAK_FLOPS
            rows.append(dict(B=B, M=M, d=d, N=N, d_eff=d_eff, M_aug=M_aug,
                             mode=mode, ms=t * 1e3, flops=fl,
                             achieved_gflops=gf, peak_fraction=frac))
            print(f"| B={B},M={M},d={d},N={N} | {mode} | {t*1e3:.2f} | "
                  f"{gf:.2f} | {frac*100:.4f}% |", flush=True)
    os.makedirs(argv_out, exist_ok=True)
    out = os.path.join(argv_out, "fused_sweep.json")
    with open(out, "w") as f:
        json.dump({"peak_flops_ref_chip": PEAK_FLOPS, "cells": rows}, f,
                  indent=2)
    print(f"wrote {out}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt", default="adafactor")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--out", default="runs/roofline")
    ap.add_argument("--fused-sweep", action="store_true",
                    help="report achieved-vs-peak FLOP/s of the fused-"
                         "transform signature sweep instead of the model "
                         "roofline")
    args = ap.parse_args(argv)
    if args.fused_sweep:
        fused_sweep(args.out)
        return
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SP.SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    print("| arch | shape | t_comp | t_mem | t_coll | dominant | "
          "useful(MODEL/HLO) | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in archs:
        for shape in shapes:
            try:
                r = measure(arch, shape, multi_pod=args.multi_pod,
                            opt_name=args.opt, remat=args.remat, mesh=mesh)
            except Exception as e:
                r = {"arch": arch, "shape": shape, "error": str(e),
                     "traceback": traceback.format_exc()}
                print(f"| {arch} | {shape} | ERROR {e} |", flush=True)
            tag = f"{arch}__{shape}" + ("__pod2" if args.multi_pod else "")
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(r, f, indent=2)
            if "error" not in r:
                print(fmt_row(r), flush=True)


if __name__ == "__main__":
    main()
