"""Paper Table 3 / Figure 2: log-signature runtime.

Validates the paper's §3.3 projection trick: computing the Lyndon-basis
log-signature WITHOUT materialising all d^N level-N coefficients
(``logsignature_projected``) vs the dense route (full signature, tensor log,
read Lyndon coordinates).  The paper reports the projected route is often
2-3x faster than the corresponding full-signature computation; here we
report the dense/projected ratio and the coefficient-count saving directly.

Both routes honour the ``PATHSIG_BACKEND`` env var (the engine dispatch's
backend string, e.g. ``pallas_interpret`` or ``hybrid`` for the projected
route), and every record lands in ``BENCH_table3.json`` — matching the
convention ``fig3_windows.py`` established.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core import logsig_dim, sig_dim
from repro.core.logsignature import (_projected_tables, logsignature,
                                     logsignature_projected)
from .common import header, make_paths, row, time_fn

BACKEND = os.environ.get("PATHSIG_BACKEND", "jax")
# the hybrid engine is projected-only: the dense route pins jax instead
DENSE_BACKEND = "jax" if BACKEND == "hybrid" else BACKEND
JSON_PATH = os.environ.get("PATHSIG_BENCH_JSON", "BENCH_table3.json")

CELLS = [  # (B, M, d, N) — paper Table 3 shapes, CPU-sized
    (32, 100, 6, 2), (32, 100, 6, 3), (32, 100, 6, 4),
    (64, 50, 4, 5), (64, 100, 4, 5),
    (16, 100, 10, 3),
]


def run(quick: bool = True) -> None:
    header("table3: log-signature runtime (paper Table 3 / Fig 2)")
    iters = 3 if quick else 10
    records = []
    for B, M, d, N in CELLS:
        path = make_paths(B, M, d)
        dense = jax.jit(lambda p: logsignature(p, N, backend=DENSE_BACKEND))
        proj = jax.jit(lambda p: logsignature_projected(p, N,
                                                        backend=BACKEND))
        t_dense = time_fn(dense, path, warmup=1, iters=iters)
        t_proj = time_fn(proj, path, warmup=1, iters=iters)
        # training mode: grad of sum-of-squares through each route
        g_dense = jax.jit(jax.grad(lambda p: jnp.sum(
            logsignature(p, N, backend=DENSE_BACKEND) ** 2)))
        g_proj = jax.jit(jax.grad(lambda p: jnp.sum(
            logsignature_projected(p, N, backend=BACKEND) ** 2)))
        tg_dense = time_fn(g_dense, path, warmup=1, iters=iters)
        tg_proj = time_fn(g_proj, path, warmup=1, iters=iters)

        plan = _projected_tables(d, N)[0]
        n_dense = sig_dim(d, N)
        n_proj = plan.closure_size
        tag = (f"B={B};M={M};d={d};N={N};logsig_dim={logsig_dim(d, N)};"
               f"backend={BACKEND}")
        row("table3/fwd/dense", f"{t_dense*1e3:.3f}", "ms", tag)
        row("table3/fwd/projected", f"{t_proj*1e3:.3f}", "ms", tag)
        row("table3/fwd/speedup", f"{t_dense/t_proj:.2f}", "x", tag)
        row("table3/train/dense", f"{tg_dense*1e3:.3f}", "ms", tag)
        row("table3/train/projected", f"{tg_proj*1e3:.3f}", "ms", tag)
        row("table3/train/speedup", f"{tg_dense/tg_proj:.2f}", "x", tag)
        row("table3/coeffs_computed", f"{n_proj}/{n_dense}",
            "projected/dense",
            f"{tag};saving={1 - n_proj/n_dense:.0%} of coefficients skipped")
        records.append({
            "B": B, "M": M, "d": d, "depth": N,
            "logsig_dim": logsig_dim(d, N), "backend": BACKEND,
            "fwd_dense_ms": t_dense * 1e3, "fwd_projected_ms": t_proj * 1e3,
            "fwd_speedup": t_dense / t_proj,
            "train_dense_ms": tg_dense * 1e3,
            "train_projected_ms": tg_proj * 1e3,
            "train_speedup": tg_dense / tg_proj,
            "coeffs_projected": n_proj, "coeffs_dense": n_dense,
        })
    out = {"benchmark": "table3_logsig", "backend": BACKEND,
           "records": records}
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    row("table3/json", JSON_PATH, "path", "")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (the default; kept explicit for CI logs)")
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    args = ap.parse_args()
    run(quick=not args.full)
