"""Paper Table 3 / Figure 2: log-signature runtime.

Validates the paper's §3.3 projection trick: computing the Lyndon-basis
log-signature WITHOUT materialising all d^N level-N coefficients
(``logsignature_projected``) vs the dense route (full signature, tensor log,
read Lyndon coordinates).  The paper reports the projected route is often
2-3x faster than the corresponding full-signature computation; here we
report the dense/projected ratio and the coefficient-count saving directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import logsig_dim, lyndon_words, sig_dim
from repro.core.logsignature import (_projected_tables, logsignature,
                                     logsignature_projected)
from .common import header, make_paths, row, time_fn

CELLS = [  # (B, M, d, N) — paper Table 3 shapes, CPU-sized
    (32, 100, 6, 2), (32, 100, 6, 3), (32, 100, 6, 4),
    (64, 50, 4, 5), (64, 100, 4, 5),
    (16, 100, 10, 3),
]


def run(quick: bool = True) -> None:
    header("table3: log-signature runtime (paper Table 3 / Fig 2)")
    iters = 3 if quick else 10
    for B, M, d, N in CELLS:
        path = make_paths(B, M, d)
        dense = jax.jit(lambda p: logsignature(p, N))
        proj = jax.jit(lambda p: logsignature_projected(p, N))
        t_dense = time_fn(dense, path, warmup=1, iters=iters)
        t_proj = time_fn(proj, path, warmup=1, iters=iters)
        # training mode: grad of sum-of-squares through each route
        g_dense = jax.jit(jax.grad(lambda p: jnp.sum(logsignature(p, N) ** 2)))
        g_proj = jax.jit(jax.grad(
            lambda p: jnp.sum(logsignature_projected(p, N) ** 2)))
        tg_dense = time_fn(g_dense, path, warmup=1, iters=iters)
        tg_proj = time_fn(g_proj, path, warmup=1, iters=iters)

        plan = _projected_tables(d, N)[0]
        n_dense = sig_dim(d, N)
        n_proj = plan.closure_size
        tag = f"B={B};M={M};d={d};N={N};logsig_dim={logsig_dim(d, N)}"
        row("table3/fwd/dense", f"{t_dense*1e3:.3f}", "ms", tag)
        row("table3/fwd/projected", f"{t_proj*1e3:.3f}", "ms", tag)
        row("table3/fwd/speedup", f"{t_dense/t_proj:.2f}", "x", tag)
        row("table3/train/dense", f"{tg_dense*1e3:.3f}", "ms", tag)
        row("table3/train/projected", f"{tg_proj*1e3:.3f}", "ms", tag)
        row("table3/train/speedup", f"{tg_dense/tg_proj:.2f}", "x", tag)
        row("table3/coeffs_computed", f"{n_proj}/{n_dense}",
            "projected/dense",
            f"{tag};saving={1 - n_proj/n_dense:.0%} of coefficients skipped")


if __name__ == "__main__":
    run()
