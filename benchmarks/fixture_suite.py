"""Synthetic benchmark suite for testing the regression gate itself.

Not part of the default suite list — reachable only via
``python -m benchmarks.run --only fixture``.  Emits the *native* flat
record schema (a top-level ``baseline_records`` list, see
``benchmarks/baselines/README.md``) so the gate path is exercised without
the per-shape extractors, and is steered entirely by environment
variables so tests can update baselines, inject a regression, and crash a
suite deterministically:

``PATHSIG_FIXTURE_MS``     wall-clock-shaped metric (default 10.0, lower
                           is better, unit ``ms``)
``PATHSIG_FIXTURE_THR``    throughput-shaped metric (default 100.0,
                           higher is better, unit ``req/s``)
``PATHSIG_FIXTURE_SHAPES`` exact count metric (default 4, unit ``count``)
``PATHSIG_FIXTURE_RAISE``  ``1`` → ``run()`` raises (crash-isolation
                           path of ``benchmarks/run.py``)
"""
from __future__ import annotations

import json
import os

JSON_PATH = "BENCH_fixture.json"


def _env_f(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, "") or default)
    except ValueError:
        return default


def run(quick: bool = True) -> None:
    if os.environ.get("PATHSIG_FIXTURE_RAISE", "").strip() == "1":
        raise RuntimeError("fixture suite crash (PATHSIG_FIXTURE_RAISE=1)")
    out = {
        "benchmark": "fixture",
        "quick": quick,
        "baseline_records": [
            # synthetic values are noiseless, so explicit tight floors
            # (the machine-calibrated unit defaults would hide the 2x
            # injected regressions the gate tests rely on)
            {"key": "fixture/latency_ms",
             "value": _env_f("PATHSIG_FIXTURE_MS", 10.0),
             "unit": "ms", "higher_is_better": False, "noise_floor": 0.25},
            {"key": "fixture/throughput",
             "value": _env_f("PATHSIG_FIXTURE_THR", 100.0),
             "unit": "req/s", "higher_is_better": True,
             "noise_floor": 0.25},
            {"key": "fixture/compiled_shapes",
             "value": _env_f("PATHSIG_FIXTURE_SHAPES", 4),
             "unit": "count", "higher_is_better": False},
        ],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=2)
    print(f"fixture: wrote {JSON_PATH}", flush=True)


if __name__ == "__main__":
    run()
