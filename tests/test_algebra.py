"""Property tests for the algebraic identities the kernel subsystem relies on.

The weighted Gram K = S diag(ω) Sᵀ is a *kernel* on path space because
signatures are grouplike: coordinate products are shuffle sums
(⟨S, u⟩⟨S, v⟩ = Σ c_w ⟨S, w⟩) and concatenation is Chen deconcatenation
(⟨S(x·y), w⟩ = Σ_{w=uv} ⟨S(x), u⟩⟨S(y), v⟩).  These hold exactly (up to
float error) for every engine, and they are what the PSD-ness and
symmetry of the Gram matrices reduce to.  Runs under real hypothesis or the
deterministic fallback shim alike.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (all_words, deconcatenations, flat_index, make_plan,
                        shuffle_product)
from repro.core.projection import projected_signature_from_increments
from repro.core.signature import signature_from_increments


def _incs(seed, B, M, d, scale=0.35):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, M, d)).astype(np.float32) * scale)


def _coord(flat, word, d):
    return np.asarray(flat)[..., flat_index(word, d)]


# ---------------------------------------------------------------------------
# shuffle product (host combinatorics)
# ---------------------------------------------------------------------------

def test_shuffle_product_counts_and_order():
    sh = shuffle_product((0,), (1,))
    assert sh == {(0, 1): 1, (1, 0): 1}
    sh = shuffle_product((0, 1), (2,))
    assert sh == {(2, 0, 1): 1, (0, 2, 1): 1, (0, 1, 2): 1}
    # |u ⧢ v| = C(|u|+|v|, |u|) counted with multiplicity
    sh = shuffle_product((0, 0), (0, 0))
    assert sum(sh.values()) == 6 and sh == {(0, 0, 0, 0): 6}
    assert shuffle_product((), (0, 1)) == {(0, 1): 1}


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 3), st.integers(1, 2), st.integers(1, 2),
       st.integers(0, 10**6))
def test_grouplike_shuffle_identity(d, lu, lv, seed):
    """⟨S, u⟩⟨S, v⟩ == Σ_w c_w ⟨S, w⟩ for random words and random paths —
    the grouplike inner-product property behind the Gram PSD-ness."""
    rng = np.random.default_rng(seed)
    u = tuple(rng.integers(0, d, lu))
    v = tuple(rng.integers(0, d, lv))
    depth = lu + lv
    incs = _incs(seed + 1, 3, 12, d)
    S = signature_from_increments(incs, depth)
    lhs = _coord(S, u, d) * _coord(S, v, d)
    rhs = sum(c * _coord(S, w, d) for w, c in shuffle_product(u, v).items())
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 3), st.integers(0, 10**6))
def test_chen_deconcatenation_identity(d, seed):
    """⟨S(x·y), w⟩ == Σ_{w=uv} ⟨S(x), u⟩⟨S(y), v⟩ (empty factors = 1)."""
    rng = np.random.default_rng(seed)
    depth = 3
    w = tuple(rng.integers(0, d, depth))
    xi = _incs(seed, 2, 8, d)
    yi = _incs(seed + 7, 2, 9, d)
    Sx = signature_from_increments(xi, depth)
    Sy = signature_from_increments(yi, depth)
    Sxy = signature_from_increments(jnp.concatenate([xi, yi], axis=1), depth)
    rhs = 0.0
    for u, v in deconcatenations(w):
        fu = 1.0 if not u else _coord(Sx, u, d)
        fv = 1.0 if not v else _coord(Sy, v, d)
        rhs = rhs + fu * fv
    np.testing.assert_allclose(_coord(Sxy, w, d), rhs, rtol=2e-4, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 3), st.integers(0, 10**6))
def test_shuffle_identity_on_projected_engine(d, seed):
    """The word-table engine satisfies the same shuffle identity: projecting
    onto {u, v} ∪ (u ⧢ v) reproduces ⟨S,u⟩⟨S,v⟩ from projected coords."""
    rng = np.random.default_rng(seed)
    u = (int(rng.integers(0, d)),)
    v = tuple(rng.integers(0, d, 2))
    sh = shuffle_product(u, v)
    words = [u, v] + sorted(sh)
    plan = make_plan(tuple(words), d)
    incs = _incs(seed + 3, 2, 10, d)
    coords = np.asarray(projected_signature_from_increments(incs, plan))
    lhs = coords[:, 0] * coords[:, 1]
    rhs = sum(sh[w] * coords[:, 2 + i] for i, w in enumerate(sorted(sh)))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# weighted Gram: symmetry + PSD over random paths and weights
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 3), st.integers(2, 3), st.integers(0, 10**6))
def test_weighted_gram_symmetric_psd(d, depth, seed):
    import repro.sigkernel as SK
    rng = np.random.default_rng(seed)
    B, M = 8, 14
    paths = jnp.asarray(np.cumsum(
        rng.normal(size=(B, M + 1, d)) * 0.3, axis=1).astype(np.float32))
    gamma = tuple(float(g) for g in rng.uniform(0.3, 2.5, d))
    lw = tuple(float(x) for x in rng.uniform(0.1, 1.5, depth))
    K = np.asarray(SK.sig_gram(paths, None, depth, gamma=gamma,
                               level_weights=lw, block_words=32))
    np.testing.assert_allclose(K, K.T, atol=1e-5 * np.abs(K).max())
    evals = np.linalg.eigvalsh((K + K.T) / 2)
    assert evals.min() >= -1e-5 * max(evals.max(), 1.0)


def test_gram_equals_shuffle_expansion_small():
    """On a tiny alphabet the kernel k(x, y) = Σ_w ω_w S_x[w] S_y[w] agrees
    with direct enumeration over the word basis — the Gram really is the
    weighted word-coordinate inner product."""
    import repro.sigkernel as SK
    d, depth = 2, 3
    incs_x = _incs(0, 2, 9, d)
    incs_y = _incs(1, 3, 7, d)
    Sx = signature_from_increments(incs_x, depth)
    Sy = signature_from_increments(incs_y, depth)
    w = SK.word_weights(d, depth, gamma=(0.7, 1.4))
    K = np.asarray(SK.sig_gram(
        jnp.concatenate([jnp.zeros((2, 1, d)), jnp.cumsum(incs_x, 1)], 1),
        jnp.concatenate([jnp.zeros((3, 1, d)), jnp.cumsum(incs_y, 1)], 1),
        depth, gamma=(0.7, 1.4)))
    manual = np.zeros((2, 3))
    for k, word in enumerate(all_words(d, depth)):
        manual += w[k] * np.outer(_coord(Sx, word, d), _coord(Sy, word, d))
    np.testing.assert_allclose(K, manual, rtol=1e-4, atol=1e-5)
