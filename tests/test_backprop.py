"""Backprop tests (paper §4): inverse-reconstruction VJP vs scan autodiff,
memory-law verification, projected/windowed gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.words import make_plan
from tests.conftest import make_path


def _grads(path, depth, backward):
    def loss(p):
        s = C.signature(p, depth, backward=backward)
        return jnp.sum(jnp.tanh(s) * jnp.arange(s.shape[-1]) * 1e-2)
    return jax.grad(loss)(jnp.asarray(path))


@pytest.mark.parametrize("d,N,M", [(2, 4, 13), (3, 3, 21), (4, 2, 7)])
def test_inverse_vjp_matches_autodiff(rng, d, N, M):
    path = make_path(rng, 3, M, d)
    g_ad = _grads(path, N, "autodiff")
    g_inv = _grads(path, N, "inverse")
    g_cp = _grads(path, N, "checkpoint")
    np.testing.assert_allclose(g_inv, g_ad, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(g_cp, g_ad, rtol=1e-3, atol=1e-5)


def test_vjp_against_finite_differences(rng):
    d, N, M = 2, 3, 6
    path = jnp.asarray(make_path(rng, 1, M, d))
    w = jnp.asarray(rng.normal(size=(C.sig_dim(d, N),)).astype(np.float32))

    def loss(p):
        return jnp.sum(C.signature(p, N, backward="inverse") * w)

    g = jax.grad(loss)(path)
    eps = 1e-3
    for idx in [(0, 0, 0), (0, 3, 1), (0, M, 0)]:
        pert = np.zeros(path.shape, np.float32)
        pert[idx] = eps
        fd = (loss(path + pert) - loss(path - pert)) / (2 * eps)
        assert abs(float(g[idx]) - float(fd)) < 5e-2 * max(1.0, abs(float(fd)))


def test_projected_vjp_matches_autodiff(rng):
    d, M = 3, 15
    words = [(0,), (1, 2), (2, 1, 0), (0, 0, 1)]
    plan = make_plan(words, d)
    path = jnp.asarray(make_path(rng, 2, M, d))

    def loss(p, mode):
        s = C.projected_signature(p, words, d, plan=plan, backward=mode)
        return jnp.sum(jnp.sin(s))

    g_inv = jax.grad(lambda p: loss(p, "inverse"))(path)
    g_ad = jax.grad(lambda p: loss(p, "autodiff"))(path)
    np.testing.assert_allclose(g_inv, g_ad, rtol=1e-3, atol=1e-5)


def test_windowed_gradients_flow(rng):
    path = jnp.asarray(make_path(rng, 2, 20, 2))
    wins = np.array([[0, 10], [5, 20]], np.int32)

    def loss(p):
        return jnp.sum(C.windowed_signature(p, wins, 3) ** 2)

    g = jax.grad(loss)(path)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0


def test_backward_memory_is_M_independent():
    """The paper's memory law (§4.2, Table 2): inverse-mode residuals hold
    only the terminal signature; autodiff scan residuals grow with M.

    We verify structurally on the jaxpr: count the total size of
    scan-carried residual outputs of the forward pass.
    """
    d, N = 2, 4

    def resid_bytes(mode, M):
        path = jnp.zeros((1, M + 1, d), jnp.float32)

        def loss(p):
            return jnp.sum(C.signature(p, N, backward=mode))

        # output of the vjp-forward: residuals appear as closed-over consts
        _, vjp = jax.vjp(loss, path)
        flat, _ = jax.tree_util.tree_flatten(vjp)
        return sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in flat if hasattr(x, "shape"))

    grow_inv = resid_bytes("inverse", 256) - resid_bytes("inverse", 32)
    grow_ad = resid_bytes("autodiff", 256) - resid_bytes("autodiff", 32)
    # inverse mode grows only by the increments themselves: (256-32)*d*4 bytes
    inc_growth = (256 - 32) * d * 4
    assert grow_inv <= 2 * inc_growth, (grow_inv, inc_growth)
    # autodiff mode must additionally store O(M · D_sig) intermediates
    assert grow_ad > 10 * inc_growth, (grow_ad, inc_growth)


def test_inverse_reconstruction_drift_bounded(rng):
    """Long-path drift check for the reconstruction backward (§4.2 note)."""
    path = jnp.asarray(make_path(rng, 1, 800, 2, scale=0.05))
    g_inv = _grads(path, 3, "inverse")
    g_cp = _grads(path, 3, "checkpoint")
    denom = float(jnp.max(jnp.abs(g_cp))) + 1e-12
    rel = float(jnp.max(jnp.abs(g_inv - g_cp))) / denom
    assert rel < 5e-3, rel
