import os
import sys

# Smoke tests and benches must see the single real CPU device; only
# launch/dryrun.py forces 512 placeholder devices (and only in its own
# process).  Guard against accidental inheritance.
os.environ.pop("XLA_FLAGS", None)

# Tier-1 unblock: several test modules import `hypothesis` at collection
# time, which is not installable in this container.  Install the
# deterministic fallback (fixed-seed @given/strategies stand-in) before any
# test module is imported; the real package wins when it is available.
# Loaded by file path: `tests` is not an importable package under every
# pytest entry point / cwd, but conftest's own directory always is known.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback",
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "_hypothesis_fallback.py"))
    _hypothesis_fallback = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_hypothesis_fallback)
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "tpu: needs compiled Pallas kernels (a real TPU device); "
        "skipped elsewhere")
    config.addinivalue_line(
        "markers", "slow: multi-second test (subprocess gate CLI, tiny "
        "train loops); run by default, deselect with -m 'not slow'")


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="compiled Pallas path needs a TPU "
                                "device (interpret-mode twin runs instead)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)


@pytest.fixture(autouse=True, scope="module")
def _drop_jit_caches_between_modules():
    # The suite is ~470 jit-heavy tests in one process; XLA's CPU JIT keeps
    # every compiled executable alive until the cache entry dies, and past
    # ~400 tests the accumulated code memory segfaults later compiles.
    # Modules don't share shapes enough for cross-module cache hits to
    # matter, so drop the caches at each module boundary.
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_path(rng, B, M, d, scale=0.3):
    return np.cumsum(rng.normal(size=(B, M + 1, d)) * scale, axis=1).astype(
        np.float32)
