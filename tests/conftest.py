import os

# Smoke tests and benches must see the single real CPU device; only
# launch/dryrun.py forces 512 placeholder devices (and only in its own
# process).  Guard against accidental inheritance.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_path(rng, B, M, d, scale=0.3):
    return np.cumsum(rng.normal(size=(B, M + 1, d)) * scale, axis=1).astype(
        np.float32)
