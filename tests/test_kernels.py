"""Pallas kernel tests: shape/dtype sweeps, allclose vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.words import (all_words, anisotropic_words, lyndon_words,
                              make_plan, make_tiled_plan)
from repro.kernels import ops, ref
from repro.kernels.sig_trunc import choose_split, cone_rows, sig_trunc


def _incs(seed, B, M, d, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, M, d)).astype(dtype) * 0.3)


# ---------------------------------------------------------------------------
# sig_trunc: shape sweep × split levels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,d,N", [
    (1, 1, 2, 1), (1, 5, 2, 3), (3, 17, 2, 6), (2, 9, 3, 4),
    (5, 13, 4, 3), (2, 7, 6, 2), (9, 21, 3, 5), (2, 3, 10, 2),
])
def test_sig_trunc_shapes(B, M, d, N):
    x = _incs(B * M * d, B, M, d)
    want = ref.sig_trunc_ref(x, N)
    got = sig_trunc(x, N, batch_tile=8, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("split", [0, 1, 2, 3])
def test_sig_trunc_splits_agree(split):
    x = _incs(0, 2, 11, 3)
    want = ref.sig_trunc_ref(x, 4)
    got = sig_trunc(x, 4, split=split, batch_tile=8, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_sig_trunc_batch_tile_padding():
    x = _incs(1, 5, 6, 2)   # B=5 not a multiple of the tile
    want = ref.sig_trunc_ref(x, 3)
    for bt in (2, 4, 8, 16):
        got = sig_trunc(x, 3, batch_tile=bt, interpret=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_sig_trunc_bf16():
    x = _incs(2, 2, 6, 3).astype(jnp.bfloat16)
    got = sig_trunc(x, 3, batch_tile=8, interpret=True)  # f32 accumulation
    want = ref.sig_trunc_ref(x.astype(jnp.float32), 3)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=2e-2, atol=2e-2)


def test_choose_split_respects_vmem():
    for d, N in [(3, 6), (8, 6), (10, 4), (40, 2)]:
        s = choose_split(d, N, 128, vmem_budget=6 * 2**20)
        state = (max(0, s - 1) + cone_rows(d, N, s) + d ** (N - s)) * 128 * 4
        assert state <= 6 * 2**20, (d, N, s, state)


@given(st.integers(2, 4), st.integers(1, 4), st.integers(1, 12),
       st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_sig_trunc_property(d, N, M, B):
    x = _incs(d * 1000 + N * 100 + M * 10 + B, B, M, d)
    want = ref.sig_trunc_ref(x, N)
    got = sig_trunc(x, N, batch_tile=8, interpret=True)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# sig_words: arbitrary word sets × tilings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("max_rows", [8, 32, 512])
def test_sig_words_full_truncation(max_rows):
    d, N = 3, 4
    x = _incs(7, 3, 9, d)
    tp = make_tiled_plan(all_words(d, N), d, max_rows=max_rows)
    got = ops.projected(x, tp, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(got, ref.sig_trunc_ref(x, N),
                               rtol=2e-4, atol=2e-5)


def test_sig_words_sparse_set():
    d = 4
    wordset = [(0,), (3, 2), (1, 1, 1, 1), (2, 0, 3), (3, 3)]
    x = _incs(8, 2, 14, d)
    got = ops.projected(x, wordset, backend="pallas_interpret", batch_tile=8)
    want = ref.sig_words_ref(x, wordset, d)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    # cross-check each coefficient against the dense oracle
    dense = ref.sig_trunc_ref(x, 4)
    from repro.core.words import flat_index
    for k, w in enumerate(wordset):
        np.testing.assert_allclose(got[:, k], dense[:, flat_index(w, d)],
                                   rtol=2e-4, atol=2e-5)


def test_sig_words_anisotropic():
    gamma, r = [1.0, 2.0, 1.5], 4.0
    ws = anisotropic_words(gamma, r)
    x = _incs(9, 2, 8, 3)
    tp = make_tiled_plan(ws, 3, max_rows=16)
    got = ops.projected(x, tp, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(got, ref.sig_words_ref(x, ws, 3),
                               rtol=2e-4, atol=2e-5)


def test_sig_words_lyndon_projection():
    """The log-signature projection word set (§3.3): dense to N-1 + Lyndon_N."""
    d, N = 2, 5
    ws = all_words(d, N - 1) + [w for w in lyndon_words(d, N) if len(w) == N]
    x = _incs(10, 2, 9, d)
    tp = make_tiled_plan(ws, d, max_rows=24)
    got = ops.projected(x, tp, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(got, ref.sig_words_ref(x, ws, d),
                               rtol=2e-4, atol=2e-5)


@given(st.integers(2, 4), st.data())
@settings(max_examples=15, deadline=None)
def test_sig_words_property(d, data):
    n_words = data.draw(st.integers(1, 8))
    wordset = list({tuple(data.draw(st.integers(0, d - 1))
                          for _ in range(data.draw(st.integers(1, 4))))
                    for _ in range(n_words)})
    M = data.draw(st.integers(1, 10))
    x = _incs(data.draw(st.integers(0, 10**6)), 2, M, d)
    max_rows = data.draw(st.sampled_from([8, 16, 128]))
    tp = make_tiled_plan(wordset, d, max_rows=max_rows)
    got = ops.projected(x, tp, backend="pallas_interpret", batch_tile=8)
    want = ref.sig_words_ref(x, wordset, d)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# dispatch + time-parallel
# ---------------------------------------------------------------------------

def test_ops_backends_agree():
    x = _incs(11, 3, 12, 3)
    a = ops.signature(x, 4, backend="jax")
    b = ops.signature(x, 4, backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("chunks", [2, 4, 7])
def test_time_parallel_combine(chunks):
    x = _incs(12, 2, 13, 3)
    want = ref.sig_trunc_ref(x, 4)
    got = ops.signature_time_parallel(x, 4, chunks,
                                      backend="pallas_interpret", batch_tile=8)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
