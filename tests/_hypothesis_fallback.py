"""Deterministic stand-in for ``hypothesis`` (tier-1 unblock).

This container cannot install hypothesis, and five test modules import it at
collection time.  ``conftest.py`` installs this module into
``sys.modules["hypothesis"]`` when the real package is missing, so the suite
collects and runs either way.

The stand-in draws a small, fixed number of deterministic examples per test
(seeded from the test's qualified name), covering the subset of the API the
suite uses: ``given``, ``settings``, and the strategies ``integers``,
``floats``, ``lists``, ``tuples``, ``sampled_from``, ``data``.  It is NOT a
property-based tester — no shrinking, no example database — just enough
deterministic coverage to keep the property tests meaningful.
"""
from __future__ import annotations

import zlib

import numpy as np

__version__ = "0.0-fallback"

_MAX_EXAMPLES = 5  # handful of deterministic examples per test


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                x = self._draw(rng)
                if pred(x):
                    return x
            raise ValueError("filter predicate never satisfied")
        return _Strategy(draw)


class _DataObject:
    """Stand-in for the object st.data() passes to the test."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


class strategies:  # noqa: N801 — mirrors the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elements))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def data():
        return _DataStrategy()


def settings(**kw):
    """Decorator recording settings; only max_examples is honoured (capped)."""
    def deco(fn):
        if getattr(fn, "_fallback_given", False):
            return fn  # settings applied outside given: nothing left to do
        fn._fallback_settings = kw
        return fn
    return deco


def given(*strategy_args, **strategy_kwargs):
    if strategy_args and strategy_kwargs:
        raise NotImplementedError(
            "the hypothesis fallback supports positional OR keyword "
            "strategies, not a mix")

    def deco(fn):
        declared = getattr(fn, "_fallback_settings", {}).get(
            "max_examples", _MAX_EXAMPLES)
        n_examples = min(int(declared), _MAX_EXAMPLES)

        def wrapper():
            for i in range(n_examples):
                seed = zlib.adler32(f"{fn.__module__}.{fn.__qualname__}"
                                    f"#{i}".encode())
                rng = np.random.default_rng(seed)
                fn(*[s._draw(rng) for s in strategy_args],
                   **{k: s._draw(rng) for k, s in strategy_kwargs.items()})

        # pytest inspects the signature to map fixtures: expose a zero-arg
        # callable (the suite never mixes fixtures with @given)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._fallback_given = True
        return wrapper
    return deco


class HealthCheck:
    too_slow = data_too_large = filter_too_much = all = None


def assume(condition):
    if not condition:
        raise ValueError("fallback assume() violated: restructure the draw")
