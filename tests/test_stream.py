"""Stream-axis tests: streamed kernels/dispatch identities, window routes,
and the online SignatureStream carry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core import tensor_ops as tops
from repro.core.signature import signature_from_increments, stream_emit_steps
from repro.core.projection import _scan_projected
from repro.core.stream import signature_stream_init
from repro.core.windows import select_route
from repro.core.words import make_plan
from repro.kernels import ops

BACKENDS = ["jax", "pallas_interpret", "auto"]
WORDS = [(0,), (2, 1), (1, 1, 0), (0, 0, 1)]


def _incs(seed, B, M, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, M, d)).astype(np.float32) * 0.3)


def _plan():
    return make_plan(WORDS, 3)


# ---------------------------------------------------------------------------
# stream_emit_steps contract
# ---------------------------------------------------------------------------

def test_stream_emit_steps():
    assert list(stream_emit_steps(10, 1)) == list(range(10))
    assert list(stream_emit_steps(10, 4)) == [3, 7, 9]   # terminal appended
    assert list(stream_emit_steps(8, 4)) == [3, 7]
    assert list(stream_emit_steps(3, 100)) == [2]        # stride > M
    assert len(stream_emit_steps(10, 4)) == -(-10 // 4)  # ceil(M/stride)
    assert list(stream_emit_steps(0, 3)) == []           # M=0: no emissions
    with pytest.raises(ValueError, match="stream_stride"):
        stream_emit_steps(10, 0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_zero_length_path(backend):
    """M=0 streamed calls used to crash with an out-of-range gather."""
    x = jnp.zeros((2, 0, 3), jnp.float32)
    out = ops.signature(x, 3, backend=backend, stream=True, stream_stride=2)
    assert out.shape == (2, 0, C.sig_dim(3, 3))
    proj = ops.projected(x, _plan(), backend=backend, stream=True)
    assert proj.shape == (2, 0, len(WORDS))


# ---------------------------------------------------------------------------
# streamed forward identities on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("stride", [1, 3])
def test_stream_last_step_is_terminal_every_backend(backend, stride):
    x = _incs(0, 2, 10, 3)
    out = ops.signature(x, 3, backend=backend, batch_tile=8, stream=True,
                        stream_stride=stride)
    assert out.shape == (2, -(-10 // stride), C.sig_dim(3, 3))
    term = ops.signature(x, 3, backend=backend, batch_tile=8)
    np.testing.assert_allclose(out[:, -1], term, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("stride", [1, 2, 7])
def test_stream_values_match_scan_oracle(backend, stride):
    x = _incs(1, 2, 9, 3)
    steps = jnp.asarray(stream_emit_steps(9, stride))
    ref = signature_from_increments(x, 3, stream=True,
                                    backward="autodiff")[:, steps]
    out = ops.signature(x, 3, backend=backend, batch_tile=8, stream=True,
                        stream_stride=stride)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("stride", [1, 4])
def test_projected_stream_values(backend, stride):
    x = _incs(2, 2, 9, 3)
    plan = _plan()
    steps = jnp.asarray(stream_emit_steps(9, stride))
    ref = _scan_projected(x, plan, stream=True)[:, steps]
    out = ops.projected(x, plan, backend=backend, batch_tile=8, stream=True,
                        stream_stride=stride)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# streamed gradients: the generalised §4.2 reverse sweep vs the jax oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
@pytest.mark.parametrize("stride", [1, 2])
def test_stream_grad_matches_autodiff_oracle(backend, stride):
    x = _incs(3, 2, 8, 3)

    def loss(fn):
        return lambda z: jnp.sum(jnp.tanh(fn(z)))

    g_ref = jax.grad(loss(lambda z: signature_from_increments(
        z, 3, stream=True, stream_stride=stride, backward="autodiff")))(x)
    g = jax.grad(loss(lambda z: ops.signature(
        z, 3, backend=backend, batch_tile=8, stream=True,
        stream_stride=stride)))(x)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_projected_stream_grad_matches_oracle(backend):
    x = _incs(4, 2, 7, 3)
    plan = _plan()
    g_ref = jax.grad(lambda z: jnp.sum(jnp.sin(_scan_projected(
        z, plan, stream=True))))(x)
    g = jax.grad(lambda z: jnp.sum(jnp.sin(ops.projected(
        z, plan, backend=backend, batch_tile=8, stream=True))))(x)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# unsupported stream cells raise (no more silent degradation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_stream_checkpoint_raises(backend):
    x = _incs(5, 1, 6, 2)
    with pytest.raises(NotImplementedError, match="stream"):
        ops.signature(x, 2, backend=backend, backward="checkpoint",
                      stream=True)
    with pytest.raises(NotImplementedError, match="stream"):
        signature_from_increments(x, 2, stream=True, backward="checkpoint",
                                  backend=backend)


def test_stream_time_chunks_raises():
    x = _incs(6, 1, 6, 2)
    with pytest.raises(NotImplementedError, match="time_chunks"):
        ops.signature(x, 2, backend="pallas_interpret", stream=True,
                      time_chunks=2)


def test_stream_stride_validates():
    x = _incs(7, 1, 6, 2)
    with pytest.raises(ValueError, match="stream_stride"):
        ops.signature(x, 2, backend="jax", stream=True, stream_stride=0)


# ---------------------------------------------------------------------------
# window routes: fold vs chen agree, auto picks sensibly, grads match
# ---------------------------------------------------------------------------

def _path(seed, B, M, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.cumsum(rng.normal(size=(B, M + 1, d)) * 0.3,
                                 axis=1).astype(np.float32))


def test_fold_vs_chen_random_overlapping_windows():
    path = _path(0, 2, 30, 3)
    rng = np.random.default_rng(1)
    l = rng.integers(0, 25, size=12)
    r = l + rng.integers(1, 6, size=12)
    windows = np.stack([l, np.minimum(r, 30)], axis=1).astype(np.int32)
    a = C.windowed_signature(path, windows, 3, route="fold")
    b = C.windowed_signature(path, windows, 3, route="chen")
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("backend", ["jax", "pallas_interpret"])
def test_window_route_grads_match(backend):
    path = _path(2, 2, 24, 3)
    windows = C.sliding_windows(24, 12, stride=2)  # heavy overlap

    def g(route):
        return jax.grad(lambda p: jnp.sum(C.windowed_signature(
            p, windows, 3, route=route, backend=backend) ** 2))(path)

    g_ref = jax.grad(lambda p: jnp.sum(C.windowed_signature(
        p, windows, 3, route="fold", backward="autodiff",
        backend="jax") ** 2))(path)
    np.testing.assert_allclose(g("fold"), g_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g("chen"), g_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g("auto"), g_ref, rtol=1e-4, atol=1e-5)


def test_windowed_checkpoint_backward_stays_on_fold_route():
    """route='auto' + backward='checkpoint' used to pick chen and raise
    (the chen route streams, and stream has no checkpoint backward)."""
    path = _path(8, 2, 24, 3)
    heavy = C.sliding_windows(24, 12, stride=1)
    assert select_route("auto", heavy, 24, backward="checkpoint") == "fold"
    out = C.windowed_signature(path, heavy, 3, backward="checkpoint")
    ref = C.windowed_signature(path, heavy, 3, route="fold")
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    C.windowed_projection(path, heavy, _plan(), backward="checkpoint")


def test_rolling_drop_everything_resets_exactly():
    x = _incs(30, 2, 8, 3)
    st = signature_stream_init(2, 3, 3, capacity=8).extend(x)
    st = st.rolling_drop(8)
    assert st.length == 0
    assert float(jnp.max(jnp.abs(st.sig))) == 0.0  # exact identity, no drift


def test_route_cost_model():
    # heavy overlap: many long windows over a short path -> chen
    heavy = C.sliding_windows(64, 32, stride=2)
    assert select_route("auto", heavy, 64) == "chen"
    # disjoint short windows -> fold
    light = np.asarray([[0, 4], [30, 34], [60, 64]], np.int32)
    assert select_route("auto", light, 64) == "fold"
    assert select_route("fold", heavy, 64) == "fold"    # explicit wins
    assert select_route("chen", light, 64) == "chen"
    with pytest.raises(ValueError, match="route"):
        select_route("nope", light, 64)


def test_windowed_signature_chen_has_backend_surface():
    path = _path(3, 2, 16, 3)
    windows = C.sliding_windows(16, 8, stride=4)
    a = C.windowed_signature_chen(path, windows, 3)
    b = C.windowed_signature_chen(path, windows, 3,
                                  backend="pallas_interpret",
                                  backward="inverse")
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_windowed_projection_routes_agree():
    path = _path(4, 2, 24, 3)
    windows = C.sliding_windows(24, 12, stride=3)
    plan = _plan()
    a = C.windowed_projection(path, windows, plan, route="fold")
    b = C.windowed_projection(path, windows, plan, route="chen")
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# SignatureStream: online extend / rolling_drop identities
# ---------------------------------------------------------------------------

def test_stream_state_extend_matches_one_shot():
    x = _incs(10, 2, 20, 3)
    st = signature_stream_init(2, 3, 3, capacity=32)
    st = st.extend(x[:, :7]).extend(x[:, 7:12]).extend(x[:, 12:])
    ref = signature_from_increments(x, 3)
    np.testing.assert_allclose(st.sig, ref, rtol=1e-5, atol=1e-6)
    assert st.length == 20


def test_stream_state_rolling_drop_matches_fresh_window():
    x = _incs(11, 2, 18, 3)
    st = signature_stream_init(2, 3, 3, capacity=18).extend(x)
    st = st.rolling_drop(6)
    ref = signature_from_increments(x[:, 6:], 3)
    np.testing.assert_allclose(st.sig, ref, rtol=1e-5, atol=1e-6)
    # matches the windowed entry point too
    path = jnp.concatenate([jnp.zeros_like(x[:, :1]),
                            jnp.cumsum(x, axis=1)], axis=1)
    win = C.windowed_signature(path, np.asarray([[6, 18]], np.int32), 3)
    np.testing.assert_allclose(st.sig, win[:, 0], rtol=1e-5, atol=1e-6)


def test_stream_state_ring_wraparound():
    x = _incs(12, 1, 30, 2)
    st = signature_stream_init(1, 2, 3, capacity=10)
    pos = 0
    for k in range(6):  # hop 5: extend 5, drop as needed
        chunk = x[:, 5 * k:5 * (k + 1)]
        need = max(0, st.length + 5 - 10)
        st = st.rolling_drop(need).extend(chunk)
        pos += need
    ref = signature_from_increments(x[:, pos:], 3)
    np.testing.assert_allclose(st.sig, ref, rtol=1e-4, atol=1e-5)
    assert st.length == 30 - pos


def test_stream_extend_padded_chunk_wider_than_ring():
    """A zero-padded chunk with m > capacity used to corrupt the ring: the
    wrapped scatter indices collide, and the stale write-backs for masked
    positions clobbered freshly written increments (so the next
    rolling_drop applied exp(-0) instead of the true inverse)."""
    from repro.core.stream import (stream_extend, stream_init,
                                   stream_rolling_drop)
    R = 5
    x = _incs(42, 1, R, 2)
    carry = stream_init(1, 2, 3, capacity=R, valid=True)
    padded = jnp.concatenate([x, jnp.zeros((1, 3, 2))], axis=1)  # rung 8 > R
    carry = stream_extend(carry, padded, counts=jnp.asarray([R]))
    # every real increment landed in the ring exactly once
    np.testing.assert_allclose(np.asarray(carry.ring[0]), np.asarray(x[0]),
                               rtol=1e-6, atol=1e-7)
    # and the subsequent exact-inverse drop sees the true oldest increments
    carry = stream_rolling_drop(carry, 2, max_drop=2)
    ref = signature_from_increments(x[:, 2:], 3)
    np.testing.assert_allclose(carry.sig, ref, rtol=1e-5, atol=1e-6)


def test_stream_state_return_stream_features():
    x = _incs(13, 2, 12, 3)
    st = signature_stream_init(2, 3, 3).extend(x[:, :5])
    st, feats = st.extend(x[:, 5:], return_stream=True)
    ref = signature_from_increments(x, 3, stream=True,
                                    backward="autodiff")[:, 5:]
    np.testing.assert_allclose(feats, ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(st.sig, feats[:, -1], rtol=1e-6, atol=1e-7)


def test_stream_state_guards():
    st = signature_stream_init(1, 2, 2, capacity=4)
    with pytest.raises(ValueError, match="capacity"):
        st.extend(_incs(14, 1, 5, 2))  # overflow
    with pytest.raises(ValueError, match="drop"):
        st.extend(_incs(15, 1, 3, 2)).rolling_drop(4)  # more than held
    with pytest.raises(ValueError, match="ring"):
        signature_stream_init(1, 2, 2).rolling_drop(1)  # no ring
    with pytest.raises(ValueError, match="dim"):
        st.extend(_incs(16, 1, 2, 3))


def test_stream_state_grad_and_jit():
    x = _incs(17, 2, 10, 3)

    def loss(z):
        st = signature_stream_init(2, 3, 3, capacity=16)
        st = st.extend(z).rolling_drop(3)
        return jnp.sum(st.sig ** 2)

    g = jax.grad(loss)(x)
    g_ref = jax.grad(lambda z: jnp.sum(signature_from_increments(
        z[:, 3:], 3) ** 2))(x)
    # dropped steps carry ~1e-6 float32 cancellation residue (exact-zero in
    # the reference), hence the absolute tolerance
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=5e-6)
    st = jax.jit(lambda s, z: s.extend(z))(
        signature_stream_init(2, 3, 3, capacity=16), x)
    assert st.length == 10


# ---------------------------------------------------------------------------
# serving + model wiring
# ---------------------------------------------------------------------------

def test_sig_stream_engine_hopping_window():
    from repro.serve import SigStreamEngine
    eng = SigStreamEngine(d=3, depth=3, batch=2, window=12, backend="jax")
    x = _incs(20, 2, 24, 3)
    for k in range(6):
        feats = eng.push(x[:, 4 * k:4 * (k + 1)])
        assert feats.shape == (2, 4, C.sig_dim(3, 3))
    assert eng.state.length <= 12
    lo = 24 - eng.state.length
    ref = signature_from_increments(x[:, lo:], 3)
    np.testing.assert_allclose(eng.features, ref, rtol=1e-4, atol=1e-5)
    eng.reset()
    assert eng.state.length == 0


def test_sig_head_stream_features_match_pool_at_terminal():
    from repro.models.config import ModelConfig, SigHeadConfig
    from repro.models.sig_head import (init_sig_head, sig_pool,
                                       sig_stream_features)
    cfg = ModelConfig(name="t", family="decoder", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      sig_head=SigHeadConfig(channels=3, depth=3,
                                             stream_stride=2))
    p = init_sig_head(jax.random.PRNGKey(0), cfg, 5)
    h = jnp.asarray(np.random.default_rng(21).normal(
        size=(2, 9, 16)).astype(np.float32))
    feats = sig_stream_features(p, h, cfg)
    assert feats.shape == (2, 4, 5)  # ceil(8 steps / stride 2)
    np.testing.assert_allclose(feats[:, -1], sig_pool(p, h, cfg),
                               rtol=1e-5, atol=1e-6)
    g = jax.grad(lambda hh: jnp.sum(sig_stream_features(p, hh, cfg) ** 2))(h)
    assert bool(jnp.all(jnp.isfinite(g)))
