"""Substrate tests: trainer, checkpointing, optimizers, compression, data
pipeline, serving engine, HLO accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config, reduce_config
from repro.data.pipeline import ShardedLoader, TokenStream, fbm_paths
from repro.distributed.hlo import collective_stats, remat_duplication
from repro.optim import (adafactor, adamw, clip_by_global_norm,
                         compress_int8, decompress_int8, global_norm,
                         linear_warmup_cosine, sgd)
from repro.optim.compression import init_error_state
from repro.serve import ServeEngine
from repro.train import TrainLoopConfig, make_train_step, train_loop

KEY = jax.random.PRNGKey(0)


def tiny_cfg():
    import dataclasses
    return dataclasses.replace(reduce_config(get_config("qwen3-4b")),
                               n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64,
                               vocab_size=64)


# --------------------------------------------------------------- optimizers

@pytest.mark.parametrize("make_opt", [adamw, adafactor, sgd])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt(lr=0.1)
    params = {"w": jnp.ones((4, 130)) * 3.0}    # >=128 cols: adafactor factors
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(25):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(jnp.add, params, upd)
    assert float(loss(params)) < 0.5 * l0


def test_schedule_warmup_then_decay():
    lr = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    vals = [float(lr(s)) for s in range(0, 100, 5)]
    assert vals[0] < vals[1]                 # warming up
    assert vals[-1] < max(vals)              # decayed
    assert abs(float(lr(10)) - 1.0) < 1e-6   # peak at end of warmup


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 10.0}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(gn) > 1.0


# -------------------------------------------------------------- compression

def test_int8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = compress_int8(x)
    err = jnp.max(jnp.abs(decompress_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6   # half-ulp of the int8 grid


def test_error_feedback_compensates(rng):
    """With EF, the *accumulated* quantised signal tracks the accumulated
    true signal (bias-free compression) — the EF-SGD guarantee."""
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 1e-3
    e = jnp.zeros_like(g)
    acc_q, acc_g = jnp.zeros_like(g), jnp.zeros_like(g)
    for _ in range(50):
        q, s = compress_int8(g + e)
        deq = decompress_int8(q, s)
        e = (g + e) - deq
        acc_q = acc_q + deq
        acc_g = acc_g + g
    # residual error is bounded by one quantisation step, not 50 of them
    assert float(jnp.max(jnp.abs(acc_q - acc_g))) <= float(s) + 1e-6


# ---------------------------------------------------------------- data pipe

def test_token_stream_deterministic_and_seekable():
    a = TokenStream(64, 2, 8, seed=3)
    b1, b2 = next(a), next(a)
    b = TokenStream(64, 2, 8, seed=3)
    b.restore({"step": 1, "seed": 3})
    b2_again = next(b)
    np.testing.assert_array_equal(b2["tokens"], b2_again["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_sharded_loader_splits_batch():
    s0 = ShardedLoader(TokenStream(64, 4, 8, seed=1), 0, 2)
    s1 = ShardedLoader(TokenStream(64, 4, 8, seed=1), 1, 2)
    b0, b1 = next(s0), next(s1)
    assert b0["tokens"].shape == (2, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    full = next(TokenStream(64, 4, 8, seed=1))
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), full["tokens"])


def test_fbm_scaling_exponent():
    """E[X_t^2] = t^(2H): check the generator's covariance structure."""
    rng = np.random.default_rng(0)
    for H in (0.3, 0.7):
        X = fbm_paths(rng, 400, 64, H, d=1)
        var_half = np.var(X[:, 32, 0])
        var_full = np.var(X[:, 64, 0])
        est = 0.5 * np.log2(var_full / var_half)   # t doubles: ratio = 2^{2H}
        assert abs(est - H) < 0.12, (H, est)


# ------------------------------------------------------------ checkpointing

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    params = {"w": jnp.arange(6.0).reshape(2, 3)}
    opt_state = {"m": jnp.ones((2, 3)), "step": jnp.int32(7)}
    for step in (1, 2, 3):
        ck.save(params, opt_state, step, extra={"data_step": step * 10})
    assert latest_step(str(tmp_path)) == 3
    assert sorted(os.listdir(tmp_path)) == ["step_2", "step_3"]  # gc keep=2
    zeros = jax.tree.map(jnp.zeros_like, params)
    zstate = jax.tree.map(jnp.zeros_like, opt_state)
    p, s, extra = ck.restore(zeros, zstate, 3)
    np.testing.assert_array_equal(p["w"], params["w"])
    np.testing.assert_array_equal(s["step"], opt_state["step"])
    assert extra == {"data_step": 30}


def test_checkpoint_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    params = {"w": jnp.ones((4,))}
    ck.save(params, {"v": jnp.zeros((4,))}, 5)
    ck.wait()
    assert latest_step(str(tmp_path)) == 5


# ------------------------------------------------------------------ trainer

def test_microbatch_accumulation_matches_full_batch():
    cfg = tiny_cfg()
    params = M.init_params(KEY, cfg, jnp.float32)
    opt = sgd(lr=0.0)   # lr=0: isolate the gradient computation
    batch = {"tokens": jnp.ones((4, 8), jnp.int32),
             "labels": jnp.arange(32, dtype=jnp.int32).reshape(4, 8) % 64}
    full = make_train_step(cfg, opt, microbatch=0)
    acc = make_train_step(cfg, opt, microbatch=2)
    _, _, m_full = jax.jit(full)(params, opt.init(params), batch)
    _, _, m_acc = jax.jit(acc)(params, opt.init(params), batch)
    assert abs(float(m_full["loss"]) - float(m_acc["loss"])) < 1e-4
    np.testing.assert_allclose(float(m_full["grad_norm"]),
                               float(m_acc["grad_norm"]), rtol=1e-3)


def test_train_loop_with_restart(tmp_path):
    cfg = tiny_cfg()
    params = M.init_params(KEY, cfg, jnp.float32)
    opt = adamw(lr=1e-3)
    stream = TokenStream(cfg.vocab_size, 2, 8, seed=0)
    ck = Checkpointer(str(tmp_path), async_save=False)
    loop = TrainLoopConfig(steps=4, log_every=1, ckpt_every=2)
    params, opt_state, hist = train_loop(cfg, params, opt, iter(stream),
                                         loop, checkpointer=ck)
    assert latest_step(str(tmp_path)) == 4      # exit save
    assert len(hist) >= 2
    # restart from step 2 and run to 4 — must not raise, losses finite
    p2 = M.init_params(jax.random.PRNGKey(9), cfg, jnp.float32)
    loop2 = TrainLoopConfig(steps=4, log_every=1)
    stream2 = TokenStream(cfg.vocab_size, 2, 8, seed=0, step=2)
    params2, _, hist2 = train_loop(cfg, p2, opt, iter(stream2), loop2,
                                   checkpointer=ck, start_step=2)
    assert all(np.isfinite(h["loss"]) for h in hist2)


# ------------------------------------------------------------------ serving

def test_serve_engine_greedy_deterministic():
    cfg = tiny_cfg()
    params = M.init_params(KEY, cfg, jnp.float32)
    eng = ServeEngine(cfg, params, max_len=32, temperature=0.0)
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out1 = eng.generate(prompts, 8)
    out2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 3 + 8)
    assert int(out1.max()) < cfg.vocab_size


def test_serve_engine_eos_freezes():
    cfg = tiny_cfg()
    params = M.init_params(KEY, cfg, jnp.float32)
    eng = ServeEngine(cfg, params, max_len=32, temperature=0.0, eos_id=0)
    out = eng.generate(jnp.asarray([[1, 2]], jnp.int32), 12)
    toks = out[0, 2:].tolist()
    if 0 in toks:                                # once EOS appears, it stays
        first = toks.index(0)
        assert all(t == 0 for t in toks[first:])


# -------------------------------------------------------------- HLO parsing

HLO_SAMPLE = """
HloModule test
  %ag = bf16[64,128] all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  %ar = f32[1024] all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[16] collective-permute(%z), source_target_pairs={{0,1}}
  %dot1 = f32[8,8] dot(%a, %b)
  %dot2 = f32[8,8] dot(%a, %b)
"""


def test_collective_stats_parses_kinds():
    st = collective_stats(HLO_SAMPLE, default_group=4)
    assert set(st.by_kind) == {"all-gather", "all-reduce",
                               "collective-permute"}
    ag = st.by_kind["all-gather"]
    assert ag[0] == 1 and ag[1] == 64 * 128 * 2          # bf16 result bytes
    assert abs(ag[2] - ag[1] * 7 / 8) < 1e-6             # ring, group of 8
    ar = st.by_kind["all-reduce"]
    assert ar[1] == 4096 and abs(ar[2] - 2 * 4096 * 3 / 4) < 1e-6
    assert st.total_wire_bytes > 0


def test_remat_duplication_counts_duplicate_dots():
    assert remat_duplication(HLO_SAMPLE) == 2.0
    assert remat_duplication("no dots here") == 1.0


def test_donation_stats_parses_module_header_and_stablehlo():
    from repro.distributed.hlo import assert_donation, donation_stats

    opt = ('HloModule jit_step, input_output_alias={ {0}: (0, {}, '
           'may-alias), {1}: (1, {}, may-alias) }\n%x = f32[4] parameter(0)')
    st = donation_stats(opt)
    assert st.n_aliased == 2
    assert [(p, k) for _o, p, k in st.pairs] == [(0, "may-alias"),
                                                 (1, "may-alias")]
    assert_donation(opt, min_aliased=2)

    stable = ('func.func public @main(%arg0: tensor<4xf32> '
              '{tf.aliasing_output = 0 : i32}) -> tensor<4xf32>')
    assert donation_stats(stable).n_aliased == 1

    import pytest as _pytest
    with _pytest.raises(AssertionError, match="aliased"):
        assert_donation("HloModule nothing_donated")


_HLO_RING_SERIAL = """
HloModule serial_ring
%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %gte = f32[8] get-tuple-element(%p), index=1
  %cp = f32[8] collective-permute(%gte), source_target_pairs={{0,1}}
  %d = f32[8,8] dot(%cp, %cp)
  ROOT %t = (s32[], f32[8]) tuple(%gte, %cp)
}
%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  ROOT %lt = pred[] compare(%p, %p), direction=LT
}
ENTRY %main (a: f32[8]) -> (s32[], f32[8]) {
  %a = f32[8] parameter(0)
  ROOT %w = (s32[], f32[8]) while(%a), condition=%cond, body=%body
}
"""

_HLO_RING_UNROLLED = """
HloModule unrolled_ring
ENTRY %main (a: f32[8]) -> f32[8,8] {
  %a = f32[8] parameter(0)
  %cp1 = f32[8] collective-permute(%a), source_target_pairs={{0,1}}
  %dot1 = f32[8,8] dot(%a, %a)
  %cp2 = f32[8] collective-permute(%cp1), source_target_pairs={{0,1}}
  %dot2 = f32[8,8] dot(%cp1, %cp1)
  ROOT %s = f32[8,8] add(%dot1, %dot2)
}
"""


def test_ring_overlap_classifies_serial_vs_unrolled():
    from repro.distributed.hlo import ring_overlap

    ser = ring_overlap(_HLO_RING_SERIAL)
    assert ser.in_loop and not ser.overlapped, ser.summary()

    ov = ring_overlap(_HLO_RING_UNROLLED)
    assert ov.n_permutes == 2 and ov.n_dots == 2, ov.summary()
    assert ov.overlapped, ov.summary()

    # a permute fed by a dot result is serialized behind the compute
    dep = ring_overlap(_HLO_RING_UNROLLED.replace(
        "collective-permute(%cp1)", "collective-permute(%dot1)"))
    assert dep.permute_depends_on_dot and not dep.overlapped, dep.summary()
