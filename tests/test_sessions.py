"""Pooled multi-tenant session layer: SessionStore semantics, traffic,
checkpoint/restore bit-identity, and the bounded serving caches.

Four testable contracts:

1. *Pool semantics*: continuous-batching ingest through one struct-of-arrays
   pool matches per-session oracles exactly; slots recycle through free
   list + generation counters; TTL/LRU eviction and grow-by-doubling keep
   occupancy honest; invalid input raises BEFORE any device work.
2. *Traffic*: ``SessionTickStream`` is deterministic under seed and
   seekable (state/restore replays the same rounds).
3. *Checkpoint/restore*: SignatureStream carries, RaggedPaths, and the
   whole session pool round-trip through ``repro.checkpoint`` bit-identical
   — single-device here, and an 8-device mesh twin in a subprocess
   (test_shard.py pattern; XLA locks the device count at first init).
4. *Bounded caches*: the per-shape jitted computes of DynamicBatcher and
   SessionStore live under the shared plan-cache policy — eviction is a
   pure perf event (results identical at maxsize=1).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core.signature import signature_from_increments
from repro.core.stream import StreamCarry, signature_stream_init, stream_init
from repro.data import SessionTickStream, session_tick_stream
from repro.serve import SessionStore, SigStreamEngine


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _oracle(chunks, depth):
    allinc = np.concatenate(chunks)
    return np.asarray(signature_from_increments(
        jnp.asarray(allinc)[None], depth)[0])


# ---------------------------------------------------------------------------
# pool semantics
# ---------------------------------------------------------------------------

def test_session_pool_matches_per_row_oracle(rng):
    d, depth = 3, 3
    store = SessionStore(d, depth, ring_capacity=64, initial_sessions=4,
                         max_ticks=8)
    handles = [store.create(f"u{i}") for i in range(10)]
    truth = {h.sid: [] for h in handles}
    for _ in range(3):
        for h in handles:
            if rng.random() < 0.3:
                continue                      # bursty: not everyone ticks
            inc = rng.normal(size=(int(rng.integers(1, 12)), d)) \
                .astype(np.float32)
            store.ingest(h.sid, inc)
            truth[h.sid].append(inc)
        store.flush()
    for h in handles:
        if not truth[h.sid]:
            assert store.length(h.sid) == 0
            continue
        np.testing.assert_allclose(np.asarray(store.features(h.sid)),
                                   _oracle(truth[h.sid], depth),
                                   atol=1e-5, err_msg=h.sid)
        assert store.length(h.sid) == sum(c.shape[0] for c in truth[h.sid])
    st = store.stats()
    assert st["sessions"] == 10 and st["pending_ticks"] == 0
    assert st["updates"] == sum(c.shape[0] for v in truth.values()
                                for c in v)
    # every flushed shape is a (pow2 tick rung, pow2 row rung) pair
    for rung, B in st["flush_shapes"]:
        assert rung & (rung - 1) == 0 and rung <= store.max_ticks
        assert B & (B - 1) == 0


def test_session_ingest_many_matches_ingest(rng):
    d, depth = 2, 3
    a = SessionStore(d, depth, initial_sessions=4)
    b = SessionStore(d, depth, initial_sessions=4)
    sids = [f"s{i}" for i in range(6)]
    counts = rng.integers(1, 9, size=6)
    ticks = rng.normal(size=(int(counts.sum()), d)).astype(np.float32)
    b.create_many(sids)
    a.ingest_many(sids, counts, ticks, auto_create=True)   # arrival path
    bounds = np.cumsum(counts)[:-1]
    for sid, chunk in zip(sids, np.split(ticks, bounds)):
        b.ingest(sid, chunk)
    a.flush()
    b.flush()
    for sid in sids:
        np.testing.assert_array_equal(np.asarray(a.features(sid)),
                                      np.asarray(b.features(sid)))


def test_session_validation_errors(rng):
    d, depth = 2, 2
    store = SessionStore(d, depth, ring_capacity=4, initial_sessions=2)
    store.create("u")
    with pytest.raises(ValueError, match="already exists"):
        store.create("u")
    with pytest.raises(KeyError, match="unknown session"):
        store.lookup("nope")
    with pytest.raises(ValueError, match=r"must be \(m, 2\)"):
        store.ingest("u", np.zeros((3, 5), np.float32))
    with pytest.raises(ValueError, match="counts sum"):
        store.ingest_many(["u"], [3], np.zeros((2, d), np.float32))
    h = store.lookup("u")
    store.evict("u")
    with pytest.raises(ValueError, match="stale session handle"):
        store.lookup(h)
    with pytest.raises(ValueError, match="stale session handle"):
        store.ingest(h, np.zeros((1, d), np.float32))
    # ring overflow raises BEFORE any device work, pool untouched
    store.create("v")
    store.ingest("v", rng.normal(size=(3, d)).astype(np.float32))
    store.flush()
    before = np.asarray(store.pool.sig)
    store.ingest("v", rng.normal(size=(2, d)).astype(np.float32))
    with pytest.raises(ValueError, match="rolling_drop at least 1"):
        store.flush()
    np.testing.assert_array_equal(np.asarray(store.pool.sig), before)


def test_session_occupancy_errors_raise_through_pooled_engine_path(rng):
    # the SignatureStream occupancy contract survives the SessionStore
    # re-backing: block extends past the ring raise the same way
    eng = SigStreamEngine(d=2, depth=2, batch=2, window=8, backend="jax")
    with pytest.raises(ValueError, match="rolling_drop at least"):
        eng.store.extend_block(eng.handles,
                               np.zeros((2, 9, 2), np.float32))
    with pytest.raises(ValueError, match="cannot drop"):
        eng.store.drop_block(eng.handles, 1)
    nowin = SessionStore(2, 2, initial_sessions=2)
    blk = nowin.create_block(2)
    with pytest.raises(ValueError, match="ring_capacity > 0"):
        nowin.drop_block(blk, 1)


def test_session_ttl_and_lru_eviction(rng):
    st = SessionStore(2, 2, initial_sessions=4, ttl=2.0)
    st.create("x", now=0.0)
    st.create("y", now=0.0)
    st.ingest("y", rng.normal(size=(2, 2)).astype(np.float32), now=3.0)
    st.flush(now=3.5)                        # sweeps: x idle > ttl
    assert "x" not in st and "y" in st
    assert st.evictions["ttl"] == 1

    lru = SessionStore(2, 2, initial_sessions=2, max_sessions=2)
    lru.create("p", now=0.0)
    lru.create("q", now=1.0)
    lru.ingest("p", rng.normal(size=(1, 2)).astype(np.float32), now=2.0)
    lru.create("r", now=3.0)                 # full: evicts q (oldest seen)
    assert "q" not in lru and "p" in lru and "r" in lru
    assert lru.evictions["lru"] == 1 and len(lru) == 2

    strict = SessionStore(2, 2, initial_sessions=2, max_sessions=2,
                          lru_evict=False)
    strict.create_many(["a", "b"])
    with pytest.raises(RuntimeError, match="pool full"):
        strict.create("c")


def test_empty_pool_stats_percentiles_are_zero_not_nan():
    """A fresh store has no staleness samples: stats() must report 0.0
    percentiles (finite), never NaN — downstream JSON sinks and dashboards
    choke on NaN."""
    store = SessionStore(2, 2, initial_sessions=2)
    st = store.stats()
    assert st["sessions"] == 0
    assert st["p50_staleness_s"] == 0.0 and st["p99_staleness_s"] == 0.0
    assert np.isfinite(st["p50_staleness_s"])
    assert np.isfinite(st["p99_staleness_s"])
    store.flush()                            # empty flush: still no samples
    st = store.stats()
    assert st["p50_staleness_s"] == 0.0 and st["p99_staleness_s"] == 0.0


def test_session_flush_rung_wider_than_ring_stays_exact(rng):
    """Non-power-of-two ring + a tick count padded to a wider rung: the
    flush's padded extend used to zero wrapped ring slots, so the next
    rolling_drop silently corrupted the window signature."""
    d, depth, R = 2, 3, 5
    store = SessionStore(d, depth, ring_capacity=R, initial_sessions=2)
    h = store.create("u")
    inc = rng.normal(size=(R, d)).astype(np.float32)
    store.ingest(h, inc)
    store.flush()                            # 5 ticks pad to rung 8 > R
    store.drop_block([h], 2)
    ref = np.asarray(signature_from_increments(
        jnp.asarray(inc[2:])[None], depth)[0])
    np.testing.assert_allclose(np.asarray(store.features(h)), ref,
                               rtol=1e-5, atol=1e-6)
    assert store.length(h) == R - 2


def test_create_many_respects_max_sessions(rng):
    # bulk admission sees its own in-flight creations: the strict bound
    # holds, LRU-evicting the earliest-admitted sessions per extra slot
    store = SessionStore(2, 2, initial_sessions=4, max_sessions=4)
    store.create_many([f"u{i}" for i in range(6)])
    assert len(store) == 4
    assert store.evictions["lru"] == 2
    assert "u0" not in store and "u1" not in store
    assert all(f"u{i}" in store for i in range(2, 6))

    strict = SessionStore(2, 2, initial_sessions=4, max_sessions=4,
                          lru_evict=False)
    strict.create_many(["a", "b"])
    with pytest.raises(RuntimeError, match="pool full"):
        strict.create_many(["c", "d", "e"])
    assert len(strict) == 2                  # atomic: no partial admission


def test_lru_eviction_prefers_sessions_without_pending_ticks(rng):
    store = SessionStore(2, 2, initial_sessions=2, max_sessions=2)
    store.create("a", now=0.0)
    store.create("b", now=1.0)
    # "a" is least-recently seen but has acknowledged (queued) ticks, so
    # the idle "b" is the LRU victim instead
    store.ingest("a", rng.normal(size=(3, 2)).astype(np.float32), now=0.5)
    store.create("c", now=2.0)
    assert "a" in store and "b" not in store and "c" in store
    assert store.stats()["dropped_ticks"] == 0
    store.flush()

    # every live session pending: fall back to true LRU, accounting the drop
    allp = SessionStore(2, 2, initial_sessions=2, max_sessions=2)
    allp.create("p", now=0.0)
    allp.create("q", now=1.0)
    allp.ingest("p", rng.normal(size=(4, 2)).astype(np.float32), now=0.0)
    allp.ingest("q", rng.normal(size=(2, 2)).astype(np.float32), now=1.0)
    allp.create("r", now=2.0)
    assert "p" not in allp
    assert allp.stats()["dropped_ticks"] == 4


def test_engine_validates_shared_store_backend_and_dtype():
    store = SessionStore(2, 2, ring_capacity=8, initial_sessions=4)
    with pytest.raises(ValueError, match="dtype"):
        SigStreamEngine(d=2, depth=2, batch=2, window=4, store=store,
                        dtype=jnp.float16)
    with pytest.raises(ValueError, match="backend"):
        SigStreamEngine(d=2, depth=2, batch=2, window=4, store=store,
                        backend="pallas_interpret")
    assert len(store) == 0                   # failed joins leave no slots


def test_session_slot_reuse_bumps_generation(rng):
    store = SessionStore(2, 2, initial_sessions=2, max_sessions=2)
    h_old = store.create("old")
    store.ingest("old", rng.normal(size=(4, 2)).astype(np.float32))
    store.flush()
    store.evict("old")
    h_new = store.create("new")              # reuses the freed slot
    assert h_new.slot == h_old.slot
    assert h_new.generation == h_old.generation + 1
    # the recycled slot is a FRESH session, not the old tenant's state
    assert store.length("new") == 0
    np.testing.assert_array_equal(np.asarray(store.features("new")), 0.0)
    with pytest.raises(ValueError, match="stale session handle"):
        store.lookup(h_old)


def test_session_pool_growth_preserves_rows(rng):
    d, depth = 3, 2
    store = SessionStore(d, depth, initial_sessions=2)
    store.create("keep")
    inc = rng.normal(size=(5, d)).astype(np.float32)
    store.ingest("keep", inc)
    store.flush()
    before = np.asarray(store.features("keep"))
    store.create_many([f"g{i}" for i in range(40)])   # forces doublings
    assert store.pool_size >= 41
    st = store.stats()
    assert st["pool_sizes"] == sorted(st["pool_sizes"])
    assert len(st["pool_sizes"]) >= 3                 # grew by doubling
    np.testing.assert_array_equal(np.asarray(store.features("keep")),
                                  before)
    assert store.length("keep") == 5


def test_session_flush_shapes_stay_bounded(rng):
    # adversarial traffic: every distinct (ticking-set size, tick count)
    # combination — compiled shapes must stay under the rung-grid bound
    d, depth = 2, 2
    store = SessionStore(d, depth, initial_sessions=32, max_ticks=16)
    store.create_many([f"u{i}" for i in range(30)])
    for r in range(12):
        k = int(rng.integers(1, 30))
        for sid in rng.choice(30, size=k, replace=False):
            m = int(rng.integers(1, 17))
            store.ingest(f"u{sid}",
                         rng.normal(size=(m, d)).astype(np.float32))
        store.flush()
    st = store.stats()
    n_tick_rungs = int(np.log2(store.max_ticks)) + 1
    n_row_rungs = int(np.log2(store.max_rows)) + 1
    bound = n_tick_rungs * n_row_rungs * len(st["pool_sizes"])
    assert st["compiled_shapes"] <= bound, st
    assert st["compute_cache"]["currsize"] <= st["compiled_shapes"] + 4


# ---------------------------------------------------------------------------
# traffic generator
# ---------------------------------------------------------------------------

def test_session_tick_stream_deterministic_and_seekable():
    kw = dict(seed=11, arrival_rate=2.0, churn_prob=0.05)
    a = session_tick_stream(40, 3, **kw)
    b = session_tick_stream(40, 3, **kw)
    for _ in range(4):
        ra, rb = next(a), next(b)
        assert ra["sids"] == rb["sids"]
        np.testing.assert_array_equal(ra["counts"], rb["counts"])
        np.testing.assert_array_equal(ra["ticks"], rb["ticks"])
        assert ra["departures"] == rb["departures"]
    state = a.state()
    r1 = next(a)
    c = SessionTickStream(40, 3, **kw)
    c.restore(state)
    r2 = next(c)
    assert r1["sids"] == r2["sids"]
    np.testing.assert_array_equal(r1["ticks"], r2["ticks"])
    assert r1["departures"] == r2["departures"]


def test_session_tick_stream_is_heavy_tailed_and_feeds_store():
    totals = {}
    s = session_tick_stream(150, 2, seed=1)
    store = SessionStore(2, 2, initial_sessions=8)
    for _ in range(20):
        r = next(s)
        assert r["ticks"].shape == (int(r["counts"].sum()), 2)
        assert (r["counts"] >= 1).all() and \
            (r["counts"] <= s.max_ticks).all()
        store.ingest_many(r["sids"], r["counts"], r["ticks"],
                          auto_create=True)
        store.flush()
        for sid, cnt in zip(r["sids"], r["counts"]):
            totals[sid] = totals.get(sid, 0) + int(cnt)
    v = np.asarray(sorted(totals.values()))
    assert v.max() / max(np.percentile(v, 50), 1) > 4   # whales exist
    assert store.stats()["updates"] == int(v.sum())


# ---------------------------------------------------------------------------
# checkpoint round trips (bit-identical)
# ---------------------------------------------------------------------------

def test_stream_carry_checkpoint_roundtrip(rng, tmp_path):
    d, depth = 3, 3
    ck = Checkpointer(str(tmp_path), async_save=False)
    state = signature_stream_init(4, d, depth, capacity=8)
    state = state.extend(jnp.asarray(
        rng.normal(size=(4, 6, d)).astype(np.float32)))
    pooled = stream_init(5, d, depth, capacity=8, valid=True)
    from repro.core.stream import stream_extend
    pooled = stream_extend(pooled, jnp.asarray(
        rng.normal(size=(5, 3, d)).astype(np.float32)),
        counts=jnp.asarray([3, 0, 2, 3, 1], jnp.int32))
    ck.save({"view": state, "pool": pooled}, {}, 1)
    like = {"view": signature_stream_init(4, d, depth, capacity=8)
            .extend(jnp.zeros((4, 6, d), jnp.float32)),
            "pool": stream_init(5, d, depth, capacity=8)}
    got, _, _ = ck.restore(like, {})
    for lane in ("sig", "ring"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got["view"], lane)),
            np.asarray(getattr(state, lane)))
    for lane in ("sig", "ring", "length", "end", "valid"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got["pool"], lane)),
            np.asarray(getattr(pooled, lane)))
    assert isinstance(got["pool"], StreamCarry)
    assert (got["pool"].d, got["pool"].depth) == (d, depth)


def test_ragged_paths_checkpoint_roundtrip(rng, tmp_path):
    from repro.ragged import RaggedPaths
    ck = Checkpointer(str(tmp_path), async_save=False)
    rp = RaggedPaths.from_list(
        [rng.normal(size=(L + 1, 2)).astype(np.float32)
         for L in (3, 7, 5)], pad_to=8)
    ck.save(rp, {}, 3)
    like = RaggedPaths(values=jnp.zeros_like(rp.values),
                       lengths=jnp.zeros_like(rp.lengths))
    got, _, _ = ck.restore(like, {})
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(rp.values))
    np.testing.assert_array_equal(np.asarray(got.lengths),
                                  np.asarray(rp.lengths))


def test_session_store_checkpoint_restart_resume(rng, tmp_path):
    d, depth = 3, 3
    ck = Checkpointer(str(tmp_path), async_save=False)
    store = SessionStore(d, depth, ring_capacity=512, initial_sessions=4,
                         ttl=100.0)
    traffic = session_tick_stream(12, d, seed=3)
    for _ in range(3):
        r = next(traffic)
        store.ingest_many(r["sids"], r["counts"], r["ticks"],
                          auto_create=True)
        store.flush()
    store.evict(next(iter(store._ids)))      # a freed slot must round-trip
    store.checkpoint(ck, step=5)

    restored = SessionStore.restore(ck)
    # bit-identical pool and host metadata
    for lane in ("sig", "ring", "length", "end", "valid"):
        np.testing.assert_array_equal(
            np.asarray(getattr(restored.pool, lane)),
            np.asarray(getattr(store.pool, lane)), err_msg=lane)
    assert restored._ids == store._ids
    assert restored._free == store._free
    assert restored.now == store.now
    assert restored.stats()["evictions"] == store.stats()["evictions"]
    for sid in store._ids:
        h_old, h_new = store.lookup(sid), restored.lookup(sid)
        assert h_old.slot == h_new.slot
        assert h_old.generation == h_new.generation

    # resume: identical traffic -> identical state on both sides
    tr2 = session_tick_stream(12, d, seed=3)
    tr2.restore(traffic.state())
    for src, st in ((traffic, store), (tr2, restored)):
        r = next(src)
        live = [s for s in r["sids"] if s in st]
        keep = [i for i, s in enumerate(r["sids"]) if s in st]
        counts = r["counts"][keep]
        chunks = np.split(r["ticks"], np.cumsum(r["counts"])[:-1])
        ticks = np.concatenate([chunks[i] for i in keep]) if keep else \
            np.zeros((0, d), np.float32)
        if live:
            st.ingest_many(live, counts, ticks)
            st.flush()
    for sid in store._ids:
        np.testing.assert_array_equal(
            np.asarray(store.features(sid)),
            np.asarray(restored.features(sid)), err_msg=sid)

    # restored pool keeps evicting / admitting correctly
    h = restored.create("fresh")
    assert h.sid in restored
    restored.evict("fresh")


def test_session_store_restore_rejects_non_pool_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save({"w": jnp.zeros((2, 2))}, {}, 1, extra={"kind": "model"})
    with pytest.raises(ValueError, match="not a session pool"):
        SessionStore.restore(ck)


# ---------------------------------------------------------------------------
# engines on the shared pool
# ---------------------------------------------------------------------------

def test_engine_joins_shared_multi_tenant_pool(rng):
    d, depth = 2, 3
    pool = SessionStore(d, depth, ring_capacity=16, initial_sessions=8)
    pool.create("tenant")
    tchunks = [rng.normal(size=(4, d)).astype(np.float32)]
    pool.ingest("tenant", tchunks[0])
    pool.flush()

    shared = SigStreamEngine(d=d, depth=depth, batch=3, window=12,
                             backend="jax", store=pool)
    private = SigStreamEngine(d=d, depth=depth, batch=3, window=12,
                              backend="jax")
    x = rng.normal(size=(3, 20, d)).astype(np.float32) * 0.3
    for k in range(5):
        fa = shared.push(x[:, 4 * k:4 * (k + 1)])
        fb = private.push(x[:, 4 * k:4 * (k + 1)])
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                   atol=1e-6)
    assert shared.store is pool and shared.state.length <= 12
    # the tenant's state survived the engine traffic in the same pool
    np.testing.assert_allclose(np.asarray(pool.features("tenant")),
                               _oracle(tchunks, depth), atol=1e-5)
    with pytest.raises(ValueError, match="needs >= "):
        SigStreamEngine(d=d, depth=depth, batch=2, window=32,
                        backend="jax", store=pool)
    with pytest.raises(ValueError, match="but the engine needs"):
        SigStreamEngine(d=d, depth=depth + 1, batch=2, backend="jax",
                        store=SessionStore(d, depth))


# ---------------------------------------------------------------------------
# bounded serving caches: eviction is a pure perf event
# ---------------------------------------------------------------------------

def test_batcher_and_pool_cache_eviction_never_changes_results(rng):
    from repro.kernels import ops
    from repro.serve import DynamicBatcher

    reqs = [rng.normal(size=(L + 1, 2)).astype(np.float32)
            for L in (3, 20, 7, 40, 12, 2)]

    def serve(maxsize):
        db = DynamicBatcher.signature_service(2, 3, max_len=64,
                                              backend="jax", min_bucket=4,
                                              max_batch=4)
        out = []
        for p in reqs:                        # one flush per request:
            t = db.submit(p)                  # alternate shapes -> evict
            out.append(np.asarray(db.flush()[t]))
        return out, db

    ref, _ = serve(None)
    old = ops.PLAN_CACHE_MAXSIZE
    try:
        ops.set_plan_cache_maxsize(1)
        got, db = serve(1)
        info = db.stats()["compute_cache"]
        assert info["maxsize"] == 1 and info["currsize"] <= 1
        assert info["misses"] > len(db.stats()["shapes"])   # re-jits happened
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)

        # same policy bounds the session pool's flush computes
        store = SessionStore(2, 2, initial_sessions=4, max_ticks=8)
        store.create_many(["a", "b"])
        truth = {"a": [], "b": []}
        for m in (1, 5, 2, 8, 3, 1):          # alternate rungs -> evict
            inc = rng.normal(size=(m, 2)).astype(np.float32)
            store.ingest("a", inc)
            truth["a"].append(inc)
            store.flush()
        ci = store.stats()["compute_cache"]
        assert ci["maxsize"] == 1 and ci["currsize"] <= 1
        np.testing.assert_allclose(np.asarray(store.features("a")),
                                   _oracle(truth["a"], 2), atol=1e-5)
    finally:
        ops.set_plan_cache_maxsize(old)

    # the pool's cache family is visible to the global registry
    store2 = SessionStore(2, 2, initial_sessions=2)
    store2.create("x")
    store2.ingest("x", np.zeros((2, 2), np.float32))
    store2.flush()
    info = ops.plan_cache_info()
    assert "session_flush" in info
    assert "dynamic_batcher_compute" in info


# ---------------------------------------------------------------------------
# 8-device mesh twin (subprocess: XLA locks the device count at first init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import Checkpointer
    from repro.data import session_tick_stream
    from repro.launch.mesh import make_sig_mesh
    from repro.serve import SessionStore

    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_sig_mesh()
    d, depth = 3, 3

    def play(store, seed=7, rounds=3):
        tr = session_tick_stream(24, d, seed=seed)
        for _ in range(rounds):
            r = next(tr)
            store.ingest_many(r["sids"], r["counts"], r["ticks"],
                              auto_create=True)
            store.flush()
        return store

    ref = play(SessionStore(d, depth, initial_sessions=8))
    dist = play(SessionStore(d, depth, initial_sessions=8, mesh=mesh))
    st = dist.stats()
    assert st["devices"] == 8, st
    assert dist.pool_size % 8 == 0, dist.pool_size
    assert dist._ids == ref._ids
    for sid in ref._ids:
        np.testing.assert_allclose(np.asarray(dist.features(sid)),
                                   np.asarray(ref.features(sid)),
                                   rtol=1e-6, atol=1e-6, err_msg=sid)
    print("ok spmd ingest", flush=True)

    # checkpoint on the mesh -> restore on the mesh AND off it (elastic):
    # both bit-identical to the saved pool
    tmp = tempfile.mkdtemp()
    ck = Checkpointer(tmp, async_save=False)
    dist.checkpoint(ck, step=2)
    saved = {lane: np.asarray(getattr(dist.pool, lane))
             for lane in ("sig", "ring", "length", "end", "valid")}
    back_mesh = SessionStore.restore(ck, mesh=mesh)
    back_1dev = SessionStore.restore(ck)
    for name, back in (("mesh", back_mesh), ("1dev", back_1dev)):
        for lane, want in saved.items():
            np.testing.assert_array_equal(
                np.asarray(getattr(back.pool, lane)), want,
                err_msg=f"{name}/{lane}")
        assert back._ids == dist._ids
        assert back.now == dist.now
    assert back_mesh.stats()["devices"] == 8
    print("ok elastic restore", flush=True)

    # resume: same traffic into original and mesh-restored twin
    tr = session_tick_stream(24, d, seed=99)
    r = next(tr)
    live = [s for s in r["sids"] if s in dist]
    keep = [i for i, s in enumerate(r["sids"]) if s in dist]
    chunks = np.split(r["ticks"], np.cumsum(r["counts"])[:-1])
    ticks = (np.concatenate([chunks[i] for i in keep]) if keep
             else np.zeros((0, d), np.float32))
    for stst in (dist, back_mesh):
        if live:
            stst.ingest_many(live, r["counts"][keep], ticks)
            stst.flush()
    for sid in dist._ids:
        np.testing.assert_array_equal(np.asarray(dist.features(sid)),
                                      np.asarray(back_mesh.features(sid)),
                                      err_msg=sid)
    print("SESSOK mesh", flush=True)
""")


def test_session_store_sharded_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=420)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "SESSOK mesh" in r.stdout
