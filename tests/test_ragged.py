"""Ragged (variable-length) paths: padding invariance across the stack.

The subsystem's contract, tested per backend × stream × backward cell:

1. terminal signatures of a padded batch with ``lengths=`` equal per-example
   unpadded oracles (<= 1e-6), and are BIT-stable in the amount of padding;
2. streamed outputs are masked after each example's true-terminal slot and
   ``ragged_terminal`` gathers the exact terminal;
3. gradients w.r.t. padded steps are exactly zero.

Plus the container/bucketing/serving layers (RaggedPaths, DynamicBatcher),
ragged windows, sigkernel, transforms, sig-head mask pass-through and the
deterministic ragged data pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (projected_signature, signature, windowed_projection,
                        windowed_signature)
from repro.core.signature import (length_mask, mask_increments,
                                  ragged_terminal, stream_emit_mask,
                                  stream_emit_slots, stream_emit_steps)
from repro.core.words import make_plan
from repro.ragged import (RaggedPaths, assign_buckets, bucket_ladder,
                          bucket_paths, pad_batch)

from tests.conftest import make_path

BACKENDS = ("jax", "pallas_interpret")
WORDS = ((0,), (1,), (0, 1), (1, 0, 1))


def ragged_batch(rng, B=3, M=12, d=2, scale=0.3):
    path = make_path(rng, B, M, d, scale)
    lengths = np.asarray([M] + list(rng.integers(1, M, size=B - 1)))
    return jnp.asarray(path), lengths


# ---------------------------------------------------------------------------
# mask helpers
# ---------------------------------------------------------------------------

def test_length_mask_and_slots():
    lengths = jnp.asarray([0, 1, 5, 12])
    m = np.asarray(length_mask(lengths, 12))
    for b, L in enumerate([0, 1, 5, 12]):
        assert m[b].sum() == L and m[b, :L].all()
    for stride in (1, 3, 5, 12, 17):
        steps = stream_emit_steps(12, stride)
        slots = np.asarray(stream_emit_slots(12, stride, lengths))
        emit = np.asarray(stream_emit_mask(12, stride, lengths))
        for b, L in enumerate([0, 1, 5, 12]):
            # the slot's emission covers >= L increments, and it is minimal
            covered = steps[slots[b]] + 1
            assert covered >= L
            if slots[b] > 0:
                assert steps[slots[b] - 1] + 1 < max(L, 1)
            assert emit[b].sum() == slots[b] + 1


def test_mask_increments_zeros_tail():
    rng = np.random.default_rng(0)
    incs = jnp.asarray(rng.standard_normal((3, 8, 2)).astype(np.float32))
    out = np.asarray(mask_increments(incs, jnp.asarray([8, 3, 0])))
    assert np.array_equal(out[0], np.asarray(incs[0]))
    assert np.array_equal(out[1, :3], np.asarray(incs[1, :3]))
    assert np.all(out[1, 3:] == 0) and np.all(out[2] == 0)


# ---------------------------------------------------------------------------
# padding invariance: terminal values vs unpadded oracles (every cell)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("backward", ["inverse", "checkpoint", "autodiff"])
def test_ragged_terminal_matches_unpadded(rng, backend, backward):
    path, lengths = ragged_batch(rng)
    out = signature(path, 3, backend=backend, backward=backward,
                    lengths=lengths)
    for b, L in enumerate(lengths):
        ref = signature(path[b:b + 1, :L + 1], 3, backend=backend,
                        backward=backward)[0]
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref),
                                   atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS + ("hybrid",))
@pytest.mark.parametrize("backward", ["inverse", "checkpoint", "autodiff"])
def test_ragged_projected_matches_unpadded(rng, backend, backward):
    path, lengths = ragged_batch(rng)
    plan = make_plan(WORDS, 2)
    out = projected_signature(path, plan.words, 2, plan=plan,
                              backend=backend, backward=backward,
                              lengths=lengths)
    for b, L in enumerate(lengths):
        ref = projected_signature(path[b:b + 1, :L + 1], plan.words, 2,
                                  plan=plan, backend=backend,
                                  backward=backward)[0]
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref),
                                   atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(k=st.integers(min_value=0, max_value=9),
       backend=st.sampled_from(BACKENDS),
       stream=st.booleans(),
       backward=st.sampled_from(["inverse", "checkpoint", "autodiff"]))
def test_padding_bitstable_in_k(k, backend, stream, backward):
    """signature(pad(x, k), lengths) is BIT-stable in the padding amount k,
    across backend × stream × checkpoint cells (property test)."""
    if stream and backward == "checkpoint":
        return  # unsupported cell (raises; covered elsewhere)
    rng = np.random.default_rng(42)
    path = jnp.asarray(make_path(rng, 2, 10, 2))
    lengths = np.asarray([10, 6])

    def run(p):
        return signature(p, 3, backend=backend, backward=backward,
                         stream=stream, lengths=lengths)

    base = np.asarray(run(path))
    if k:
        garbage = jnp.asarray(
            rng.standard_normal((2, k, 2)).astype(np.float32))
        padded = jnp.concatenate([path, garbage], axis=1)
        got = np.asarray(run(padded))
        if stream:
            # emissions at the shared slots agree bitwise; the extra padded
            # slots are exactly zero (masked)
            emit = np.asarray(stream_emit_mask(10 + k, 1,
                                               jnp.asarray(lengths)))
            assert np.array_equal(got[:, :base.shape[1]], base)
            assert np.all(got[~emit] == 0)
        else:
            assert np.array_equal(got, base)


# ---------------------------------------------------------------------------
# streamed emissions: masking + true-terminal gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("stride", [1, 4])
def test_ragged_stream_mask_and_terminal(rng, backend, stride):
    path, lengths = ragged_batch(rng, M=12)
    out = signature(path, 3, backend=backend, stream=True,
                    stream_stride=stride, lengths=lengths)
    emit = np.asarray(stream_emit_mask(12, stride, jnp.asarray(lengths)))
    assert np.all(np.asarray(out)[~emit] == 0)
    term = ragged_terminal(out, lengths, stride, M=12)
    ref = signature(path, 3, backend=backend, lengths=lengths)
    np.testing.assert_allclose(np.asarray(term), np.asarray(ref), atol=1e-6)
    # in-range emissions match the unpadded per-example stream
    steps = stream_emit_steps(12, stride)
    for b, L in enumerate(lengths):
        sref = signature(path[b:b + 1, :L + 1], 3, backend=backend,
                         stream=True)[0]          # (L, D)
        for j, t in enumerate(steps):
            if t + 1 <= L:
                np.testing.assert_allclose(np.asarray(out[b, j]),
                                           np.asarray(sref[t]), atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_ragged_projected_stream(rng, backend):
    path, lengths = ragged_batch(rng, M=10)
    plan = make_plan(WORDS, 2)
    out = projected_signature(path, plan.words, 2, plan=plan, stream=True,
                              stream_stride=3, backend=backend,
                              lengths=lengths)
    emit = np.asarray(stream_emit_mask(10, 3, jnp.asarray(lengths)))
    assert np.all(np.asarray(out)[~emit] == 0)
    term = ragged_terminal(out, lengths, 3, M=10)
    ref = projected_signature(path, plan.words, 2, plan=plan,
                              backend=backend, lengths=lengths)
    np.testing.assert_allclose(np.asarray(term), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# gradients: exactly zero past each example's true end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("backward", ["inverse", "checkpoint", "autodiff"])
@pytest.mark.parametrize("stream", [False, True])
def test_ragged_grads_zero_past_end(rng, backend, backward, stream):
    if stream and backward == "checkpoint":
        pytest.skip("stream x checkpoint raises (support matrix)")
    path, lengths = ragged_batch(rng)

    def loss(p):
        out = signature(p, 3, backend=backend, backward=backward,
                        stream=stream, lengths=lengths)
        return jnp.sum(out ** 2)

    g = np.asarray(jax.grad(loss)(path))
    assert np.all(np.isfinite(g))
    for b, L in enumerate(lengths):
        # path point k feeds increments k-1 and k; every increment >= L is
        # masked, so points strictly past L get EXACTLY zero gradient
        assert np.all(g[b, L + 1:] == 0.0), (backend, backward, stream, b)
        assert np.any(g[b, :L + 1] != 0.0)


@pytest.mark.parametrize("backend", BACKENDS + ("hybrid",))
def test_ragged_projected_grads_zero_past_end(rng, backend):
    path, lengths = ragged_batch(rng)
    plan = make_plan(WORDS, 2)

    def loss(p):
        out = projected_signature(p, plan.words, 2, plan=plan,
                                  backend=backend, lengths=lengths)
        return jnp.sum(out ** 2)

    g = np.asarray(jax.grad(loss)(path))
    for b, L in enumerate(lengths):
        assert np.all(g[b, L + 1:] == 0.0)


# ---------------------------------------------------------------------------
# RaggedPaths container + bucketing
# ---------------------------------------------------------------------------

def test_ragged_paths_constructors(rng):
    paths = [np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32), 0)
             for L in (3, 9, 5)]
    rp = RaggedPaths.from_list(paths)
    assert rp.batch == 3 and rp.max_len == 9 and rp.d == 2
    assert np.array_equal(np.asarray(rp.lengths), [3, 9, 5])
    # frozen tail: zero increments past the end even WITHOUT masking
    incs = np.asarray(rp.values[:, 1:] - rp.values[:, :-1])
    for b, L in enumerate([3, 9, 5]):
        assert np.all(incs[b, L:] == 0)
    flat = np.concatenate(paths, axis=0)
    rp2 = RaggedPaths.from_segments(flat, [4, 10, 6])
    assert np.array_equal(np.asarray(rp2.values), np.asarray(rp.values))
    # the container is accepted directly by the signature entry points
    sig = signature(rp, 3)
    for b, p in enumerate(paths):
        ref = signature(jnp.asarray(p)[None], 3)[0]
        np.testing.assert_allclose(np.asarray(sig[b]), np.asarray(ref),
                                   atol=1e-6)
    # pytree: jit accepts it
    jsig = jax.jit(lambda r: signature(r, 3))(rp)
    np.testing.assert_allclose(np.asarray(jsig), np.asarray(sig), atol=0)
    # terminal points + pad_to keep exactness
    tp = np.asarray(rp.terminal_points())
    for b, p in enumerate(paths):
        assert np.array_equal(tp[b], p[-1])
    sig2 = signature(rp.pad_to(16), 3)
    np.testing.assert_allclose(np.asarray(sig2), np.asarray(sig), atol=0)


def test_ragged_paths_validation():
    with pytest.raises(ValueError):
        RaggedPaths.from_list([])
    with pytest.raises(ValueError):
        RaggedPaths.from_list([np.zeros((3, 2)), np.zeros((3, 3))])
    with pytest.raises(ValueError):
        RaggedPaths.from_segments(np.zeros((5, 2)), [2, 2])
    with pytest.raises(ValueError):
        RaggedPaths.from_list([np.zeros((4, 2))], pad_to=2)


def test_bucket_ladder_and_assignment():
    lad = bucket_ladder(100, min_len=8, growth=2.0)
    assert lad[0] == 8 and lad[-1] >= 100
    assert all(b > a for a, b in zip(lad, lad[1:]))
    lengths = np.asarray([1, 8, 9, 16, 100])
    which = assign_buckets(lengths, lad)
    for L, k in zip(lengths, which):
        assert lad[k] >= L and (k == 0 or lad[k - 1] < L)
    with pytest.raises(ValueError):
        assign_buckets([101], lad)
    with pytest.raises(ValueError):
        bucket_ladder(10, growth=1.0)


def test_bucket_paths_exact(rng):
    paths = [np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32), 0)
             for L in (2, 3, 17, 40, 9, 64, 33, 5)]
    rp = RaggedPaths.from_list(paths)
    full = signature(rp, 3)
    groups = bucket_paths(rp, bucket_ladder(64, min_len=8))
    covered = []
    for idx, sub in groups:
        assert sub.max_len <= 64
        s = signature(sub, 3)
        for j, i in enumerate(idx):
            covered.append(int(i))
            np.testing.assert_allclose(np.asarray(s[j]),
                                       np.asarray(full[i]), atol=1e-6)
    assert sorted(covered) == list(range(8))
    padded = pad_batch(rp, 16)
    assert padded.batch == 16
    np.testing.assert_allclose(np.asarray(signature(padded, 3)[:8]),
                               np.asarray(full), atol=0)


# ---------------------------------------------------------------------------
# DynamicBatcher serving layer
# ---------------------------------------------------------------------------

def test_dynamic_batcher_exact_and_bounded(rng):
    from repro.serve import DynamicBatcher
    reqs = [np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32), 0)
            for L in (5, 40, 12, 3, 63, 21, 9, 2, 31, 17)]
    db = DynamicBatcher.signature_service(2, 3, max_len=64, backend="jax",
                                          min_bucket=8, max_batch=4)
    tickets = [db.submit(r) for r in reqs]
    res = db.flush()
    assert db.pending == 0 and set(res) == set(tickets)
    for t, r in zip(tickets, reqs):
        ref = signature(jnp.asarray(r)[None], 3)[0]
        np.testing.assert_allclose(np.asarray(res[t]), np.asarray(ref),
                                   atol=1e-6)
    st_ = db.stats()
    ladder = st_["ladder"]
    # the shape set is bounded by ladder x batch rungs, whatever the traffic
    assert st_["compiled_shapes"] <= len(ladder) * 3
    for rung, B in st_["shapes"]:
        assert rung in ladder and B <= 4
    # second wave reuses shapes (no growth for repeat traffic)
    n_shapes = st_["compiled_shapes"]
    t2 = [db.submit(r) for r in reqs]
    res2 = db.flush()
    assert db.stats()["compiled_shapes"] == n_shapes
    for t, r in zip(t2, reqs):
        ref = signature(jnp.asarray(r)[None], 3)[0]
        np.testing.assert_allclose(np.asarray(res2[t]), np.asarray(ref),
                                   atol=1e-6)


def test_dynamic_batcher_async_prefetch_matches_sync(rng):
    """async_dispatch places the next rung's padded batch on device while
    the current rung computes (bounded by max_in_flight) — results are
    identical to the synchronous path and the stats surface the overlap."""
    from repro.serve import DynamicBatcher
    reqs = [np.cumsum(rng.normal(size=(L + 1, 2)).astype(np.float32), 0)
            for L in (5, 40, 12, 3, 63, 21, 9, 2, 31, 17, 48, 7)]

    def run(**kw):
        db = DynamicBatcher.signature_service(2, 3, max_len=64,
                                              backend="jax", min_bucket=8,
                                              max_batch=4, **kw)
        tickets = [db.submit(r) for r in reqs]
        res = db.flush()
        return db, {id(r): res[t] for t, r in zip(tickets, reqs)}

    db_a, res_a = run(async_dispatch=True, max_in_flight=3)
    db_s, res_s = run(async_dispatch=False)
    for r in reqs:
        np.testing.assert_array_equal(np.asarray(res_a[id(r)]),
                                      np.asarray(res_s[id(r)]))
    sa, ss = db_a.stats(), db_s.stats()
    assert sa["async_dispatch"] and sa["max_in_flight"] == 3
    assert sa["prefetched_rungs"] >= 1, sa       # overlap actually happened
    assert sa["in_flight_peak"] >= 2, sa
    assert not ss["async_dispatch"] and ss["prefetched_rungs"] == 0, ss
    with pytest.raises(ValueError, match="max_in_flight"):
        DynamicBatcher.signature_service(2, 3, max_len=16, max_in_flight=0)


def test_dynamic_batcher_validation(rng):
    from repro.serve import DynamicBatcher
    db = DynamicBatcher.signature_service(2, 3, max_len=32, backend="jax")
    with pytest.raises(ValueError):
        db.submit(np.zeros((40, 2), np.float32))   # too long
    with pytest.raises(ValueError):
        db.submit(np.zeros((4, 3), np.float32))    # wrong d
    assert db.flush() == {}


def test_dynamic_batcher_scoring(rng):
    from repro.serve import DynamicBatcher, SigScoreEngine
    refs = jnp.asarray(np.cumsum(
        rng.normal(size=(5, 17, 2)).astype(np.float32) * 0.2, axis=1))
    eng = SigScoreEngine(d=2, depth=3, batch=2, references=refs,
                         backend="jax",
                         targets=np.arange(5, dtype=np.float32))
    db = DynamicBatcher.scoring_service(eng, max_len=32, min_bucket=8)
    # a full-length request equals the engine's own scoring of that path
    q = np.cumsum(rng.normal(size=(17, 2)).astype(np.float32) * 0.2, 0)
    t = db.submit(q)
    got = np.asarray(db.flush()[t])
    eng.state = eng.state.extend(
        jnp.asarray(q[1:] - q[:-1])[None].repeat(2, 0))
    want = np.asarray(eng.scores())[0]
    np.testing.assert_allclose(got, want, atol=1e-5)
    pb = DynamicBatcher.scoring_service(eng, max_len=32, mode="predict")
    t2 = pb.submit(q)
    pred = np.asarray(pb.flush()[t2])
    np.testing.assert_allclose(
        pred, np.asarray(eng.predict())[0], atol=1e-5)
    with pytest.raises(ValueError):
        DynamicBatcher.scoring_service(eng, max_len=32, mode="nope")


# ---------------------------------------------------------------------------
# ragged windows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("route", ["fold", "chen"])
def test_ragged_windows_clip(rng, route):
    path, lengths = ragged_batch(rng, M=16)
    wins = np.asarray([[0, 4], [2, 10], [0, 16], [12, 16]])
    out = windowed_signature(path, wins, 3, route=route, lengths=lengths)
    for b, L in enumerate(lengths):
        for k, (l, r) in enumerate(wins):
            lc, rc = min(l, L), min(r, L)
            if rc > lc:
                ref = signature(path[b:b + 1, lc:rc + 1], 3)[0]
            else:
                ref = jnp.zeros_like(out[b, k])
            np.testing.assert_allclose(np.asarray(out[b, k]),
                                       np.asarray(ref), atol=1e-5)


def test_ragged_windowed_projection(rng):
    path, lengths = ragged_batch(rng, M=16)
    plan = make_plan(WORDS, 2)
    wins = np.asarray([[0, 8], [4, 16]])
    out = windowed_projection(path, wins, plan, route="fold",
                              lengths=lengths)
    full = windowed_signature(path, wins, plan.depth, route="fold",
                              lengths=lengths)
    from repro.core.words import flat_index
    idx = [flat_index(w, 2) for w in plan.words]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full)[..., idx], atol=1e-6)
    # RaggedPaths accepted directly
    rp = RaggedPaths.from_dense(path, lengths)
    out2 = windowed_signature(rp, wins, 3, route="fold")
    np.testing.assert_allclose(
        np.asarray(out2),
        np.asarray(windowed_signature(path, wins, 3, route="fold",
                                      lengths=lengths)), atol=0)


# ---------------------------------------------------------------------------
# ragged sigkernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_ragged_sig_gram(rng, backend):
    from repro.sigkernel import sig_gram
    x, xl = ragged_batch(rng, B=4, M=10)
    y, yl = ragged_batch(rng, B=3, M=14)
    K = sig_gram(x, y, 3, backend=backend, x_lengths=xl, y_lengths=yl)
    Sx = [signature(x[b:b + 1, :xl[b] + 1], 3)[0] for b in range(4)]
    Sy = [signature(y[b:b + 1, :yl[b] + 1], 3)[0] for b in range(3)]
    ref = np.asarray([[float(jnp.dot(a, c)) for c in Sy] for a in Sx])
    np.testing.assert_allclose(np.asarray(K), ref, atol=1e-5, rtol=1e-5)
    # RaggedPaths spelling agrees
    K2 = sig_gram(RaggedPaths.from_dense(x, xl),
                  RaggedPaths.from_dense(y, yl), 3, backend=backend)
    np.testing.assert_allclose(np.asarray(K2), np.asarray(K), atol=0)


def test_ragged_sig_mmd_grad(rng):
    from repro.sigkernel import sig_mmd
    x, xl = ragged_batch(rng, B=4, M=10)
    y, yl = ragged_batch(rng, B=3, M=8)
    val = sig_mmd(x, y, 3, x_lengths=xl, y_lengths=yl)
    assert np.isfinite(float(val))
    g = np.asarray(jax.grad(
        lambda a: sig_mmd(a, y, 3, x_lengths=xl, y_lengths=yl))(x))
    for b, L in enumerate(xl):
        assert np.all(g[b, L + 1:] == 0.0)
    # padding invariance of the statistic itself
    pad = jnp.concatenate(
        [x, jnp.asarray(rng.standard_normal((4, 5, 2)).astype(np.float32))],
        axis=1)
    val2 = sig_mmd(pad, y, 3, x_lengths=xl, y_lengths=yl)
    np.testing.assert_allclose(float(val2), float(val), atol=1e-6)


# ---------------------------------------------------------------------------
# ragged transforms
# ---------------------------------------------------------------------------

def test_transforms_ragged_invariance(rng):
    from repro.core import basepoint_augment, lead_lag, time_augment
    path, lengths = ragged_batch(rng, B=3, M=9)
    for name, fn in [("time", lambda p, l: time_augment(p, lengths=l)),
                     ("leadlag", lambda p, l: lead_lag(p, lengths=l)),
                     ("base", lambda p, l: basepoint_augment(p, l))]:
        out, nl = fn(path, jnp.asarray(lengths))
        sig = signature(out, 3, lengths=nl)
        for b, L in enumerate(lengths):
            ref_t, ref_l = fn(path[b:b + 1, :L + 1], jnp.asarray([L]))
            ref = signature(ref_t[:, :int(ref_l[0]) + 1], 3)[0]
            np.testing.assert_allclose(np.asarray(sig[b]), np.asarray(ref),
                                       atol=1e-6, err_msg=name)
    # without lengths: legacy single-return behaviour is untouched
    assert time_augment(path).shape == (3, 10, 3)
    assert lead_lag(path).shape == (3, 19, 4)
    assert basepoint_augment(path).shape == (3, 11, 2)


def test_time_augment_ragged_reaches_t1(rng):
    from repro.core import time_augment
    path, _ = ragged_batch(rng, B=2, M=8)
    lengths = jnp.asarray([8, 3])
    out, _ = time_augment(path, lengths=lengths)
    t = np.asarray(out[..., 0])
    assert np.isclose(t[1, 3], 1.0) and np.allclose(t[1, 3:], 1.0)
    assert np.isclose(t[0, -1], 1.0) and t[1, 2] < 1.0


# ---------------------------------------------------------------------------
# sig-head mask pass-through + trainer ragged MMD
# ---------------------------------------------------------------------------

def _sig_cfg(**kw):
    from repro.models.config import ModelConfig, SigHeadConfig
    return ModelConfig(name="t", family="decoder", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=50,
                       sig_head=SigHeadConfig(channels=3, depth=3,
                                              backend="jax", **kw))


def test_sig_pool_mask_matches_unpadded(rng):
    from repro.models.sig_head import init_sig_head, sig_pool
    cfg = _sig_cfg()
    p = init_sig_head(jax.random.PRNGKey(0), cfg, 5)
    h = jnp.asarray(rng.standard_normal((3, 12, 16)).astype(np.float32))
    n_valid = [12, 7, 4]
    mask = jnp.asarray(np.arange(12)[None, :] < np.asarray(n_valid)[:, None])
    out = sig_pool(p, h, cfg, mask=mask)
    for b, n in enumerate(n_valid):
        ones = jnp.ones((1, n), bool)
        ref = sig_pool(p, h[b:b + 1, :n], cfg, mask=ones)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   atol=1e-5)
    # gradient w.r.t. masked-out hidden states is exactly zero
    g = np.asarray(jax.grad(
        lambda hh: jnp.sum(sig_pool(p, hh, cfg, mask=mask) ** 2))(h))
    for b, n in enumerate(n_valid):
        assert np.all(g[b, n:] == 0.0)


def test_sig_stream_features_mask(rng):
    from repro.models.sig_head import init_sig_head, sig_stream_features
    cfg = _sig_cfg()
    p = init_sig_head(jax.random.PRNGKey(1), cfg, 4)
    h = jnp.asarray(rng.standard_normal((2, 10, 16)).astype(np.float32))
    mask = jnp.asarray(np.arange(10)[None, :] < np.asarray([10, 5])[:, None])
    out = np.asarray(sig_stream_features(p, h, cfg, mask=mask))
    assert out.shape[:2] == (2, 9)
    assert np.all(out[1, 4:] == 0.0)       # post-end steps fully zeroed
    assert np.any(out[1, :4] != 0.0)


def test_sig_stream_features_mask_strided_no_pad_leak(rng):
    """stream_stride > 1: the true-terminal emission slot may cover past-end
    steps; its displacement must read X_L (clamped), never a pad-token
    projection — so pad hidden states get exactly zero gradient."""
    from repro.models.sig_head import init_sig_head, sig_stream_features
    cfg = _sig_cfg(stream_stride=3)
    p = init_sig_head(jax.random.PRNGKey(1), cfg, 4)
    h = jnp.asarray(rng.standard_normal((2, 10, 16)).astype(np.float32))
    n_valid = [10, 5]
    mask = jnp.asarray(
        np.arange(10)[None, :] < np.asarray(n_valid)[:, None])
    out = sig_stream_features(p, h, cfg, mask=mask)
    # terminal-slot features equal the unpadded per-example terminal slot
    ref = sig_stream_features(p, h[1:2, :5], cfg, mask=jnp.ones((1, 5),
                                                                bool))
    np.testing.assert_allclose(np.asarray(out[1, 1]), np.asarray(ref[0, -1]),
                               atol=1e-5)
    g = np.asarray(jax.grad(lambda hh: jnp.sum(
        sig_stream_features(p, hh, cfg, mask=mask) ** 2))(h))
    assert np.all(g[1, 5:] == 0.0)         # no gradient into pad positions
    assert np.any(g[1, :5] != 0.0)


def test_sig_kernel_pool_mask(rng):
    from repro.models.sig_head import init_sig_head, sig_pool
    cfg = _sig_cfg(kernel_landmarks=4, landmark_steps=5)
    p = init_sig_head(jax.random.PRNGKey(2), cfg, 5)
    h = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
    mask = jnp.asarray(np.arange(8)[None, :] < np.asarray([8, 3])[:, None])
    out = sig_pool(p, h, cfg, mask=mask)
    ref = sig_pool(p, h[1:2, :3], cfg, mask=jnp.ones((1, 3), bool))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[0]),
                               atol=1e-5)


def test_trainer_ragged_sig_mmd(rng):
    """The trainer's sig_mmd loss consumes the ragged pipeline keys
    (paths + path_lengths) AND the backbone attention mask — finite loss,
    zero gradient into masked-out token positions' hidden states."""
    import dataclasses
    import repro.models as M
    from repro.configs import get_config, reduce_config, with_sig_head
    from repro.data import RaggedPathStream
    from repro.optim import adamw
    from repro.train import make_train_step
    base = reduce_config(get_config("qwen3-4b"))
    cfg = dataclasses.replace(
        with_sig_head(base, channels=3, depth=2, backend="jax"),
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
        vocab_size=64, head_dim=16)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    step = jax.jit(make_train_step(cfg, adamw(lr=1e-3), loss="sig_mmd"))
    batch = next(RaggedPathStream(batch=6, max_steps=8, d=3, seed=0))
    full = dict(batch,
                tokens=jnp.asarray(rng.integers(0, 64, size=(4, 9))),
                mask=jnp.asarray(np.arange(9)[None, :] < np.asarray(
                    [9, 6, 4, 9])[:, None], jnp.int32))
    opt_state = adamw(lr=1e-3).init(params)
    _, _, metrics = step(params, opt_state, full)
    assert np.isfinite(float(metrics["loss"]))


# ---------------------------------------------------------------------------
# ragged data pipeline determinism
# ---------------------------------------------------------------------------

def test_geometric_lengths_deterministic_and_skewed():
    from repro.data import geometric_lengths
    a = geometric_lengths(0, 4000, 256)
    assert np.array_equal(a, geometric_lengths(0, 4000, 256))
    assert a.min() >= 2 and a.max() <= 256
    assert a.max() / np.median(a) >= 4.0     # the serving-traffic shape
    assert not np.array_equal(a, geometric_lengths(1, 4000, 256))


def test_ragged_path_stream_seekable(rng):
    from repro.data import RaggedPathStream
    s1 = RaggedPathStream(batch=3, max_steps=16, d=2, seed=5)
    batches = [next(s1) for _ in range(3)]
    s2 = RaggedPathStream(batch=3, max_steps=16, d=2, seed=5)
    s2.restore({"step": 2, "seed": 5})
    b2 = next(s2)
    assert np.array_equal(np.asarray(b2["paths"]),
                          np.asarray(batches[2]["paths"]))
    p, L = np.asarray(batches[0]["paths"]), \
        np.asarray(batches[0]["path_lengths"])
    for b in range(3):                      # frozen tails
        assert np.all(p[b, L[b]:] == p[b, L[b]])
    # ragged fbm + token variants are deterministic too
    from repro.data import ragged_fbm_dataset, ragged_token_batches
    x1, l1, h1 = ragged_fbm_dataset(3, 4, 12, 2)
    x2, l2, h2 = ragged_fbm_dataset(3, 4, 12, 2)
    assert np.array_equal(x1, x2) and np.array_equal(l1, l2)
    t1 = next(iter(ragged_token_batches(30, 2, 10, seed=4)))
    t2 = next(iter(ragged_token_batches(30, 2, 10, seed=4)))
    assert np.array_equal(np.asarray(t1["tokens"]), np.asarray(t2["tokens"]))
    assert np.array_equal(np.asarray(t1["mask"]), np.asarray(t2["mask"]))
