"""Persistent per-cell autotuner (repro.kernels.autotune) safety rails.

The cache is an optimisation, never a correctness dependency: corrupt files
degrade to defaults with one warning, invalid modes degrade to ``off``,
traced shapes and jax-engine cells skip the lookup, and the hysteresis rule
guarantees an autotuned cell can never lose to the library default by more
than timing noise.
"""
from __future__ import annotations

import json
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune, ops


@pytest.fixture()
def cache(tmp_path, monkeypatch):
    p = tmp_path / "tune.json"
    monkeypatch.setenv("PATHSIG_AUTOTUNE_CACHE", str(p))
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "load")
    autotune.clear()
    yield p
    autotune.clear()


def _write(p, payload):
    p.write_text(payload if isinstance(payload, str)
                 else json.dumps(payload))
    autotune.clear()


CELL = dict(engine="pallas_interpret", d=3, depth=3, M=100, B=32,
            precision="fp32")


def test_load_mode_returns_cached_record(cache):
    key = autotune.cell_key("sig_trunc", **CELL)
    _write(cache, {"version": 1,
                   "cells": {key: {"batch_tile": 32, "split": 1}}})
    hit = autotune.lookup("sig_trunc", **CELL)
    assert hit["batch_tile"] == 32 and hit["split"] == 1


def test_corrupt_cache_falls_back_to_defaults(cache):
    """Satellite guard: garbage cache -> defaults + ONE warning, no raise."""
    _write(cache, "{not json at all")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert autotune.lookup("sig_trunc", **CELL) == {}
        assert autotune.lookup("sig_trunc", **CELL) == {}  # warned once
    assert sum("corrupt" in str(x.message) for x in w) == 1
    # and the dispatch keeps working end to end on the defaults
    incs = jnp.asarray(np.random.default_rng(0)
                       .standard_normal((4, 9, 2)).astype(np.float32))
    out = ops.signature(incs, 3, backend="pallas_interpret")
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("payload", [
    {"version": 999, "cells": {}},          # wrong version
    {"version": 1, "cells": "nope"},        # wrong cells type
    [1, 2, 3],                              # wrong top-level type
], ids=["version", "cells-type", "top-type"])
def test_wrong_schema_falls_back(cache, payload):
    _write(cache, payload)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert autotune.lookup("sig_trunc", **CELL) == {}


def test_off_mode_never_reads(cache, monkeypatch):
    key = autotune.cell_key("sig_trunc", **CELL)
    _write(cache, {"version": 1, "cells": {key: {"batch_tile": 8}}})
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "off")
    assert autotune.lookup("sig_trunc", **CELL) == {}


def test_invalid_mode_degrades_to_off(cache, monkeypatch):
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "turbo")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert autotune.mode() == "off"


def test_jax_engine_skips_lookup(cache):
    key = autotune.cell_key("sig_trunc", **dict(CELL, engine="jax"))
    _write(cache, {"version": 1, "cells": {key: {"batch_tile": 8}}})
    assert autotune.lookup("sig_trunc", **dict(CELL, engine="jax")) == {}


def test_cell_key_buckets_sizes():
    a = autotune.cell_key("sig_trunc", **dict(CELL, M=100, B=32))
    b = autotune.cell_key("sig_trunc", **dict(CELL, M=128, B=20))
    c = autotune.cell_key("sig_trunc", **dict(CELL, M=129, B=32))
    assert a == b            # 100 and 128 share the pow2 bucket; 20|32 too
    assert a != c            # 129 -> 256
    d_ = autotune.cell_key("sig_trunc", **dict(CELL, d=4))
    assert d_ != a           # structural axes are exact


def test_hysteresis_keeps_default_within_noise():
    """A non-default candidate must beat the default by >= 10%, so the tuned
    configuration can never lose to the default by more than that margin."""
    default = {"batch_tile": 128, "split": None}
    other = {"batch_tile": 8, "split": None}
    # 5% faster: not enough evidence, default retained
    pick = autotune._pick([(1.00, default), (0.95, other)], default)
    assert pick == default
    # 20% faster: tuned wins
    pick = autotune._pick([(1.00, default), (0.80, other)], default)
    assert pick == other


def test_lookup_through_ops_dispatch(cache):
    """ops.signature with batch_tile=None consults the cache; a cached tile
    must give the same numbers as passing it explicitly."""
    incs = jnp.asarray(np.random.default_rng(0)
                       .standard_normal((4, 9, 3)).astype(np.float32))
    key = autotune.cell_key("sig_trunc", engine="pallas_interpret", d=3,
                            depth=3, M=9, B=4, precision="fp32")
    _write(cache, {"version": 1,
                   "cells": {key: {"batch_tile": 8, "split": 1}}})
    tuned = ops.signature(incs, 3, backend="pallas_interpret")
    explicit = ops.signature(incs, 3, backend="pallas_interpret",
                             batch_tile=8, split=1)
    np.testing.assert_array_equal(np.asarray(tuned), np.asarray(explicit))


def test_sweep_mode_persists_winner(cache, monkeypatch):
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "sweep")
    cell = dict(engine="pallas_interpret", d=2, depth=2, M=6, B=4,
                precision="fp32")
    rec = autotune.lookup("sig_trunc", **cell)
    assert "batch_tile" in rec
    saved = json.loads(cache.read_text())
    assert autotune.cell_key("sig_trunc", **cell) in saved["cells"]
    # second lookup is a pure cache hit (load mode suffices)
    monkeypatch.setenv("PATHSIG_AUTOTUNE", "load")
    autotune.clear()
    assert autotune.lookup("sig_trunc", **cell)["batch_tile"] == \
        rec["batch_tile"]


def test_tuned_never_loses_to_default_by_more_than_5pct():
    """Acceptance rail measured, not assumed: time the recorded winner vs
    the default on a sweep cell; the hysteresis rule plus shared timing
    noise keeps any regression under 5%... with CPU-timer slack."""
    import time

    import jax

    cell = dict(engine="pallas_interpret", d=2, depth=3, M=20, B=4,
                precision="fp32")
    rec = autotune.sweep_cell("sig_trunc", cell, repeats=3)
    if not rec:
        pytest.skip("sweep found nothing to tune")
    incs = jnp.asarray(np.random.default_rng(0).standard_normal(
        (cell["B"], cell["M"], cell["d"])).astype(np.float32))

    def med(bt, sp):
        fn = jax.jit(lambda x: ops.signature(
            x, cell["depth"], backend="pallas_interpret", batch_tile=bt,
            split=sp))
        jax.block_until_ready(fn(incs))
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(incs))
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[2]

    t_tuned = med(rec["batch_tile"], rec.get("split"))
    t_default = med(128, None)
    assert t_tuned <= 1.30 * t_default  # 5% rule + generous CPU-timer noise
